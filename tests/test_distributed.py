"""Distributed tests — spawn subprocesses with 8 fake host devices so the
main test process keeps its single-device view (per the brief, the forced
device count must never leak into smoke tests/benches)."""

import os
import subprocess
import sys
import textwrap

import pytest

# subprocess spawns + 8 fake devices: ~3.5 min wall — keep out of the CI
# fast lane (`-m "not slow"`); the full lane still runs everything.
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_compiles_and_runs():
    print(run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_arch, ShapeSpec
        from repro.launch.steps import build_cell, family_fns
        from repro.optim import adamw_init
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        arch = get_arch("qwen3-0.6b", smoke=True)
        import dataclasses
        # widen smoke so dims divide the 4-way model axis
        arch = dataclasses.replace(arch, model=dataclasses.replace(
            arch.model, d_model=128, n_heads=4, n_kv=2, head_dim=32,
            d_ff=256, vocab=256))
        cell = build_cell(arch, ShapeSpec("t", "train", 64, 4), mesh)
        fns = family_fns(arch)
        with mesh:
            params = jax.jit(fns["init"],
                             out_shardings=cell.in_shardings[0])(
                jax.random.PRNGKey(0))
            opt = jax.jit(adamw_init,
                          out_shardings=cell.in_shardings[1])(params)
            step = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings)
            from repro.data import DataConfig, synthetic_batch
            b = synthetic_batch(DataConfig(vocab=256, seq_len=64,
                                           global_batch=4), 0)
            p2, o2, m = step(params, opt, b)
            assert np.isfinite(float(m["loss"]))
            # TP actually sharded something across the model axis
            wq = p2["blocks"]["attn"]["wq"]
            assert len(wq.sharding.device_set) == 8 or \
                   "model" in str(wq.sharding.spec)
            print("loss", float(m["loss"]))
        print("OK")
    """))


def test_sharded_result_matches_single_device():
    """The same train step on a (2,4) mesh and on 1 device gives the same
    loss — GSPMD partitioning must not change semantics."""
    code = """
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs.registry import get_arch, ShapeSpec
        from repro.launch.steps import build_cell, family_fns
        from repro.optim import adamw_init
        from repro.data import DataConfig, synthetic_batch
        arch = get_arch("tinyllama-1.1b", smoke=True)
        arch = dataclasses.replace(arch, model=dataclasses.replace(
            arch.model, d_model=128, n_heads=4, n_kv=2, head_dim=32,
            d_ff=256, vocab=256))
        fns = family_fns(arch)
        b = synthetic_batch(DataConfig(vocab=256, seq_len=64,
                                       global_batch=4), 0)
        mesh = jax.make_mesh(MESH_SHAPE, ("data", "model"))
        cell = build_cell(arch, ShapeSpec("t", "train", 64, 4), mesh)
        with mesh:
            params = jax.jit(fns["init"],
                             out_shardings=cell.in_shardings[0])(
                jax.random.PRNGKey(0))
            opt = jax.jit(adamw_init,
                          out_shardings=cell.in_shardings[1])(params)
            step = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings)
            _, _, m = step(params, opt, b)
            print("LOSS=%.6f" % float(m["loss"]))
    """
    out1 = run_sub(code.replace("MESH_SHAPE", "(1, 1)"), devices=1)
    out8 = run_sub(code.replace("MESH_SHAPE", "(2, 4)"), devices=8)
    l1 = float(out1.split("LOSS=")[1].split()[0])
    l8 = float(out8.split("LOSS=")[1].split()[0])
    assert abs(l1 - l8) < 5e-3, (l1, l8)


def test_elastic_retarget_between_meshes():
    print(run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.fault_tolerance import elastic_retarget
        from repro.models.modules import ModelConfig, AttnConfig
        from repro.models.transformer import lm_init
        cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv=2,
                          d_ff=128, vocab=128,
                          attn=AttnConfig(window=16, k=16))
        params = lm_init(jax.random.PRNGKey(0), cfg)
        m1 = jax.make_mesh((2, 4), ("data", "model"))
        p1 = elastic_retarget(params, m1)
        # "node failure": retarget onto a smaller mesh
        m2 = jax.make_mesh((1, 2), ("data", "model"))
        p2 = elastic_retarget(jax.device_get(p1), m2)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK")
    """))


def test_dryrun_cell_on_test_mesh():
    """The dry-run machinery itself (lower+compile+roofline) on 8 devices."""
    print(run_sub("""
        import jax
        from repro.configs.registry import get_arch, SHAPES, ShapeSpec
        import dataclasses
        from repro.launch.steps import build_cell
        from repro.analysis import roofline as rl
        arch = get_arch("qwen3-0.6b", smoke=True)
        arch = dataclasses.replace(arch, model=dataclasses.replace(
            arch.model, d_model=128, n_heads=4, n_kv=2, head_dim=32,
            d_ff=256, vocab=256))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cell = build_cell(arch, ShapeSpec("t", "train", 64, 8), mesh)
        with mesh:
            lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                              out_shardings=cell.out_shardings).lower(*cell.args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes >= 0
        roof = rl.from_compiled("t", "2x4", 8, compiled, model_flops=1e9)
        assert roof.flops_per_chip > 0
        assert roof.t_compute > 0 and roof.t_memory > 0
        print("bottleneck:", roof.bottleneck, "coll:", roof.coll_breakdown)
        print("OK")
    """))
