import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; distributed tests spawn subprocesses with
# their own XLA_FLAGS (see test_distributed.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# tests/ has no __init__.py; make the _hypothesis_compat shim importable
# regardless of pytest's import mode
sys.path.insert(0, os.path.dirname(__file__))

# One consistent RNG implementation for the whole suite: src/repro/
# __init__.py flips jax_threefry_partitionable on at package import
# (mesh-invariant init); setting it up-front too keeps random streams
# identical even for tests that touch jax.random before importing repro.
import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
