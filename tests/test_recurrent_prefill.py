"""Chunk-parallel recurrent prefill ↔ sequential-scan bit-identity.

`models.mamba2.mamba_prefill_chunk` and `models.rglru.rg_prefill_chunk`
hoist every position-local op (norms, projections, causal conv, gates,
output paths) into bulk [S, nc] computations and keep only the O(nc)
state recurrence (plus the cache-appending attention sub-step in the
hybrid) in a `lax.scan`.  The serving contract — recompute-from-prompt
preemption is exact, chunked admission equals decode-built state — rests
on these being BIT-identical to the retained token-sequential references
(`*_prefill_chunk_seq`, which scan the exact decode-step update), so this
suite compares logits at valid rows and EVERY state leaf with
array_equal, never allclose, across ragged n_valid (full, partial, zero
rows) and chained chunks at staggered resume points."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba2 as m2
from repro.models import rglru as rg
from repro.models.modules import AttnConfig, ModelConfig

W = 8
S = 4


def _family(name):
    if name == "mamba2":
        cfg = ModelConfig(n_layers=2, d_model=32, n_heads=1, n_kv=1, d_ff=0,
                          vocab=97, attn=AttnConfig(window=W, backend="full"))
        params = m2.mamba_init(jax.random.PRNGKey(0), cfg)
        states = m2.mamba_slot_states(cfg, S)
        return cfg, params, states, m2.mamba_prefill_chunk, \
            m2.mamba_prefill_chunk_seq
    cfg = ModelConfig(n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                      vocab=97,
                      attn=AttnConfig(window=W, k=W, backend="mita_ref"))
    params = rg.rg_init(jax.random.PRNGKey(0), cfg)
    states = rg.rg_slot_states(cfg, S, 64)
    return cfg, params, states, rg.rg_prefill_chunk, rg.rg_prefill_chunk_seq


def _assert_states_equal(st_a, st_b, msg):
    la, lb = jax.tree.leaves(st_a), jax.tree.leaves(st_b)
    assert len(la) == len(lb)
    for i, (a, b) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{msg} leaf {i}")


@pytest.mark.parametrize("family", ["mamba2", "rglru"])
@pytest.mark.parametrize("n_valid", [
    (16, 16, 16, 16),    # full chunk every row
    (16, 5, 0, 1),       # ragged tails + an untouched row
    (3, 16, 7, 0),
])
def test_chunk_parallel_matches_sequential(family, n_valid):
    """One chunk, then a second chained chunk from the produced state at
    shifted resume points: logits at live rows and every state leaf
    bit-identical between the chunk-parallel path and the sequential
    reference.  Rows with n_valid == 0 must leave state untouched in both
    (their logits are unspecified and excluded)."""
    nc = 16
    cfg, params, states, new_fn, seq_fn = _family(family)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (S, nc)), jnp.int32)
    t0 = jnp.asarray([0, W, 2 * W, 0], jnp.int32)
    nv = jnp.asarray(n_valid, jnp.int32)
    live = np.asarray(n_valid) > 0

    lg_n, st_n = new_fn(params, states, toks, t0, nv, cfg)
    lg_s, st_s = seq_fn(params, states, toks, t0, nv, cfg)
    np.testing.assert_array_equal(np.asarray(lg_n)[live],
                                  np.asarray(lg_s)[live], err_msg="logits")
    _assert_states_equal(st_n, st_s, "chunk 1")
    # zero-valid rows keep their incoming state bit-exactly
    if not live.all():
        dead = ~live
        for i, (a, b) in enumerate(zip(jax.tree.leaves(st_n),
                                       jax.tree.leaves(states))):
            a, b = np.asarray(a), np.asarray(b)
            if a.ndim >= 2 and a.shape[1] == S:     # [layers, S, ...] leaves
                np.testing.assert_array_equal(
                    a[:, dead], b[:, dead], err_msg=f"dead-row leaf {i}")

    lg_n2, st_n2 = new_fn(params, st_n, toks[:, ::-1], t0 + nv, nv, cfg)
    lg_s2, st_s2 = seq_fn(params, st_s, toks[:, ::-1], t0 + nv, nv, cfg)
    np.testing.assert_array_equal(np.asarray(lg_n2)[live],
                                  np.asarray(lg_s2)[live],
                                  err_msg="logits chunk 2")
    _assert_states_equal(st_n2, st_s2, "chunk 2")


@pytest.mark.parametrize("family", ["mamba2", "rglru"])
def test_chunk_size_invariance(family):
    """The same 32-token prompt admitted as 2×16 and as 4×8 chunks builds a
    bit-identical state on the chunk-parallel path — chunk-boundary
    invariance is what lets preemption recompute use a different chunking
    than the original admission."""
    cfg, params, states, new_fn, _ = _family(family)
    rng = np.random.default_rng(8)
    toks = np.asarray(rng.integers(0, cfg.vocab, (S, 32)), np.int32)

    def admit(chunk):
        st = states
        lg = None
        for c0 in range(0, 32, chunk):
            t0 = jnp.full((S,), c0, jnp.int32)
            nv = jnp.full((S,), chunk, jnp.int32)
            lg, st = new_fn(params, st,
                            jnp.asarray(toks[:, c0: c0 + chunk]), t0, nv,
                            cfg)
        return lg, st

    lg_a, st_a = admit(16)
    lg_b, st_b = admit(8)
    np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))
    _assert_states_equal(st_a, st_b, "chunk-size invariance")
