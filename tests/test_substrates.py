"""Optimizer / data pipeline / checkpoint / fault-tolerance unit tests."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.data import DataConfig, SyntheticLMStream, synthetic_batch
from repro.distributed.fault_tolerance import StepTimer, run_with_restarts
from repro.optim import (OptConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule)


# ---------------------------------------------------------------- optimizer

def test_adamw_matches_numpy_reference():
    cfg = OptConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.1,
                    clip_norm=1e9, warmup_steps=0, total_steps=10,
                    min_lr_ratio=1.0)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    state = adamw_init(p)
    new_p, state, metrics = adamw_update(g, state, p, cfg)

    w, gr = np.array(p["w"]), np.array(g["w"])
    mu = 0.1 * gr
    nu = 0.01 * gr * gr
    mhat = mu / (1 - 0.9)
    nhat = nu / (1 - 0.99)
    ref = w - 1e-2 * (mhat / (np.sqrt(nhat) + 1e-8) + 0.1 * w)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-6)


def test_cosine_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_ratio=0.1)
    s = [float(cosine_schedule(jnp.asarray(t), cfg)) for t in
         [0, 5, 10, 60, 110]]
    assert s[0] == 0.0 and abs(s[1] - 0.5) < 1e-6 and abs(s[2] - 1.0) < 1e-6
    assert s[2] > s[3] > s[4] >= 0.1 - 1e-6


@settings(max_examples=15, deadline=None)
@given(st.floats(0.1, 10.0), st.integers(0, 2**31 - 1))
def test_clip_by_global_norm_property(max_norm, seed):
    g = {"a": jax.random.normal(jax.random.PRNGKey(seed), (7,)) * 5}
    clipped, gn = clip_by_global_norm(g, max_norm)
    cn = float(jnp.linalg.norm(clipped["a"]))
    assert cn <= max_norm * (1 + 1e-5) or cn <= float(gn) + 1e-5


# --------------------------------------------------------------------- data

def test_data_deterministic_and_elastic_invariant():
    """Same (seed, step) -> same global batch, regardless of host count."""
    cfg1 = DataConfig(vocab=101, seq_len=32, global_batch=8, host_count=1)
    full = synthetic_batch(cfg1, step=5)["tokens"]
    parts = []
    for hi in range(4):
        cfg4 = DataConfig(vocab=101, seq_len=32, global_batch=8,
                          host_index=hi, host_count=4)
        parts.append(synthetic_batch(cfg4, step=5)["tokens"])
    np.testing.assert_array_equal(full, np.concatenate(parts, axis=0))
    # different steps differ
    assert not np.array_equal(full, synthetic_batch(cfg1, step=6)["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=101, seq_len=32, global_batch=2)
    b = synthetic_batch(cfg, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_stream_prefetch_and_resume():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=2)
    s = SyntheticLMStream(cfg, start_step=3)
    step, batch = next(s)
    assert step == 3
    np.testing.assert_array_equal(batch["tokens"],
                                  synthetic_batch(cfg, 3)["tokens"])
    s.close()


# --------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "t": (jnp.zeros((2,)), jnp.asarray(3))}
    save_checkpoint(str(tmp_path), 7, tree)
    step, restored = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_manager_prunes_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((3,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.ones((3,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(str(tmp_path), {"w": jnp.ones((4,))})


# ---------------------------------------------------------- fault tolerance

def test_step_timer_straggler_detection():
    t = StepTimer(alpha=0.5, threshold=2.0)
    for _ in range(5):
        t.observe(0.1)
    assert not t.is_straggling
    t.observe(1.0)
    assert t.is_straggling


def test_run_with_restarts_recovers(tmp_path):
    """A step that fails once is replayed identically after restore."""
    mgr = CheckpointManager(str(tmp_path))
    failures = {"armed": True}

    def step_fn(step, state):
        if step == 7 and failures["armed"]:
            failures["armed"] = False
            raise RuntimeError("simulated preemption")
        return {"acc": state["acc"] + step}

    out = run_with_restarts(step_fn, {"acc": jnp.asarray(0)}, mgr,
                            n_steps=10, ckpt_every=2)
    assert int(out["acc"]) == sum(range(10))


def test_run_with_restarts_gives_up(tmp_path):
    mgr = CheckpointManager(str(tmp_path))

    def bad_step(step, state):
        raise RuntimeError("hard failure")

    with pytest.raises(RuntimeError, match="hard failure"):
        run_with_restarts(bad_step, {}, mgr, n_steps=3, max_restarts=2)
