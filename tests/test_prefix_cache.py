"""Prefix-cache parity + page-lifecycle suite (PR 6 tentpole).

Contract layers:
  * cache: the radix trie's physical-match insert walk and leaf-only LRU
    eviction preserve the path invariant (a node's rows only reference
    pages on its own root-anchored path) and exact ref-counting;
  * engine: cache-hit requests emit BIT-identical greedy tokens to a
    cold-cache run (chunk-quantized skip keeps every remaining dispatch's
    reduction order equal to the cold schedule's), retiring or preempting
    one sharer never frees or mutates a page another sharer still reads,
    and pressure reclaims cached pages (LRU leaves) before touching live
    work;
  * backend: an attached prefix plus the recomputed tail reproduce the
    cold engine's landmark/expert/pool state bit-exactly (the COW tail
    page is a fresh allocation whose contents the resumed chunk program
    rebuilds).
"""

import numpy as np
import jax

from repro.models import transformer as tfm
from repro.models.modules import AttnConfig, ModelConfig
from repro.serve import EngineConfig, Request, ServingEngine
from repro.serve.engine import _PageAllocator
from repro.serve.prefix_cache import RadixPrefixCache

W, K = 8, 8


def _cfg():
    return ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                       vocab=97,
                       attn=AttnConfig(window=W, k=K, backend="mita_ref",
                                       external_finalize=False))


def _params():
    return tfm.lm_init(jax.random.PRNGKey(0), _cfg())


def _shared_trace(n_req, shared_w=4, tail_w=2, gen=6, seed=0):
    """Requests sharing a `shared_w`-window system prompt + unique tails."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, 97, size=shared_w * W).astype(np.int32)
    return [Request(rid=i,
                    prompt=np.concatenate([
                        sys_prompt,
                        rng.integers(0, 97,
                                     size=tail_w * W).astype(np.int32)]),
                    max_new_tokens=gen)
            for i in range(n_req)]


def _ecfg(cache=True, **kw):
    base = dict(n_slots=3, pages_per_slot=8, n_pages=40,
                prefill_chunk=2 * W, prefix_cache=cache)
    base.update(kw)
    return EngineConfig(**base)


# -------------------------------------------------------------------- trie --

def test_radix_trie_insert_match_refcounts():
    al = _PageAllocator(16)
    cache = RadixPrefixCache(al, W)
    toks = np.arange(4 * W, dtype=np.int32)
    pages = al.alloc(4)
    payloads = [f"w{i}" for i in range(4)]
    added = cache.insert(toks, 4, pages, lambda: payloads)
    assert added == 4 and cache.n_pages == 4
    assert all(al.refcount(p) == 2 for p in pages)   # holder + trie
    # full and partial matches walk the path in window order
    nodes = cache.match(toks, 4)
    assert [nd.page for nd in nodes] == pages
    assert [nd.payload for nd in nodes] == payloads
    assert [nd.page for nd in cache.match(toks, 2)] == pages[:2]
    other = toks.copy()
    other[W] += 1                                    # diverge in window 1
    assert [nd.page for nd in cache.match(other, 4)] == pages[:1]
    # releasing the original holder keeps trie-held pages alive
    al.release(pages)
    assert al.in_use == 4 and not set(pages) & set(al.free)


def test_radix_trie_physical_divergence_stops_insert():
    """A duplicate prefill (same tokens, different pages) must not graft
    its pages under the incumbent path — nodes below a physical mismatch
    would reference pages not on their own path."""
    al = _PageAllocator(16)
    cache = RadixPrefixCache(al, W)
    toks = np.arange(3 * W, dtype=np.int32)
    first = al.alloc(3)
    cache.insert(toks, 3, first, lambda: list("abc"))
    dup = al.alloc(3)
    calls = []
    added = cache.insert(toks, 3, dup, lambda: calls.append(1) or list("xyz"))
    assert added == 0 and not calls, \
        "divergent insert added nodes or snapshotted needlessly"
    assert all(al.refcount(p) == 1 for p in dup)
    # extending the INCUMBENT path with fresh pages is fine
    ext = np.concatenate([toks, np.full(W, 90, np.int32)])
    tail = al.alloc(1)
    assert cache.insert(ext, 4, first + tail, lambda: list("abcd")) == 1
    assert [nd.page for nd in cache.match(ext, 4)] == first + tail


def test_radix_trie_evicts_lru_leaf_only():
    al = _PageAllocator(16)
    cache = RadixPrefixCache(al, W)
    toks = np.arange(3 * W, dtype=np.int32)
    pages = al.alloc(3)
    cache.insert(toks, 3, pages, lambda: list("abc"))
    al.release(pages)                    # trie is now the only holder
    assert cache.evict_one()
    # deepest node (the only leaf) went first, its page freed
    assert pages[2] in al.free and pages[1] not in al.free
    assert [nd.page for nd in cache.match(toks, 3)] == pages[:2]
    assert cache.evict_one() and cache.evict_one()
    assert not cache.evict_one() and al.in_use == 0
    assert cache.evictions == 3


# ------------------------------------------------------------------ engine --

def test_prefix_hits_bit_parity_with_cold_engine():
    """Warm engine (prefix cache on) vs cold engine on a shared-prefix
    trace: every request's greedy tokens are bit-identical, the warm run
    records hits and shared pages, and after the trace only trie
    references keep pages in use."""
    params = _params()
    reqs = _shared_trace(6)
    cold = ServingEngine(params, _cfg(), _ecfg(cache=False))
    warm = ServingEngine(params, _cfg(), _ecfg(cache=True))
    tok_c = {f.rid: f.tokens for f in cold.run(_shared_trace(6))}
    tok_w = {f.rid: f.tokens for f in warm.run(reqs)}
    for r in reqs:
        np.testing.assert_array_equal(tok_w[r.rid], tok_c[r.rid],
                                      err_msg=f"req {r.rid}")
    st = warm.stats()
    assert st["prefix_cache_hits"] > 0 and st["pages_shared"] > 0
    assert st["prefix_tokens_reused"] >= st["prefix_cache_hits"] * 2 * W
    assert warm.prefix_hits, "per-request hit sizes not recorded"
    # drained engine: the only remaining references are the trie's
    assert warm.alloc.in_use == warm.cache.n_pages
    assert all(c == 1 for c in warm.alloc.refs.values())


def test_retiring_one_sharer_keeps_pages_for_the_other():
    """Two concurrent sharers of one cached prefix: the short one retires
    first, and the pages it shared must stay live (and unmutated — pinned
    by token parity) for the survivor."""
    params = _params()
    seed_req = _shared_trace(1, gen=2, seed=3)
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(0, 97, size=4 * W).astype(np.int32)
    tails = [rng.integers(0, 97, size=2 * W).astype(np.int32)
             for _ in range(3)]

    def pair(gen_a, gen_b):
        return [Request(rid=10, prompt=np.concatenate([sys_prompt, tails[1]]),
                        max_new_tokens=gen_a),
                Request(rid=11, prompt=np.concatenate([sys_prompt, tails[2]]),
                        max_new_tokens=gen_b)]

    cold = ServingEngine(params, _cfg(), _ecfg(cache=False))
    ref = {f.rid: f.tokens for f in cold.run(pair(2, 14))}

    warm = ServingEngine(params, _cfg(), _ecfg(cache=True))
    warm.run(seed_req)                       # populate the cache
    for r in pair(2, 14):
        warm.submit(r)
    shared = [nd.page for nd in warm.cache.match(sys_prompt, 4)]
    assert len(shared) == 4
    retired_early = False
    while warm.step():
        done = {f.rid for f in warm.finished}
        if 10 in done and 11 not in done:
            retired_early = True
            # rid 11 still reads the shared pages: none may be free
            assert not set(shared) & set(warm.alloc.free)
            assert all(warm.alloc.refcount(p) >= 2 for p in shared), \
                "sharer's pages dropped to trie-only while still read"
    assert retired_early, "scenario never had one sharer outlive the other"
    out = {f.rid: f.tokens for f in warm.finished if f.rid in (10, 11)}
    np.testing.assert_array_equal(out[10], ref[10])
    np.testing.assert_array_equal(out[11], ref[11])


def test_preempting_one_sharer_keeps_the_other_exact():
    """Tight pool: a high-priority burst preempts one sharer mid-decode.
    The victim's release must only drop ITS references — the surviving
    sharer and the trie keep the prefix pages, and every request still
    matches the cold run bit-exactly."""
    params = _params()
    ecfg_kw = dict(n_slots=2, pages_per_slot=8, n_pages=18)
    reqs = _shared_trace(2, shared_w=3, tail_w=1, gen=20, seed=5)
    hp = [Request(rid=100 + i,
                  prompt=np.random.default_rng(7 + i).integers(
                      0, 97, size=2 * W).astype(np.int32),
                  max_new_tokens=16, priority=5) for i in range(2)]

    cold = ServingEngine(params, _cfg(), _ecfg(cache=False, **ecfg_kw))
    cold.run(_shared_trace(2, shared_w=3, tail_w=1, gen=20, seed=5))
    for r in hp:
        cold.submit(Request(rid=r.rid, prompt=r.prompt.copy(),
                            max_new_tokens=16, priority=5))
    while cold.step():
        pass
    ref = {f.rid: f.tokens for f in cold.finished}

    warm = ServingEngine(params, _cfg(), _ecfg(cache=True, **ecfg_kw))
    for r in reqs:
        warm.submit(r)
    for _ in range(8):
        warm.step()
    for r in hp:
        warm.submit(r)
    while warm.step():
        owned = [p for pages in warm.slot_pages.values() for p in pages]
        assert not set(owned) & set(warm.alloc.free), "owned page freed"
    assert warm.n_preemptions >= 1, "scenario no longer preempts"
    out = {f.rid: f.tokens for f in warm.finished}
    for rid in ref:
        np.testing.assert_array_equal(out[rid], ref[rid],
                                      err_msg=f"req {rid}")


def test_cow_tail_state_matches_cold_engine_bit_exact():
    """The COW contract at the state level: a cache-hit request's
    landmark/expert/q_sum rows AND its pool pages (shared prefix + the
    freshly-recomputed tail page) are bit-identical to a cold engine's
    after its own full prefill — modulo the physical page ids, which the
    page tables translate."""
    params = _params()
    rng = np.random.default_rng(11)
    sys_prompt = rng.integers(0, 97, size=4 * W).astype(np.int32)
    tail_a = rng.integers(0, 97, size=2 * W).astype(np.int32)
    tail_b = rng.integers(0, 97, size=2 * W).astype(np.int32)
    req_b = lambda: Request(rid=1, prompt=np.concatenate(  # noqa: E731
        [sys_prompt, tail_b]), max_new_tokens=4)

    def drive_until_active(eng, rid):
        for _ in range(64):
            eng.step()
            if any(r.rid == rid for r in eng.slot_req.values()):
                slot = next(s for s, r in eng.slot_req.items()
                            if r.rid == rid)
                return slot
        raise AssertionError("request never reached the decode batch")

    warm = ServingEngine(params, _cfg(), _ecfg(cache=True))
    warm.run([Request(rid=0, prompt=np.concatenate([sys_prompt, tail_a]),
                      max_new_tokens=2)])     # seed the cache
    warm.submit(req_b())
    slot_w = drive_until_active(warm, 1)
    assert warm.prefix_hits.get(1, 0) == 4 * W, "hit did not cover 4 windows"

    cold = ServingEngine(params, _cfg(), _ecfg(cache=False))
    cold.submit(req_b())
    slot_c = drive_until_active(cold, 1)

    st_w, st_c = warm.backend.states, cold.backend.states
    m = 6 * W // W
    for f in ("lm_q", "lm_v", "pre_lm_q"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_w, f))[:, slot_w, :, :m],
            np.asarray(getattr(st_c, f))[:, slot_c, :, :m], err_msg=f)
    np.testing.assert_array_equal(np.asarray(st_w.q_sum)[:, slot_w],
                                  np.asarray(st_c.q_sum)[:, slot_c])
    # expert rows are GLOBAL pool rows — translate through each page table
    pt_w = np.asarray(warm.slot_pages[slot_w])
    pt_c = np.asarray(cold.slot_pages[slot_c])
    inv_w = {int(p): i for i, p in enumerate(pt_w)}
    inv_c = {int(p): i for i, p in enumerate(pt_c)}
    ev_w = np.asarray(st_w.expert_valid)[:, slot_w, :, :m]
    ev_c = np.asarray(st_c.expert_valid)[:, slot_c, :, :m]
    np.testing.assert_array_equal(ev_w, ev_c)
    ei_w = np.asarray(st_w.expert_idx)[:, slot_w, :, :m]
    ei_c = np.asarray(st_c.expert_idx)[:, slot_c, :, :m]
    # invalid rows hold arbitrary pool indices — mask them before
    # translating through the (different) physical page tables
    trans = np.vectorize(lambda g, inv: inv.get(g // W, -1) * W + g % W,
                         excluded=[1])
    log_w = np.where(ev_w, trans(ei_w, inv_w), -1)
    log_c = np.where(ev_c, trans(ei_c, inv_c), -1)
    np.testing.assert_array_equal(log_w, log_c)
    # pool rows: shared prefix pages AND the recomputed tail pages hold
    # bit-identical K/V — the "copy" in copy-on-write is an exact rebuild
    kp_w, kp_c = np.asarray(st_w.k_pool), np.asarray(st_c.k_pool)
    vp_w, vp_c = np.asarray(st_w.v_pool), np.asarray(st_c.v_pool)
    for c in range(6 * W):
        rw = pt_w[c // W] * W + c % W
        rc = pt_c[c // W] * W + c % W
        np.testing.assert_array_equal(kp_w[:, rw], kp_c[:, rc],
                                      err_msg=f"k_pool tok {c}")
        np.testing.assert_array_equal(vp_w[:, rw], vp_c[:, rc],
                                      err_msg=f"v_pool tok {c}")
    # and the COW structure is physical: the prefix pages ARE the seed's
    # trie pages (attached by reference), while the tail windows landed in
    # fresh pages the seed never owned
    seed_path = [nd.page for nd in warm.cache.match(
        np.concatenate([sys_prompt, tail_a]), 6)]
    assert [int(p) for p in pt_w[:4]] == seed_path[:4]
    assert not {int(pt_w[4]), int(pt_w[5])} & set(seed_path)
    assert all(warm.alloc.refcount(int(p)) >= 2 for p in pt_w[:4])


def test_cache_pages_reclaimed_under_pressure_before_preemption():
    """A pool sized so new admissions need the cache's pages: the engine
    must evict LRU cache leaves (never preempting live work) and keep
    serving correctly."""
    params = _params()
    ecfg_kw = dict(n_slots=2, pages_per_slot=6, n_pages=13)
    trace = [_shared_trace(1, shared_w=3, tail_w=1, gen=4, seed=s)[0]
             for s in range(4)]
    for i, r in enumerate(trace):
        r.rid = i                        # distinct prompts, distinct rids
    cold = ServingEngine(params, _cfg(), _ecfg(cache=False, **ecfg_kw))
    ref = {f.rid: f.tokens for f in cold.run(
        [Request(rid=r.rid, prompt=r.prompt.copy(), max_new_tokens=4)
         for r in trace])}
    warm = ServingEngine(params, _cfg(), _ecfg(cache=True, **ecfg_kw))
    done = warm.run(trace)
    st = warm.stats()
    assert st["prefix_cache_evictions"] > 0, \
        "scenario never pressured the cache"
    assert st["preemptions"] == 0, "pressure hit live work before the cache"
    for f in done:
        np.testing.assert_array_equal(f.tokens, ref[f.rid],
                                      err_msg=f"req {f.rid}")


def test_nonaligned_prompts_never_match_or_insert():
    """Prompts whose length is not window-aligned train their summaries on
    a different grid — they must be pure cache misses and never populate
    the trie."""
    params = _params()
    rng = np.random.default_rng(21)
    # 4W+4 = 36: chunk-servable (36 % (36 // 8) == 0) but NOT aligned —
    # its summary grid differs from the aligned one, so no cache traffic
    prompt = rng.integers(0, 97, size=4 * W + 4).astype(np.int32)
    warm = ServingEngine(params, _cfg(), _ecfg(cache=True))
    cold = ServingEngine(params, _cfg(), _ecfg(cache=False))
    for eng in (warm, cold):
        eng.run([Request(rid=i, prompt=prompt.copy(), max_new_tokens=4)
                 for i in range(2)])
    assert warm.cache.n_nodes == 0
    st = warm.stats()
    assert st["prefix_cache_hits"] == 0 and st["pages_shared"] == 0
    tok_w = {f.rid: f.tokens for f in warm.finished}
    tok_c = {f.rid: f.tokens for f in cold.finished}
    for rid in tok_c:
        np.testing.assert_array_equal(tok_w[rid], tok_c[rid])


def test_cancel_hit_request_releases_only_its_refs():
    """Cancelling a cache-hit request mid-decode drops the slot's
    references but leaves the trie's — the prefix stays warm for the next
    arrival, and accounting balances."""
    params = _params()
    warm = ServingEngine(params, _cfg(), _ecfg(cache=True))
    warm.run(_shared_trace(1, gen=2))
    trie_pages = warm.cache.n_pages
    r = _shared_trace(2, gen=14)[1]
    warm.submit(r)
    for _ in range(8):
        warm.step()
    assert warm.prefix_hits.get(r.rid, 0) > 0, "second request missed"
    assert warm.cancel(r.rid)
    # its own tail windows joined the trie at prefill commit, but the
    # slot's references are gone: only trie refs remain, all singular
    assert warm.alloc.in_use == warm.cache.n_pages >= trie_pages
    assert all(c == 1 for c in warm.alloc.refs.values())
    assert not warm.step()
