"""Per-kernel allclose vs ref.py oracles: shape/dtype sweeps, interpret=True."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention
from repro.kernels.mita_expert_attn import mita_expert_attention
from repro.kernels.ops import routed_expert_partial
from repro.kernels.ref import flash_attention_ref, mita_expert_attention_ref

RNG = jax.random.PRNGKey(7)


@pytest.mark.parametrize("b,h,n,d", [(2, 3, 256, 64), (1, 2, 128, 128),
                                     (1, 1, 512, 32), (2, 1, 64, 16)])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, n, d, causal, dtype):
    ks = jax.random.split(jax.random.fold_in(RNG, n * d + causal), 3)
    q, k, v = (jax.random.normal(kk, (b, h, n, d), dtype) for kk in ks)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    ref = flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=causal)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=atol, rtol=atol)


def test_flash_attention_cross_lengths():
    """n_q != n_kv (cross-attention shape)."""
    q = jax.random.normal(RNG, (1, 2, 128, 32))
    k = jax.random.normal(jax.random.fold_in(RNG, 1), (1, 2, 256, 32))
    v = jax.random.normal(jax.random.fold_in(RNG, 2), (1, 2, 256, 32))
    out = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("b,h,ns,d,m,kw,bq", [
    (2, 2, 128, 32, 8, 16, 32),
    (1, 3, 256, 64, 16, 32, 64),
    (1, 1, 64, 16, 4, 8, 64),
    (1, 1, 128, 128, 2, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mita_expert_kernel_sweep(b, h, ns, d, m, kw, bq, dtype):
    ks = jax.random.split(jax.random.fold_in(RNG, ns * m), 5)
    q = jax.random.normal(ks[0], (b, h, ns, d), dtype)
    assign = jnp.sort(jax.random.randint(ks[1], (b, h, ns), 0, m + 1), -1)
    ke = jax.random.normal(ks[2], (b, h, m, kw, d), dtype)
    ve = jax.random.normal(ks[3], (b, h, m, kw, d), dtype)
    valid = jax.random.bernoulli(ks[4], 0.9, (b, h, m, kw))
    o, ms, l = mita_expert_attention(q, assign, ke, ve, valid,
                                     block_q=bq, interpret=True)
    oref, msref, lref = mita_expert_attention_ref(
        q.astype(jnp.float32), assign, ke.astype(jnp.float32),
        ve.astype(jnp.float32), valid)
    act = np.asarray(l) > 0
    assert np.allclose(act, np.asarray(lref) > 0)
    on = np.asarray(o, np.float32) / np.maximum(np.asarray(l)[..., None], 1e-30)
    orn = np.asarray(oref) / np.maximum(np.asarray(lref)[..., None], 1e-30)
    atol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(on * act[..., None], orn * act[..., None],
                               atol=atol, rtol=atol)
    np.testing.assert_allclose(np.asarray(ms) * act, np.asarray(msref) * act,
                               atol=atol, rtol=atol)


def test_ops_wrapper_broadcast_leads():
    """routed_expert_partial accepts GQA-style broadcast kv leads."""
    b, hkv, g, ns, d, m, kw = 1, 2, 3, 64, 16, 4, 8
    ks = jax.random.split(RNG, 5)
    q = jax.random.normal(ks[0], (b, hkv, g, ns, d))
    a = jnp.sort(jax.random.randint(ks[1], (b, hkv, g, ns), 0, m), -1)
    ke = jax.random.normal(ks[2], (b, hkv, 1, m, kw, d))
    ve = jax.random.normal(ks[3], (b, hkv, 1, m, kw, d))
    valid = jnp.ones((b, hkv, 1, m, kw), bool)
    o, ms, l = routed_expert_partial(q, a, ke, ve, valid, block_q=32,
                                     interpret=True)
    assert o.shape == (b, hkv, g, ns, d)
    keb = jnp.broadcast_to(ke, (b, hkv, g, m, kw, d)).reshape(
        b, hkv * g, m, kw, d)
    veb = jnp.broadcast_to(ve, (b, hkv, g, m, kw, d)).reshape(
        b, hkv * g, m, kw, d)
    vab = jnp.broadcast_to(valid, (b, hkv, g, m, kw)).reshape(
        b, hkv * g, m, kw)
    oref, msref, lref = mita_expert_attention_ref(
        q.reshape(b, hkv * g, ns, d), a.reshape(b, hkv * g, ns),
        keb, veb, vab)
    np.testing.assert_allclose(np.asarray(o).reshape(b, hkv * g, ns, d),
                               np.asarray(oref), atol=3e-5)
