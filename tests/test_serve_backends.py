"""Backend-agnostic serving core: the `DecodeBackend` protocol.

Contract layers:
  * scheduler: `serve/engine.py` contains NO backend-specific types or
    branches — it talks only to the protocol (pinned by a source grep);
  * recurrent backends (Mamba2 SSD, RG-LRU hybrid): engine greedy tokens
    are bit-identical to each backend's static/full-forward reference —
    chunked and monolithic admission, slot reuse, fused sampling, AND
    recompute-from-prompt preemption (the victim re-emits identical
    tokens);
  * scheduler regressions re-run under a recurrent backend: the
    equal-priority livelock scenario must still converge;
  * observability: chunk-prefill kernel→XLA VMEM fallbacks are counted
    (`kernels.ops.prefill_kernel_fallbacks`) and warned once, and
    `stats()` reports per-backend dispatch counts.
"""

import dataclasses
import inspect
import warnings

import jax
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import mamba2 as m2
from repro.models import rglru as rglru_mod
from repro.models.modules import AttnConfig, ModelConfig
from repro.serve import EngineConfig, Request, ServingEngine, backends
from repro.serve.backends.recurrent import Mamba2Backend, RGLRUBackend

W = 8


def _mamba_cfg():
    # d_model=32 -> d_inner=64 -> one 64-dim SSD head; attn unused except
    # as the window/page quantum
    return ModelConfig(n_layers=2, d_model=32, n_heads=1, n_kv=1, d_ff=0,
                       vocab=97, attn=AttnConfig(window=W, backend="full"))


def _rg_cfg():
    # one (RG-LRU, RG-LRU, attention) super-block with MiTA attention
    return ModelConfig(n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                       vocab=97,
                       attn=AttnConfig(window=W, k=W, backend="mita_ref"))


def _setup(family):
    if family == "mamba2":
        cfg = _mamba_cfg()
        params = m2.mamba_init(jax.random.PRNGKey(0), cfg)
        mk = Mamba2Backend
    else:
        cfg = _rg_cfg()
        params = rglru_mod.rg_init(jax.random.PRNGKey(0), cfg)
        mk = RGLRUBackend
    return cfg, params, lambda ecfg: mk(params, cfg, ecfg)


def _engine(cfg, params, mk, ecfg):
    return ServingEngine(params, cfg, ecfg, backend=mk(ecfg))


def _requests(vocab, n, lens, gens, seed=7, temperature=0.0):
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(21)
    reqs = []
    for i in range(n):
        ln = int(rng.choice(lens))
        p = np.asarray(jax.random.randint(jax.random.fold_in(key, i), (ln,),
                                          0, vocab))
        reqs.append(Request(rid=i, prompt=p,
                            max_new_tokens=int(rng.choice(gens)),
                            temperature=temperature))
    return reqs


# ---------------------------------------------------------- scheduler core --

def test_engine_module_is_backend_agnostic():
    """The acceptance grep: the scheduler has no backend-specific types or
    branches — every device-side operation goes through the protocol."""
    import repro.serve.engine as eng
    src = inspect.getsource(eng)
    assert "PagedMiTAState" not in src
    assert "mita" not in src


@pytest.mark.parametrize("family", ["mamba2", "rglru"])
def test_prefix_cache_silently_off_for_recurrent_backends(family):
    """`prefix_cache=True` on a backend that doesn't advertise
    `supports_prefix_cache` (constant-size recurrent state has no pages to
    share) must be a silent no-op: no cache is built, stats report zeros,
    and repeated prompts still match the static reference exactly."""
    cfg, params, mk = _setup(family)
    ecfg = EngineConfig(n_slots=2, pages_per_slot=5, n_pages=12,
                        prefill_chunk=W, prefix_cache=True)
    eng = _engine(cfg, params, mk, ecfg)
    assert eng.cache is None
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(11), (2 * W,),
                                           0, cfg.vocab))
    done = eng.run([Request(rid=i, prompt=prompt.copy(), max_new_tokens=5)
                    for i in range(3)])
    ref = mk(ecfg).static_reference(prompt[None], 5)
    for f in done:
        np.testing.assert_array_equal(f.tokens, ref[0],
                                      err_msg=f"{family} req {f.rid}")
    st = eng.stats()
    assert st["prefix_cache_hits"] == 0 and st["pages_shared"] == 0
    assert st["prefix_cache_pages"] == 0 and st["prefix_tokens_reused"] == 0


def test_resolve_requires_explicit_backend_for_recurrent():
    cfg = _mamba_cfg()
    params = m2.mamba_init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="MiTA"):
        ServingEngine(params, cfg, EngineConfig())


def test_for_arch_rejects_encdec():
    from repro.configs.registry import get_arch
    arch = get_arch("whisper-tiny", smoke=True)
    with pytest.raises(ValueError, match="family"):
        backends.for_arch(arch, {}, EngineConfig())


# ----------------------------------------------------- greedy bit-parity ---

@pytest.mark.parametrize("family", ["mamba2", "rglru"])
def test_engine_chunked_matches_reference(family):
    """Chunked admission through the recurrent backend: every request's
    greedy tokens == the backend's static reference (time-major full-prompt
    scan + single-token decode), with slot reuse mid-trace, and stats()
    reports the backend's dispatch counts."""
    cfg, params, mk = _setup(family)
    reqs = _requests(cfg.vocab, 6, lens=[W, 2 * W, 3 * W], gens=[2, 5, 9])
    ecfg = EngineConfig(n_slots=3, pages_per_slot=5, n_pages=12,
                        prefill_chunk=W)
    eng = _engine(cfg, params, mk, ecfg)
    done = eng.run(reqs)
    assert len(done) == len(reqs)
    ref_backend = mk(ecfg)
    for f, r in zip(done, reqs):
        ref = ref_backend.static_reference(r.prompt[None], r.max_new_tokens)
        np.testing.assert_array_equal(f.tokens, ref[0],
                                      err_msg=f"{family} req {f.rid}")
    st = eng.stats()
    assert st["backend"] == family
    assert st["decode_dispatches"] == eng.steps
    assert st["chunks"] >= sum(-(-len(r.prompt) // W) for r in reqs) - 1


@pytest.mark.parametrize("family", ["mamba2", "rglru"])
def test_engine_monolithic_matches_reference(family):
    """Unchunked (grouped) admission rides the backend's `prefill_group`
    path; tokens still match the reference, and fused on-device sampling
    is bit-identical to host sampling under mixed temperatures."""
    cfg, params, mk = _setup(family)
    reqs = _requests(cfg.vocab, 4, lens=[2 * W], gens=[6])
    for r in reqs[::2]:
        r.temperature = 0.8
    ecfg = EngineConfig(n_slots=2, pages_per_slot=5, n_pages=12)
    host = _engine(cfg, params, mk, ecfg).run(reqs)
    fused = _engine(cfg, params, mk, dataclasses.replace(
        ecfg, sample_device="fused")).run(reqs)
    ref_backend = mk(ecfg)
    for h, f, r in zip(host, fused, reqs):
        np.testing.assert_array_equal(h.tokens, f.tokens,
                                      err_msg=f"{family} host!=fused "
                                              f"req {h.rid}")
        if r.temperature == 0.0:
            ref = ref_backend.static_reference(r.prompt[None],
                                               r.max_new_tokens)
            np.testing.assert_array_equal(h.tokens, ref[0],
                                          err_msg=f"{family} req {h.rid}")
        else:
            ref = ref_backend.static_reference(
                r.prompt[None], r.max_new_tokens,
                temperature=r.temperature, rids=[r.rid])
            np.testing.assert_array_equal(h.tokens, ref[0],
                                          err_msg=f"{family} tempered "
                                                  f"req {h.rid}")


# ----------------------------------------------------------- preemption ----

@pytest.mark.parametrize("family", ["mamba2", "rglru"])
def test_preemption_recompute_bit_parity(family):
    """A low-priority victim evicted mid-decode by high-priority arrivals
    is rebuilt by re-scanning prompt + generated-so-far through the chunk
    program — the constant-size state recompute is exact, so the victim
    re-emits identical greedy tokens."""
    cfg, params, mk = _setup(family)
    N, gen = 2 * W, 20
    victim = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (N,),
                                           0, cfg.vocab))
    ecfg = EngineConfig(n_slots=2, pages_per_slot=6, n_pages=8,
                        prefill_chunk=2 * W)
    ref = _engine(cfg, params, mk, ecfg).run(
        [Request(rid=0, prompt=victim, max_new_tokens=gen)])[0].tokens

    eng = _engine(cfg, params, mk, ecfg)
    eng.submit(Request(rid=0, prompt=victim, max_new_tokens=gen, priority=0))
    for _ in range(6):
        eng.step()
    hp = jax.random.randint(jax.random.PRNGKey(5), (2, 2 * W), 0, cfg.vocab)
    eng.submit(Request(rid=1, prompt=np.asarray(hp[0]), max_new_tokens=20,
                       priority=5))
    eng.submit(Request(rid=2, prompt=np.asarray(hp[1]), max_new_tokens=20,
                       priority=5))
    while eng.step():
        owned = [p for pages in eng.slot_pages.values() for p in pages]
        assert len(owned) == len(set(owned)), "page double-booked"
        assert len(owned) + len(eng.alloc.free) == ecfg.n_pages, "page leak"
    done = sorted(eng.finished, key=lambda f: f.rid)
    assert len(done) == 3
    assert eng.n_preemptions >= 1, "scenario no longer triggers preemption"
    assert done[0].preemptions >= 1
    np.testing.assert_array_equal(done[0].tokens, ref)


def test_equal_priority_jobs_never_livelock_recurrent():
    """The PR-2 livelock regression re-run under the mamba2 backend: two
    equal-priority long prompts whose chunked prefills together exceed the
    pool must converge via the strict (priority, seniority) order."""
    cfg, params, mk = _setup("mamba2")
    N = 8 * W
    prompts = jax.random.randint(jax.random.PRNGKey(13), (2, N), 0,
                                 cfg.vocab)
    eng = _engine(cfg, params, mk, EngineConfig(
        n_slots=2, pages_per_slot=9, n_pages=9, prefill_chunk=2 * W))
    for i in range(2):
        eng.submit(Request(rid=i, prompt=np.asarray(prompts[i]),
                           max_new_tokens=1))
    for _ in range(400):
        if not eng.step():
            break
    else:
        raise AssertionError("engine livelocked: no progress in 400 steps")
    done = sorted(eng.finished, key=lambda f: f.rid)
    assert [f.rid for f in done] == [0, 1]
    assert all(len(f.tokens) == 1 for f in done)


# -------------------------------------------------------- observability ----

def test_prefill_kernel_fallback_counted_and_warned_once():
    """A VMEM-budget 'no' when the kernel was requested increments the
    process-wide fallback counter and warns exactly once; off-TPU auto
    mode (kernel never requested) does not count."""
    shapes = dict(nc=16, window=W, m=8, k_width=8, g=2, d=16)
    base = ops.prefill_kernel_fallbacks()
    ops._PREFILL_FALLBACK_WARNED = False
    with pytest.warns(RuntimeWarning, match="VMEM budget"):
        assert not ops.use_prefill_kernel("kernel", budget=1, **shapes)
    assert ops.prefill_kernel_fallbacks() == base + 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # further fallbacks stay silent
        assert not ops.use_prefill_kernel("kernel", budget=1, **shapes)
    assert ops.prefill_kernel_fallbacks() == base + 2
    if not ops.on_tpu():
        assert not ops.use_prefill_kernel("auto", budget=1, **shapes)
        assert ops.prefill_kernel_fallbacks() == base + 2
    # impl="xla" is a choice, not a fallback
    assert not ops.use_prefill_kernel("xla", budget=1, **shapes)
    assert ops.prefill_kernel_fallbacks() == base + 2


def test_stats_surface_fallback_counter():
    """The MiTA backend's `stats()["prefill_kernel_fallbacks"]` reports
    the delta since the backend was built; recurrent backends (which never
    dispatch the chunk-prefill kernel) always report 0 instead of
    inheriting another engine's process-global fallbacks."""
    mita_cfg = ModelConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=97,
        attn=AttnConfig(window=W, k=W, backend="mita_ref"))
    from repro.models import transformer as tfm
    mita_eng = ServingEngine(tfm.lm_init(jax.random.PRNGKey(0), mita_cfg),
                             mita_cfg, EngineConfig(
                                 n_slots=2, pages_per_slot=4, n_pages=8))
    cfg, params, mk = _setup("mamba2")
    rec_eng = _engine(cfg, params, mk, EngineConfig(
        n_slots=2, pages_per_slot=4, n_pages=8))
    assert mita_eng.stats()["prefill_kernel_fallbacks"] == 0
    ops._PREFILL_KERNEL_FALLBACKS += 3       # simulate trace-time fallbacks
    try:
        assert mita_eng.stats()["prefill_kernel_fallbacks"] == 3
        assert rec_eng.stats()["prefill_kernel_fallbacks"] == 0
    finally:
        ops._PREFILL_KERNEL_FALLBACKS -= 3


def test_mita_static_reference_tempered_matches_engine():
    """The MiTA backend's `static_reference` honours the protocol's
    tempered-oracle contract: (rid, index)-keyed sampling identical to the
    engine's, so tempered parity checks mean the same thing on every
    backend."""
    cfg = ModelConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=97,
        attn=AttnConfig(window=W, k=W, backend="mita_ref"))
    from repro.models import transformer as tfm
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(n_slots=2, pages_per_slot=4, n_pages=8)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (2 * W,),
                                           0, cfg.vocab))
    req = Request(rid=7, prompt=prompt, max_new_tokens=6, temperature=0.8)
    done = ServingEngine(params, cfg, ecfg).run([req])
    ref = backends.resolve(params, cfg, ecfg).static_reference(
        prompt[None], 6, temperature=0.8, rids=[7])
    np.testing.assert_array_equal(done[0].tokens, ref[0])
