"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (the brief's
required smoke coverage for all 10 assigned architectures)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch
from repro.data import DataConfig, synthetic_batch
from repro.launch.steps import family_fns
from repro.optim import OptConfig, adamw_init, adamw_update

B, SEQ = 2, 64


def _batch_for(arch):
    cfg = arch.model
    d = synthetic_batch(DataConfig(vocab=cfg.vocab, seq_len=SEQ,
                                   global_batch=B), 0)
    batch = {"tokens": jnp.asarray(d["tokens"]),
             "labels": jnp.asarray(d["labels"])}
    if arch.family == "vlm":
        batch["image_embeds"] = jnp.zeros((B, arch.n_img_tokens, cfg.d_model))
    if arch.family == "encdec":
        batch = {
            "audio_embeds": jax.random.normal(
                jax.random.PRNGKey(1), (B, arch.t_enc, cfg.d_model)),
            "tokens": batch["tokens"][:, : arch.dec_len],
            "labels": batch["labels"][:, : arch.dec_len],
        }
    return batch


@pytest.mark.parametrize("arch_id", ARCHS)
def test_arch_smoke_forward_and_train_step(arch_id):
    arch = get_arch(arch_id, smoke=True)
    fns = family_fns(arch)
    params = fns["init"](jax.random.PRNGKey(0))
    batch = _batch_for(arch)

    loss = fns["loss"](params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch_id} loss not finite"

    ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, b):
        l, g = jax.value_and_grad(fns["loss"])(p, b)
        p2, o2, m = adamw_update(g, o, p, ocfg)
        return p2, o2, l

    params2, opt2, l0 = step(params, opt, batch)
    leaves = jax.tree.leaves(params2)
    assert all(np.isfinite(np.asarray(x)).all() for x in leaves), \
        f"{arch_id} params not finite after a step"
    # params actually changed
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), leaves))
    assert changed, f"{arch_id} train step did not update params"


@pytest.mark.parametrize("arch_id", ["qwen3-0.6b", "deepseek-moe-16b",
                                     "mamba2-370m", "recurrentgemma-9b"])
def test_arch_smoke_decode_step(arch_id):
    """One decode step produces finite logits of the right shape."""
    arch = get_arch(arch_id, smoke=True)
    fns = family_fns(arch)
    params = fns["init"](jax.random.PRNGKey(0))
    states = fns["init_states"](B, 64)
    tok = jnp.zeros((B,), jnp.int32)
    logits, states2 = fns["decode"](params, states, tok, jnp.asarray(0))
    assert logits.shape == (B, arch.model.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyper-parameters."""
    spec = {
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "mamba2-370m": (48, 1024, None, None, None, 50280),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    }
    for arch_id, (l, d, h, kv, ff, v) in spec.items():
        m = get_arch(arch_id).model
        assert m.n_layers == l and m.d_model == d and m.vocab == v, arch_id
        if h is not None:
            assert m.n_heads == h and m.n_kv == kv and m.d_ff == ff, arch_id
    rg = get_arch("recurrentgemma-9b").model
    assert rg.d_model == 4096 and rg.n_kv == 1 and rg.d_ff == 12288
    ds = get_arch("deepseek-moe-16b").model
    assert ds.n_experts == 64 and ds.moe_top_k == 6 and ds.n_shared_experts == 2
    db = get_arch("dbrx-132b").model
    assert db.n_experts == 16 and db.moe_top_k == 4


def test_moe_capacity_dispatch_matches_dense_reference():
    """With ample capacity, the scatter-based MoE == dense per-token compute."""
    from repro.models.modules import ModelConfig
    from repro.models.moe import moe_apply, moe_init
    cfg = ModelConfig(d_model=32, d_ff=16, n_experts=4, moe_top_k=2,
                      n_shared_experts=0, moe_capacity_factor=8.0, vocab=7)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = moe_apply(params, x, cfg)

    # dense reference: every token through its top-k experts
    tokens = x.reshape(-1, 32)
    gates = jax.nn.softmax(tokens @ params["router"], axis=-1)
    w, idx = jax.lax.top_k(gates, 2)
    w = w / w.sum(-1, keepdims=True)
    ref = []
    for t in range(tokens.shape[0]):
        acc = 0
        for j in range(2):
            e = int(idx[t, j])
            h = jax.nn.silu(tokens[t] @ params["wg"][e]) * (tokens[t] @ params["wi"][e])
            acc += w[t, j] * (h @ params["wo"][e])
        ref.append(acc)
    ref = jnp.stack(ref).reshape(2, 16, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    assert np.isfinite(float(aux))
