"""Chunked prefill + priority preemption (PR 2).

Contract layers:
  * core: `mita_chunk_prefill` over the paged pool — chunk-by-chunk — must
    rebuild exactly the state `mita_prefill_state` builds monolithically
    (landmarks, expert rows, open-window q_sum), resume an open window
    across a non-aligned chunk boundary, and emit forward outputs equal to
    the training-path attention;
  * engine: chunked admission and recompute-from-prompt preemption must be
    invisible in the output — greedy tokens identical to the static
    baseline / the unpreempted run;
  * scheduler: priority ordering, allocator reserve/high-water accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import mita as mref
from repro.core import mita_decode as mdec
from repro.launch.serve import static_generate
from repro.models import transformer as tfm
from repro.models.modules import AttnConfig, ModelConfig
from repro.serve import EngineConfig, Request, ServingEngine
from repro.serve.engine import _PageAllocator

W, K = 8, 8


def _cfg(external=False):
    return ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                       vocab=97,
                       attn=AttnConfig(window=W, k=K, backend="mita_ref",
                                       external_finalize=external))


# ------------------------------------------------------------------- core --

def test_chunk_prefill_state_matches_monolithic():
    """Chunk-by-chunk prefill into shuffled pages == monolithic prefill:
    forward outputs, landmarks, expert rows (rebased), and q_sum."""
    Hkv, G, d, N, M = 2, 2, 16, 48, 8
    cfg = mdec.DecodeConfig(window=W, k=K, s=1)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, Hkv, G, N, d))
    k, v = (jax.random.normal(kk, (1, Hkv, 1, N, d))
            for kk in jax.random.split(key, 2))

    pre = mdec.mita_prefill_state(q, k, v, cfg, capacity=M * W)
    mcfg = mref.MiTAConfig(m=N // W, k=K, s=1, causal=True)
    out_ref = mref.mita_attention(
        q[0], k[0], v[0], mcfg,
        q_landmarks=jnp.mean(q[0], axis=1, keepdims=True))

    n_pages = M + 3
    table = np.random.default_rng(0).permutation(n_pages)[:M]
    pt = jnp.asarray(table, jnp.int32)
    st = mdec.init_paged_state(Hkv, d, n_pages, 2, M, cfg, jnp.float32)
    slot, chunk = 1, 16
    step = jax.jit(mdec.mita_chunk_prefill, static_argnames="cfg")
    outs = []
    for t0 in range(0, N, chunk):
        o, st = step(st, q[0, :, :, t0:t0 + chunk], k[0, :, 0, t0:t0 + chunk],
                     v[0, :, 0, t0:t0 + chunk], pt, slot, t0, chunk, N, cfg)
        outs.append(np.asarray(o))
    np.testing.assert_allclose(np.concatenate(outs, axis=2),
                               np.asarray(out_ref), atol=2e-5)

    m = N // W
    np.testing.assert_allclose(np.asarray(st.lm_q[slot][:, :m]),
                               np.asarray(pre.lm_q[0][:, :m]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st.lm_v[slot][:, :m]),
                               np.asarray(pre.lm_v[0][:, :m]), atol=2e-5)
    loc = np.asarray(pre.expert_idx[0][:, :m])
    np.testing.assert_array_equal(np.asarray(st.expert_idx[slot][:, :m]),
                                  table[loc // W] * W + loc % W)
    np.testing.assert_array_equal(np.asarray(st.expert_valid[slot][:, :m]),
                                  np.asarray(pre.expert_valid[0][:, :m]))
    np.testing.assert_allclose(np.asarray(st.q_sum[slot]),
                               np.asarray(pre.q_sum[0]), atol=2e-5)
    # KV rows landed at page_table[c // w] * w + c % w
    kpool = np.asarray(st.k_pool)
    for c in range(0, N, 7):
        np.testing.assert_allclose(kpool[table[c // W] * W + c % W],
                                   np.asarray(k[0, :, 0, c]), atol=1e-6)


def test_chunk_prefill_resumes_open_window():
    """A chunk starting mid-window (non-aligned t0, the preemption-recompute
    shape) resumes the packed q_sum and matches monolithic decode steps."""
    Hkv, G, d, M = 2, 2, 16, 8
    cfg = mdec.DecodeConfig(window=W, k=K, s=1)
    n_pre, n_tot = 20, 36
    q = jax.random.normal(jax.random.PRNGKey(7), (1, Hkv, G, n_tot, d))
    k, v = (jax.random.normal(kk, (1, Hkv, 1, n_tot, d))
            for kk in jax.random.split(jax.random.PRNGKey(8), 2))

    cap_pre = mdec.window_aligned(n_pre, W)
    pre = mdec.mita_prefill_state(q[:, :, :, :n_pre], k[:, :, :, :n_pre],
                                  v[:, :, :, :n_pre], cfg, capacity=cap_pre)
    ref = mdec.mita_prefill_state(q[:, :, :, :n_pre], k[:, :, :, :n_pre],
                                  v[:, :, :, :n_pre], cfg, capacity=M * W)
    step_m = jax.jit(lambda s, *a: mdec.mita_decode_step(s, *a, cfg))
    for i in range(n_pre, n_tot):
        _, ref = step_m(ref, q[:, :, :, i], k[:, :, 0, i], v[:, :, 0, i])

    n_pages = M + 2
    table = np.random.default_rng(1).permutation(n_pages)[:M]
    pt = jnp.asarray(table, jnp.int32)
    st = mdec.init_paged_state(Hkv, d, n_pages, 1, M, cfg, jnp.float32)
    st = mdec.pack_prefill_into_pages(st, pre, 0, pt[: cap_pre // W], cfg)
    _, st = jax.jit(mdec.mita_chunk_prefill, static_argnames="cfg")(
        st, q[0, :, :, n_pre:], k[0, :, 0, n_pre:], v[0, :, 0, n_pre:],
        pt, 0, n_pre, n_tot - n_pre, n_tot, cfg)

    m = n_tot // W
    np.testing.assert_allclose(np.asarray(st.lm_q[0][:, :m]),
                               np.asarray(ref.lm_q[0][:, :m]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st.lm_v[0][:, :m]),
                               np.asarray(ref.lm_v[0][:, :m]), atol=2e-5)
    loc = np.asarray(ref.expert_idx[0][:, :m])
    np.testing.assert_array_equal(np.asarray(st.expert_idx[0][:, :m]),
                                  table[loc // W] * W + loc % W)
    np.testing.assert_allclose(np.asarray(st.q_sum[0]),
                               np.asarray(ref.q_sum[0]), atol=2e-5)


# ----------------------------------------------------------------- engine --

@pytest.mark.parametrize("mode", ["batched", "per-job"])
def test_engine_chunked_matches_static_greedy(mode):
    """Chunked admission (prompt spans several chunks) emits the same greedy
    tokens as the monolithic static baseline, per request — in the batched
    single-dispatch mode (default) and the per-job legacy mode."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    B, N, gen = 4, 48, 10
    prompts = jax.random.randint(jax.random.PRNGKey(7), (B, N), 0, cfg.vocab)
    pages = (N + gen + W - 1) // W
    ref, _ = static_generate(params, _cfg(external=True), prompts, gen,
                             capacity=pages * W)
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=3, pages_per_slot=pages, n_pages=3 * pages + 2,
        prefill_chunk=2 * W, prefill_mode=mode))
    done = eng.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                            max_new_tokens=gen) for i in range(B)])
    assert len(done) == B
    assert eng.stats()["chunks"] >= B * (N // (2 * W))
    for i, f in enumerate(done):
        np.testing.assert_array_equal(f.tokens, ref[i], err_msg=f"req {i}")


@pytest.mark.parametrize("mode", ["batched", "per-job"])
def test_engine_chunked_nonaligned_prompt(mode):
    """Non-window-aligned prompts match the static baseline in both
    modes: per-job falls back to the monolithic head, batched serves them
    through the chunk program (the n//m landmark quirk is per-slot data —
    there is no monolithic prefill left in batched mode)."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    N, gen = 20, 9
    prompts = jax.random.randint(jax.random.PRNGKey(9), (2, N), 0, cfg.vocab)
    pages = (N + gen + W - 1) // W
    ref, _ = static_generate(params, _cfg(external=True), prompts, gen,
                             capacity=pages * W)
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=2, pages_per_slot=pages, n_pages=2 * pages + 2,
        prefill_chunk=2 * W, prefill_mode=mode))
    done = eng.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                            max_new_tokens=gen) for i in range(2)])
    for i, f in enumerate(done):
        np.testing.assert_array_equal(f.tokens, ref[i], err_msg=f"req {i}")
    if mode == "batched":
        # every prefill token went through the ONE chunk program
        assert eng.stats()["chunks"] >= 2


def test_batched_prefill_is_one_dispatch_per_step():
    """With several requests mid-prefill simultaneously, the batched
    engine issues EXACTLY one prefill dispatch per step (per-job issues
    one per job per chunk), and all requests still match the static
    baseline.  This is the compiled-program-scaling contract: prefill work
    per step is one fixed-shape program, not O(prefilling slots)."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    B, N, gen = 3, 6 * W, 4
    prompts = jax.random.randint(jax.random.PRNGKey(21), (B, N), 0,
                                 cfg.vocab)
    pages = (N + gen + W - 1) // W
    ref, _ = static_generate(params, _cfg(external=True), prompts, gen,
                             capacity=pages * W)
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=B, pages_per_slot=pages, n_pages=B * pages + 2,
        prefill_chunk=W))
    for i in range(B):
        eng.submit(Request(rid=i, prompt=np.asarray(prompts[i]),
                           max_new_tokens=gen))
    saw_concurrent = False
    while True:
        before = eng.prefill_dispatches
        n_jobs = 0
        eng._admit(0.0)
        n_jobs = len(eng.prefilling)
        if not eng.step():
            break
        saw_concurrent |= n_jobs > 1
        assert eng.prefill_dispatches - before <= 1, \
            f"{n_jobs} prefilling jobs took >1 dispatch in one step"
        if n_jobs > 1:
            # all jobs advanced in that single dispatch
            assert all(j.done > 0 for j in eng.prefilling.values())
    assert saw_concurrent, "scenario never had concurrent prefills"
    done = sorted(eng.finished, key=lambda f: f.rid)
    assert len(done) == B
    for i, f in enumerate(done):
        np.testing.assert_array_equal(f.tokens, ref[i], err_msg=f"req {i}")


def test_preemption_round_trip_identical_tokens():
    """A low-priority request evicted mid-decode by high-priority arrivals
    (pages released, later rebuilt by recompute-from-prompt) emits exactly
    the tokens of the same request run unpreempted, and page-accounting
    invariants hold through eviction and re-admission."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    N, gen = 16, 24
    victim = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (N,),
                                           0, cfg.vocab))
    ecfg = EngineConfig(n_slots=2, pages_per_slot=6, n_pages=8,
                        prefill_chunk=2 * W)
    ref = ServingEngine(params, cfg, ecfg).run(
        [Request(rid=0, prompt=victim, max_new_tokens=gen)])[0].tokens

    eng = ServingEngine(params, cfg, ecfg)
    eng.submit(Request(rid=0, prompt=victim, max_new_tokens=gen, priority=0))
    for _ in range(6):                   # prefill + decode a few tokens
        eng.step()
    hp = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, cfg.vocab)
    eng.submit(Request(rid=1, prompt=np.asarray(hp[0]), max_new_tokens=24,
                       priority=5))
    eng.submit(Request(rid=2, prompt=np.asarray(hp[1]), max_new_tokens=24,
                       priority=5))
    while eng.step():
        owned = [p for pages in eng.slot_pages.values() for p in pages]
        assert len(owned) == len(set(owned)), "page double-booked"
        assert not set(owned) & set(eng.alloc.free), "owned page in free list"
        assert len(owned) + len(eng.alloc.free) == ecfg.n_pages, "page leaked"
    done = sorted(eng.finished, key=lambda f: f.rid)
    assert len(done) == 3
    assert eng.n_preemptions >= 1, "scenario no longer triggers preemption"
    assert done[0].preemptions >= 1
    np.testing.assert_array_equal(done[0].tokens, ref)


def test_preemption_round_trip_nonaligned_prompt():
    """Preemption recompute of a NON-window-aligned prompt (n = 20, the
    n//m quirk head) through the batched chunk program — no monolithic
    head exists anymore, so the rebuilt A-system (prompt positions) and
    B-system (recomputed generated positions, decode availability) must
    reproduce the victim's unpreempted tokens exactly."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    N, gen = 20, 24
    victim = np.asarray(jax.random.randint(jax.random.PRNGKey(4), (N,),
                                           0, cfg.vocab))
    ecfg = EngineConfig(n_slots=2, pages_per_slot=6, n_pages=8,
                        prefill_chunk=2 * W)
    ref = ServingEngine(params, cfg, ecfg).run(
        [Request(rid=0, prompt=victim, max_new_tokens=gen)])[0].tokens

    eng = ServingEngine(params, cfg, ecfg)
    eng.submit(Request(rid=0, prompt=victim, max_new_tokens=gen, priority=0))
    for _ in range(6):                   # prefill + decode a few tokens
        eng.step()
    hp = jax.random.randint(jax.random.PRNGKey(6), (2, 16), 0, cfg.vocab)
    eng.submit(Request(rid=1, prompt=np.asarray(hp[0]), max_new_tokens=24,
                       priority=5))
    eng.submit(Request(rid=2, prompt=np.asarray(hp[1]), max_new_tokens=24,
                       priority=5))
    while eng.step():
        pass
    done = sorted(eng.finished, key=lambda f: f.rid)
    assert len(done) == 3
    assert eng.n_preemptions >= 1, "scenario no longer triggers preemption"
    np.testing.assert_array_equal(done[0].tokens, ref)


def test_equal_priority_jobs_never_livelock():
    """Two equal-priority long prompts whose chunked prefills together
    exceed the pool: pages must flow to the senior job (FCFS within a
    priority class) instead of both jobs stalling forever, and both
    requests must finish with the right token counts."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    N = 8 * W
    prompts = jax.random.randint(jax.random.PRNGKey(13), (2, N), 0,
                                 cfg.vocab)
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=2, pages_per_slot=9, n_pages=9, prefill_chunk=2 * W))
    for i in range(2):
        eng.submit(Request(rid=i, prompt=np.asarray(prompts[i]),
                           max_new_tokens=1))
    for _ in range(400):
        if not eng.step():
            break
    else:
        raise AssertionError("engine livelocked: no progress in 400 steps")
    done = sorted(eng.finished, key=lambda f: f.rid)
    assert [f.rid for f in done] == [0, 1]
    assert all(len(f.tokens) == 1 for f in done)


def test_priority_orders_admission():
    """With one free slot, a later-submitted higher-priority request is
    admitted first; FCFS order holds within a priority class."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=1, pages_per_slot=3, n_pages=3, prefill_chunk=W))
    pr = jax.random.randint(jax.random.PRNGKey(11), (3, W), 0, cfg.vocab)
    eng.submit(Request(rid=0, prompt=np.asarray(pr[0]), max_new_tokens=4,
                       priority=0))
    eng.submit(Request(rid=1, prompt=np.asarray(pr[1]), max_new_tokens=4,
                       priority=3))
    eng.submit(Request(rid=2, prompt=np.asarray(pr[2]), max_new_tokens=4,
                       priority=3))
    while eng.step():
        pass
    order = [f.rid for f in sorted(eng.finished, key=lambda f: f.finished)]
    assert order == [1, 2, 0]


def test_allocator_reserve_and_high_water():
    """Ordinary allocations cannot dip into the reserve; reserved (append)
    allocations can, and both dips and the high-water mark are counted."""
    al = _PageAllocator(8, reserve=2)
    assert al.can_alloc(6) and not al.can_alloc(7)
    got = al.alloc(6)
    assert len(got) == 6 and al.high_water == 6
    with pytest.raises(RuntimeError, match="exhausted"):
        al.alloc(1)
    assert al.can_alloc(2, reserved=True)
    al.alloc(1, reserved=True)
    assert al.reserve_dips == 1 and al.high_water == 7
    al.release(got)
    assert al.in_use == 1


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 16), st.integers(0, 8), st.integers(0, 2**32 - 1))
def test_allocator_reserve_high_water_property(n_pages, reserve, seed):
    """Property: under ANY interleaving of alloc/release/reserved-alloc,
    (1) pages in use never exceed the pool, (2) ordinary allocations never
    eat into the reserve, (3) the high-water mark is monotone and equals
    the max in-use ever seen, (4) releases restore exact accounting."""
    reserve = min(reserve, n_pages)
    al = _PageAllocator(n_pages, reserve=reserve)
    rng = np.random.default_rng(seed)
    held: list[list[int]] = []
    seen_hw = 0
    for _ in range(50):
        op = rng.integers(3)
        if op == 0 or (op == 2 and not held):       # ordinary alloc
            n = int(rng.integers(0, n_pages + 2))
            if al.can_alloc(n):
                held.append(al.alloc(n))
                assert len(al.free) >= al.reserve, "reserve invaded"
            else:
                assert n > len(al.free) - al.reserve
        elif op == 1:                               # reserved (append) alloc
            if al.can_alloc(1, reserved=True):
                held.append(al.alloc(1, reserved=True))
        else:                                       # release
            al.release(held.pop(int(rng.integers(len(held)))))
        in_use = sum(len(h) for h in held)
        assert al.in_use == in_use, "accounting drift"
        assert in_use <= n_pages, "pool overcommitted"
        seen_hw = max(seen_hw, in_use)
        # the max is always attained right after an alloc, so the mark is
        # exactly the running max (and therefore monotone)
        assert al.high_water == seen_hw, "high-water drift"
        assert sorted(al.free + [p for h in held for p in h]) \
            == list(range(n_pages)), "page leaked or duplicated"


def test_allocator_release_validation():
    """Ref-count hard errors: double-free, foreign release, duplicate ids
    in one call, retain of a free page — and none of them mutate state."""
    al = _PageAllocator(6)
    got = al.alloc(3)
    al.release(got)
    with pytest.raises(RuntimeError, match="double-free"):
        al.release([got[0]])                  # already back in the pool
    with pytest.raises(RuntimeError, match="double-free"):
        al.release([5])                       # never allocated
    got = al.alloc(2)
    with pytest.raises(RuntimeError, match="duplicate"):
        al.release([got[0], got[0]])
    with pytest.raises(RuntimeError, match="not allocated"):
        al.retain([al.free[0]])
    # the raising calls left accounting intact
    assert al.in_use == 2 and al.refcount(got[0]) == 1
    al.release(got)
    assert al.in_use == 0 and sorted(al.free) == list(range(6))


def test_allocator_refcount_shared_page_lifecycle():
    """A retained page survives the first release and frees on the last;
    shared_pages tracks multi-holder pages."""
    al = _PageAllocator(4)
    pages = al.alloc(2)
    al.retain([pages[0]])
    assert al.refcount(pages[0]) == 2 and al.shared_pages == 1
    al.release(pages)                         # slot lets go of both
    assert pages[0] not in al.free and pages[1] in al.free
    assert al.in_use == 1 and al.shared_pages == 0
    al.release([pages[0]])                    # cache lets go: page frees
    assert al.in_use == 0
    with pytest.raises(RuntimeError, match="double-free"):
        al.release([pages[0]])


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 16), st.integers(0, 2**32 - 1))
def test_allocator_refcount_property(n_pages, seed):
    """Property: under ANY interleaving of alloc / retain / release —
    including injected release-twice, release-foreign, and duplicate-id
    attempts, which must raise WITHOUT mutating — the allocator's refs
    match a model reference multiset exactly, every un-referenced page is
    free, and in_use counts distinct live pages."""
    from collections import Counter

    al = _PageAllocator(n_pages)
    rng = np.random.default_rng(seed)
    held: list[list[int]] = []        # one reference per page per batch
    for _ in range(80):
        op = int(rng.integers(4))
        if op == 0:                                 # alloc
            n = int(rng.integers(0, n_pages + 1))
            if al.can_alloc(n):
                held.append(al.alloc(n))
        elif op == 1 and held:                      # retain (share) a batch
            batch = held[int(rng.integers(len(held)))]
            if batch:
                al.retain(batch)
                held.append(list(batch))
        elif op == 2 and held:                      # release one reference
            al.release(held.pop(int(rng.integers(len(held)))))
        else:                                       # invalid ops must raise
            if al.free:
                p = al.free[int(rng.integers(len(al.free)))]
                with pytest.raises(RuntimeError, match="double-free"):
                    al.release([p])                 # foreign / already free
            if held and held[-1]:
                p = held[-1][0]
                with pytest.raises(RuntimeError, match="duplicate"):
                    al.release([p, p])
        model = Counter(p for h in held for p in h)
        assert dict(al.refs) == dict(model), "refcount drift"
        assert sorted(al.free + list(model)) == list(range(n_pages)), \
            "page leaked or duplicated"
        assert al.in_use == len(model)
        assert al.shared_pages == sum(1 for c in model.values() if c > 1)


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 16), st.integers(0, 4), st.integers(0, 2**32 - 1))
def test_allocator_spec_grow_rollback_property(n_pages, reserve, seed):
    """Property: the speculative-decoding page pattern — a slot GROWS by
    several reserved pages in one event (a verified run of k+1 commits can
    cross multiple page boundaries, `_ensure_append_pages`' while-loop)
    and may immediately ROLL BACK the newest pages (rejected drafts) —
    preserves exact accounting: grow never leaves a partially-allocated
    slot on failure paths we model (grow is all-or-nothing per page, so a
    mid-grow exhaustion keeps the pages it did get), rollback releases
    LIFO from the slot's tail only, and no interleaving of grows,
    rollbacks, and full retires across slots leaks or duplicates pages."""
    reserve = min(reserve, n_pages)
    al = _PageAllocator(n_pages, reserve=reserve)
    rng = np.random.default_rng(seed)
    slots: dict[int, list[int]] = {}
    next_slot = 0
    for _ in range(60):
        op = int(rng.integers(4))
        if op == 0:                                 # admit a new slot
            n = int(rng.integers(1, max(2, n_pages // 2)))
            if al.can_alloc(n):
                slots[next_slot] = al.alloc(n)
                next_slot += 1
        elif op == 1 and slots:                     # spec grow (while-loop)
            s = int(rng.choice(list(slots)))
            for _ in range(int(rng.integers(1, 4))):
                if not al.can_alloc(1, reserved=True):
                    break                           # engine would preempt
                slots[s] += al.alloc(1, reserved=True)
        elif op == 2 and slots:                     # spec rollback (tail)
            s = int(rng.choice(list(slots)))
            n = min(int(rng.integers(1, 4)), len(slots[s]) - 1)
            if n > 0:
                tail = [slots[s].pop() for _ in range(n)]
                al.release(tail)
        elif slots:                                 # retire a whole slot
            al.release(slots.pop(int(rng.choice(list(slots)))))
        live = [p for h in slots.values() for p in h]
        assert al.in_use == len(live), "accounting drift"
        assert len(set(live)) == len(live), "page double-owned"
        assert sorted(al.free + live) == list(range(n_pages)), \
            "page leaked or duplicated"
    for pages in slots.values():
        al.release(pages)
    assert al.in_use == 0 and sorted(al.free) == list(range(n_pages))


def test_preempted_prefill_keeps_admission_stamp():
    """A victim evicted MID-PREFILL must report its ORIGINAL admission
    time: re-admission restamping `admitted` would under-report queueing
    delay (TTFT = first_token - admitted) for exactly the requests that
    suffered preemption."""
    import time as _time

    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    N = 6 * W
    victim = np.asarray(jax.random.randint(jax.random.PRNGKey(17), (N,),
                                           0, cfg.vocab))
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=1, pages_per_slot=8, n_pages=8, prefill_chunk=W))
    eng.submit(Request(rid=0, prompt=victim, max_new_tokens=2, priority=0))
    eng.step()                           # admit + first chunk only
    assert eng.prefilling, "victim should still be mid-prefill"
    first_admit = next(iter(eng.prefilling.values())).admit_time
    mark = _time.perf_counter()
    assert first_admit <= mark
    hp = np.asarray(jax.random.randint(jax.random.PRNGKey(18), (W,),
                                       0, cfg.vocab))
    eng.submit(Request(rid=1, prompt=hp, max_new_tokens=4, priority=5))
    while eng.step():
        pass
    f0 = next(f for f in eng.finished if f.rid == 0)
    assert f0.preemptions >= 1, "scenario no longer preempts mid-prefill"
    assert f0.admitted == first_admit, \
        "re-admission restamped the admission time"
    assert f0.admitted <= mark < f0.first_token


def test_cancel_releases_pages_in_every_state():
    """`cancel(rid)` in each lifecycle state — waiting, prefilling,
    decoding — frees the slot and every page immediately (alloc.in_use
    returns to zero), emits a cancelled FinishedRequest carrying the
    tokens emitted so far, and makes the rid reusable."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(23), (3, 4 * W), 0,
                                 cfg.vocab)
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=1, pages_per_slot=6, n_pages=6, prefill_chunk=W))

    # --- waiting: one slot is busy, the second request queues
    eng.submit(Request(rid=0, prompt=np.asarray(prompts[0]),
                       max_new_tokens=8))
    eng.submit(Request(rid=1, prompt=np.asarray(prompts[1]),
                       max_new_tokens=8))
    eng.step()
    assert eng.cancel(1)
    f1 = next(f for f in eng.finished if f.rid == 1)
    assert f1.cancelled and len(f1.tokens) == 0
    assert not eng.waiting

    # --- prefilling: rid 0 is mid-chunked-prefill right now
    assert eng.prefilling
    assert eng.cancel(0)
    f0 = next(f for f in eng.finished if f.rid == 0)
    assert f0.cancelled and len(f0.tokens) == 0
    assert eng.alloc.in_use == 0, "prefill pages leaked"
    assert not eng.prefilling and len(eng.free_slots) == 1

    # --- decoding: cancel after a few emitted tokens; rid 0 is reusable
    eng.submit(Request(rid=0, prompt=np.asarray(prompts[2]),
                       max_new_tokens=16))
    for _ in range(8):
        eng.step()
    assert eng.slot_req, "request should be decoding by now"
    assert eng.cancel(0)
    f0b = [f for f in eng.finished if f.rid == 0][-1]
    assert f0b.cancelled and 0 < len(f0b.tokens) < 16
    assert eng.alloc.in_use == 0, "decode pages leaked"
    assert not eng.cancel(0), "cancel of a finished rid must be a no-op"
    assert not eng.step()


def test_engine_rejects_bad_chunk_and_reserve():
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="multiple of"):
        ServingEngine(params, cfg, EngineConfig(prefill_chunk=W + 1))
    with pytest.raises(ValueError, match="multiple of"):
        ServingEngine(params, cfg, EngineConfig(prefill_chunk=-W))
    with pytest.raises(ValueError, match="deadlock"):
        ServingEngine(params, cfg, EngineConfig(
            n_slots=2, pages_per_slot=8, n_pages=9, reserve_pages=4))


def test_preemption_round_trip_fused_sampling():
    """Preemption + on-device sampling: the rebuilt request's device-side
    sample index resumes at len(emitted), so a preempted temperature-
    sampled request still emits exactly the tokens of its unpreempted run
    (the (rid, index) key derivation is schedule-independent)."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    N, gen = 16, 24
    victim = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (N,),
                                           0, cfg.vocab))
    ecfg = EngineConfig(n_slots=2, pages_per_slot=6, n_pages=8,
                        prefill_chunk=2 * W, sample_device="fused")
    ref = ServingEngine(params, cfg, ecfg).run(
        [Request(rid=0, prompt=victim, max_new_tokens=gen,
                 temperature=0.7)])[0].tokens

    eng = ServingEngine(params, cfg, ecfg)
    eng.submit(Request(rid=0, prompt=victim, max_new_tokens=gen,
                       temperature=0.7, priority=0))
    for _ in range(6):
        eng.step()
    hp = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, cfg.vocab)
    eng.submit(Request(rid=1, prompt=np.asarray(hp[0]), max_new_tokens=24,
                       priority=5))
    eng.submit(Request(rid=2, prompt=np.asarray(hp[1]), max_new_tokens=24,
                       priority=5))
    while eng.step():
        pass
    done = sorted(eng.finished, key=lambda f: f.rid)
    assert eng.n_preemptions >= 1, "scenario no longer triggers preemption"
    np.testing.assert_array_equal(done[0].tokens, ref)
