"""Kernel ↔ oracle parity for the routed-expert branch.

`kernels/mita_expert_attn.py` (interpret=True on CPU) against the
`core/mita.py` routed branch, on exactly the cases the static-shape kernel
can get wrong: causal window masking, k wider than early window ends
(padded expert tiles), GQA group-shared routing, and pathological expert
load skew (a sorted query block spanning one expert vs many)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mita as mref
from repro.core import mita_sparse as msp
from repro.core.mita import MiTAConfig, mita_attention
from repro.core.mita_sparse import mita_attention_sparse

RNG = jax.random.PRNGKey(11)


def _qkv(b=1, h=2, n=128, d=16, key=RNG):
    return tuple(jax.random.normal(k, (b, h, n, d))
                 for k in jax.random.split(key, 3))


def test_routed_branch_kernel_vs_oracle_direct():
    """The kernel-backed sorted routed branch (expert_span=0 dispatches to
    `mita_expert_attention`) against `core.mita._routed_partial`, compared
    as normalized partials so no other branch can mask a mismatch."""
    q, k, v = _qkv(n=128)
    cfg = MiTAConfig(m=8, k=16, s=1, causal=True)
    q_lm = mref.extract_landmarks(q, cfg)
    s_kv = mref.landmark_scores(k, q_lm, cfg)
    r = mref.routing_logits(q, q_lm, cfg)
    k_e, v_e, valid = mref.gather_topk(k, v, s_kv, cfg)

    ref = mref._routed_partial(q, k_e, v_e, valid, r, cfg)
    out = msp._routed_sorted(q, k_e, v_e, valid, r, cfg, block_q=32,
                             expert_span=0)   # 0 -> Pallas kernel path

    act = np.asarray(ref.l) > 0
    assert np.array_equal(act, np.asarray(out.l) > 0)
    on = np.asarray(out.o, np.float32) / np.maximum(
        np.asarray(out.l)[..., None], 1e-30)
    rn = np.asarray(ref.o, np.float32) / np.maximum(
        np.asarray(ref.l)[..., None], 1e-30)
    np.testing.assert_allclose(on * act[..., None], rn * act[..., None],
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(out.m) * act,
                               np.asarray(ref.m) * act, atol=3e-5)


@pytest.mark.parametrize("s", [1, 2])
def test_pallas_causal_k_exceeds_window_end(s):
    """k > early window ends: the first windows contribute fewer than k
    valid rows, so the expert tiles carry causal padding the kernel must
    mask (NEG_INF bias lanes), not attend."""
    q, k, v = _qkv(n=128)
    cfg = MiTAConfig(m=8, k=32, s=s, causal=True)   # window = 16 < k = 32
    ref = mita_attention(q, k, v, cfg)
    out = mita_attention_sparse(q, k, v, cfg, impl="pallas", block_q=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_pallas_gqa_route_per_group():
    """route_per_group: ONE routing decision per KV group, shared by all G
    query heads — the kernel sees a broadcast-1 routing lead dim."""
    b, hkv, g, n, d = 2, 2, 4, 128, 16
    q = jax.random.normal(RNG, (b, hkv, g, n, d))
    k, v = (jax.random.normal(kk, (b, hkv, 1, n, d))
            for kk in jax.random.split(RNG, 2))
    q_lm = jnp.mean(q, axis=2, keepdims=True)
    cfg = MiTAConfig(m=8, k=16, causal=True, route_per_group=True)
    ref = mita_attention(q, k, v, cfg, q_landmarks=q_lm)
    out = mita_attention_sparse(q, k, v, cfg, impl="pallas", block_q=32,
                                q_landmarks=q_lm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_pallas_uneven_expert_load():
    """Pathological skew: all queries share a dominant direction, so nearly
    every sub-query routes to the same expert.  A sorted query block then
    walks a single expert tile (dynamic fori_loop lower==upper) — the
    degenerate case of the kernel's expert-range walk."""
    b, h, n, d = 1, 2, 128, 16
    ks = jax.random.split(RNG, 4)
    base = jax.random.normal(ks[0], (d,))
    q = base + 0.05 * jax.random.normal(ks[1], (b, h, n, d))
    q = q.at[..., :16, :].multiply(5.0)   # window 0's landmark dominates
    k = base + 0.05 * jax.random.normal(ks[2], (b, h, n, d))
    v = jax.random.normal(ks[3], (b, h, n, d))
    cfg = MiTAConfig(m=8, k=16, s=1, causal=False)
    ref = mita_attention(q, k, v, cfg)
    out = mita_attention_sparse(q, k, v, cfg, impl="pallas", block_q=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-5, rtol=1e-4)
    # the skew is real: >90% of queries on one expert
    r = mref.routing_logits(q, mref.extract_landmarks(q, cfg), cfg)
    top = np.asarray(jnp.argmax(r, axis=-1))
    _, counts = np.unique(top, return_counts=True)
    assert counts.max() > 0.9 * top.size


def test_pallas_all_experts_invalid_early_rows():
    """Causal + tiny first window where even expert 0's tile is partially
    invalid; queries before the first window end have NO routable expert —
    their routed partial must be empty (l == 0), never NaN."""
    q, k, v = _qkv(n=64)
    cfg = MiTAConfig(m=8, k=16, s=1, causal=True)    # window = 8 < k
    q_lm = mref.extract_landmarks(q, cfg)
    s_kv = mref.landmark_scores(k, q_lm, cfg)
    r = mref.routing_logits(q, q_lm, cfg)
    k_e, v_e, valid = mref.gather_topk(k, v, s_kv, cfg)
    out = msp._routed_sorted(q, k_e, v_e, valid, r, cfg, block_q=32,
                             expert_span=0)
    l = np.asarray(out.l)
    # expert 0 becomes available at t = w-1 ((i+1)*w <= t+1); before that
    # a query has no routable expert
    assert np.all(l[..., : 7] == 0.0)
    assert np.isfinite(np.asarray(out.o)).all()
    ref = mref._routed_partial(q, k_e, v_e, valid, r, cfg)
    assert np.array_equal(l > 0, np.asarray(ref.l) > 0)
