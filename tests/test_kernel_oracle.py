"""Kernel ↔ oracle parity for the routed-expert branch and the fused
paged-decode kernel.

`kernels/mita_expert_attn.py` (interpret=True on CPU) against the
`core/mita.py` routed branch, on exactly the cases the static-shape kernel
can get wrong: causal window masking, k wider than early window ends
(padded expert tiles), GQA group-shared routing, and pathological expert
load skew (a sorted query block spanning one expert vs many).

`kernels/mita_paged_attn.py` (interpret mode) against the XLA gather path
of `core/mita_decode.mita_paged_decode_step` (``paged_impl="xla"``), on
the cases the page walk can get wrong: randomized page permutations,
ragged per-slot progress, inactive slots, and the scratch-row append."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mita as mref
from repro.core import mita_decode as mdec
from repro.core import mita_sparse as msp
from repro.core.mita import MiTAConfig, mita_attention
from repro.core.mita_sparse import mita_attention_sparse
from repro.kernels import ops

RNG = jax.random.PRNGKey(11)


def _qkv(b=1, h=2, n=128, d=16, key=RNG):
    return tuple(jax.random.normal(k, (b, h, n, d))
                 for k in jax.random.split(key, 3))


def test_routed_branch_kernel_vs_oracle_direct():
    """The kernel-backed sorted routed branch (expert_span=0 dispatches to
    `mita_expert_attention`) against `core.mita._routed_partial`, compared
    as normalized partials so no other branch can mask a mismatch."""
    q, k, v = _qkv(n=128)
    cfg = MiTAConfig(m=8, k=16, s=1, causal=True)
    q_lm = mref.extract_landmarks(q, cfg)
    s_kv = mref.landmark_scores(k, q_lm, cfg)
    r = mref.routing_logits(q, q_lm, cfg)
    k_e, v_e, valid = mref.gather_topk(k, v, s_kv, cfg)

    ref = mref._routed_partial(q, k_e, v_e, valid, r, cfg)
    out = msp._routed_sorted(q, k_e, v_e, valid, r, cfg, block_q=32,
                             expert_span=0)   # 0 -> Pallas kernel path

    act = np.asarray(ref.l) > 0
    assert np.array_equal(act, np.asarray(out.l) > 0)
    on = np.asarray(out.o, np.float32) / np.maximum(
        np.asarray(out.l)[..., None], 1e-30)
    rn = np.asarray(ref.o, np.float32) / np.maximum(
        np.asarray(ref.l)[..., None], 1e-30)
    np.testing.assert_allclose(on * act[..., None], rn * act[..., None],
                               atol=3e-5)
    np.testing.assert_allclose(np.asarray(out.m) * act,
                               np.asarray(ref.m) * act, atol=3e-5)


@pytest.mark.parametrize("s", [1, 2])
def test_pallas_causal_k_exceeds_window_end(s):
    """k > early window ends: the first windows contribute fewer than k
    valid rows, so the expert tiles carry causal padding the kernel must
    mask (NEG_INF bias lanes), not attend."""
    q, k, v = _qkv(n=128)
    cfg = MiTAConfig(m=8, k=32, s=s, causal=True)   # window = 16 < k = 32
    ref = mita_attention(q, k, v, cfg)
    out = mita_attention_sparse(q, k, v, cfg, impl="pallas", block_q=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_pallas_gqa_route_per_group():
    """route_per_group: ONE routing decision per KV group, shared by all G
    query heads — the kernel sees a broadcast-1 routing lead dim."""
    b, hkv, g, n, d = 2, 2, 4, 128, 16
    q = jax.random.normal(RNG, (b, hkv, g, n, d))
    k, v = (jax.random.normal(kk, (b, hkv, 1, n, d))
            for kk in jax.random.split(RNG, 2))
    q_lm = jnp.mean(q, axis=2, keepdims=True)
    cfg = MiTAConfig(m=8, k=16, causal=True, route_per_group=True)
    ref = mita_attention(q, k, v, cfg, q_landmarks=q_lm)
    out = mita_attention_sparse(q, k, v, cfg, impl="pallas", block_q=32,
                                q_landmarks=q_lm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_pallas_uneven_expert_load():
    """Pathological skew: all queries share a dominant direction, so nearly
    every sub-query routes to the same expert.  A sorted query block then
    walks a single expert tile (dynamic fori_loop lower==upper) — the
    degenerate case of the kernel's expert-range walk."""
    b, h, n, d = 1, 2, 128, 16
    ks = jax.random.split(RNG, 4)
    base = jax.random.normal(ks[0], (d,))
    q = base + 0.05 * jax.random.normal(ks[1], (b, h, n, d))
    q = q.at[..., :16, :].multiply(5.0)   # window 0's landmark dominates
    k = base + 0.05 * jax.random.normal(ks[2], (b, h, n, d))
    v = jax.random.normal(ks[3], (b, h, n, d))
    cfg = MiTAConfig(m=8, k=16, s=1, causal=False)
    ref = mita_attention(q, k, v, cfg)
    out = mita_attention_sparse(q, k, v, cfg, impl="pallas", block_q=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-5, rtol=1e-4)
    # the skew is real: >90% of queries on one expert
    r = mref.routing_logits(q, mref.extract_landmarks(q, cfg), cfg)
    top = np.asarray(jnp.argmax(r, axis=-1))
    _, counts = np.unique(top, return_counts=True)
    assert counts.max() > 0.9 * top.size


def test_expert_kernel_pads_ragged_ns():
    """NS not divisible by block_q: the kernel wrapper pads the sorted
    sub-queries with the inactive assignment id and slices the outputs —
    the caller-side divisibility constraint is gone (the span path keeps
    it; impl='pallas' must not)."""
    q, k, v = _qkv(n=120)            # n*s = 120, block_q = 32 -> pad to 128
    cfg = MiTAConfig(m=8, k=16, s=1, causal=False)
    ref = mita_attention(q, k, v, cfg)
    out = mita_attention_sparse(q, k, v, cfg, impl="pallas", block_q=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
    with pytest.raises(ValueError, match="block_q"):
        mita_attention_sparse(q, k, v, cfg, impl="sorted", block_q=32)


def test_pallas_all_experts_invalid_early_rows():
    """Causal + tiny first window where even expert 0's tile is partially
    invalid; queries before the first window end have NO routable expert —
    their routed partial must be empty (l == 0), never NaN."""
    q, k, v = _qkv(n=64)
    cfg = MiTAConfig(m=8, k=16, s=1, causal=True)    # window = 8 < k
    q_lm = mref.extract_landmarks(q, cfg)
    s_kv = mref.landmark_scores(k, q_lm, cfg)
    r = mref.routing_logits(q, q_lm, cfg)
    k_e, v_e, valid = mref.gather_topk(k, v, s_kv, cfg)
    out = msp._routed_sorted(q, k_e, v_e, valid, r, cfg, block_q=32,
                             expert_span=0)
    l = np.asarray(out.l)
    # expert 0 becomes available at t = w-1 ((i+1)*w <= t+1); before that
    # a query has no routable expert
    assert np.all(l[..., : 7] == 0.0)
    assert np.isfinite(np.asarray(out.o)).all()
    ref = mref._routed_partial(q, k_e, v_e, valid, r, cfg)
    assert np.array_equal(l > 0, np.asarray(ref.l) > 0)


# ------------------------------------------------- fused paged-decode kernel --

W, K = 8, 8


def _paged_pair(s_route=1, external=True, impl="kernel"):
    cfg_x = mdec.DecodeConfig(window=W, k=K, s=s_route, paged_impl="xla",
                              external_finalize=external)
    return cfg_x, dataclasses.replace(cfg_x, paged_impl=impl)


def _drive(cfg_x, cfg_k, offs, n_steps, seed=3, b=3, hkv=2, g=2, d=16):
    """Step the XLA oracle and the kernel side by side over a shuffled page
    pool with per-slot staggered activity; assert outputs AND pools match
    every step (the pools pin the fused scratch-row append)."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (b, hkv, g, n_steps, d))
    k, v = (jax.random.normal(kk, (b, hkv, n_steps, d))
            for kk in jax.random.split(key, 2))
    m = (n_steps + W - 1) // W
    n_pages = b * m + 2
    table = np.random.default_rng(seed).permutation(n_pages)[: b * m]
    page_table = jnp.asarray(table.reshape(b, m), jnp.int32)
    st_x = mdec.init_paged_state(hkv, d, n_pages, b, m, cfg_x, jnp.float32)
    st_k = mdec.init_paged_state(hkv, d, n_pages, b, m, cfg_k, jnp.float32)
    step_x = jax.jit(lambda s, *a: mdec.mita_paged_decode_step(s, *a, cfg_x))
    step_k = jax.jit(lambda s, *a: mdec.mita_paged_decode_step(s, *a, cfg_k))
    fin = jax.jit(lambda s, *a: mdec.mita_paged_finalize(s, *a, cfg_x))
    t = np.zeros(b, np.int32)
    m_done = np.zeros(b, np.int32)
    for i in range(n_steps):
        act = np.array([offs[s] <= i for s in range(b)])
        if cfg_x.external_finalize:
            due = act & (t % W == 0) & (t // W > m_done)
            if due.any():
                td, dd = jnp.asarray(t), jnp.asarray(due)
                st_x = fin(st_x, page_table, td, dd)
                st_k = fin(st_k, page_table, td, dd)
                m_done = np.where(due, t // W, m_done)
        qi = jnp.stack([q[s, :, :, (i - offs[s]) % n_steps] for s in range(b)])
        ki = jnp.stack([k[s, :, (i - offs[s]) % n_steps] for s in range(b)])
        vi = jnp.stack([v[s, :, (i - offs[s]) % n_steps] for s in range(b)])
        td, ad = jnp.asarray(t), jnp.asarray(act)
        o_x, st_x = step_x(st_x, qi, ki, vi, page_table, td, ad)
        o_k, st_k = step_k(st_k, qi, ki, vi, page_table, td, ad)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_x),
                                   atol=2e-5, err_msg=f"step {i}")
        np.testing.assert_array_equal(np.asarray(st_k.k_pool),
                                      np.asarray(st_x.k_pool),
                                      err_msg=f"k_pool step {i}")
        np.testing.assert_array_equal(np.asarray(st_k.v_pool),
                                      np.asarray(st_x.v_pool),
                                      err_msg=f"v_pool step {i}")
        t = t + act
    return st_x, st_k


@pytest.mark.parametrize("s_route,external", [(1, True), (2, True),
                                              (1, False)])
def test_paged_kernel_matches_xla_staggered(s_route, external):
    """Kernel vs XLA gather path over shuffled pages, ragged per-slot t
    (slots join at different steps), inactive slots, inline + external
    finalize, and multi-expert routing.  Pools are compared bit-exactly —
    the kernel's fused append (external mode) must write exactly the rows
    the XLA scatter writes, scratch row included."""
    cfg_x, cfg_k = _paged_pair(s_route=s_route, external=external)
    _drive(cfg_x, cfg_k, offs=[0, 5, 11], n_steps=24)


def test_paged_kernel_scratch_row_append():
    """An inactive slot's fused append lands in the scratch row and ONLY
    the scratch row — no owned page of any other slot changes."""
    cfg_x, cfg_k = _paged_pair()
    b, hkv, g, d, m = 2, 2, 1, 16, 2
    n_pages = b * m
    page_table = jnp.asarray(np.arange(n_pages).reshape(b, m), jnp.int32)
    st = mdec.init_paged_state(hkv, d, n_pages, b, m, cfg_k, jnp.float32)
    key = jax.random.PRNGKey(0)
    qi = jax.random.normal(key, (b, hkv, g, d))
    ki, vi = (jax.random.normal(kk, (b, hkv, d))
              for kk in jax.random.split(key, 2))
    act = jnp.asarray([True, False])
    t = jnp.asarray([3, 0], jnp.int32)
    before = np.asarray(st.k_pool)
    _, st2 = jax.jit(lambda s, *a: mdec.mita_paged_decode_step(
        s, *a, cfg_k))(st, qi, ki, vi, page_table, t, act)
    after = np.asarray(st2.k_pool)
    scratch = after.shape[0] - 1
    np.testing.assert_array_equal(after[scratch], np.asarray(ki)[1])
    # slot 0 wrote its own page row; every other non-scratch row unchanged
    row0 = int(page_table[0, 0]) * W + 3
    np.testing.assert_array_equal(after[row0], np.asarray(ki)[0])
    mask = np.ones(after.shape[0], bool)
    mask[[row0, scratch]] = False
    np.testing.assert_array_equal(after[mask], before[mask])


def test_paged_kernel_shared_prefix_pages_append_isolation():
    """Two slots whose page tables alias the same prefix pages (the prefix
    cache's read-sharing): a decode step writes ONLY each slot's exclusive
    append row, in both the fused kernel and the XLA path — a shared page
    never takes the in-place append, so read-only sharing needs no copy."""
    cfg_x, cfg_k = _paged_pair()
    b, hkv, g, d, m = 2, 2, 1, 16, 3
    n_pages = 5
    table = np.asarray([[0, 1, 2], [0, 1, 3]], np.int32)  # pages 0,1 shared
    page_table = jnp.asarray(table)
    kq, kk, kv, kp = jax.random.split(jax.random.PRNGKey(7), 4)
    qi = jax.random.normal(kq, (b, hkv, g, d))
    ki = jax.random.normal(kk, (b, hkv, d))
    vi = jax.random.normal(kv, (b, hkv, d))
    pool = jax.random.normal(kp, (n_pages * W + 1, hkv, d))
    t = jnp.asarray([2 * W + 1, 2 * W + 3], jnp.int32)
    act = jnp.asarray([True, True])
    states = {}
    for name, cfg in (("kernel", cfg_k), ("xla", cfg_x)):
        st = mdec.init_paged_state(hkv, d, n_pages, b, m, cfg, jnp.float32)
        st = st._replace(k_pool=pool, v_pool=pool + 1.0)
        out, st2 = jax.jit(lambda s, *a, c=cfg: mdec.mita_paged_decode_step(
            s, *a, c))(st, qi, ki, vi, page_table, t, act)
        states[name] = (np.asarray(out), st2)
    np.testing.assert_allclose(states["kernel"][0], states["xla"][0],
                               atol=2e-5)
    rows = [int(table[0, 2]) * W + 1, int(table[1, 2]) * W + 3]
    before_k, before_v = np.asarray(pool), np.asarray(pool) + 1.0
    for name, st2 in ((n, s) for n, (_, s) in states.items()):
        for pname, after, src, base in (
                ("k_pool", np.asarray(st2.k_pool), np.asarray(ki), before_k),
                ("v_pool", np.asarray(st2.v_pool), np.asarray(vi), before_v)):
            np.testing.assert_array_equal(after[rows[0]], src[0],
                                          err_msg=f"{name} {pname} slot0")
            np.testing.assert_array_equal(after[rows[1]], src[1],
                                          err_msg=f"{name} {pname} slot1")
            mask = np.ones(after.shape[0], bool)
            mask[rows] = False
            np.testing.assert_array_equal(
                after[mask], base[mask],
                err_msg=f"{name} {pname} shared pages mutated")


def test_paged_kernel_vmem_budget_dispatch(monkeypatch):
    """Dispatch flips to the XLA fallback when the VMEM budget shrinks —
    via the DecodeConfig field and via REPRO_VMEM_BUDGET_BYTES — and the
    step stays correct either way (it IS the fallback)."""
    shape = dict(window=W, m=4, k_width=K, g=2, d=16, itemsize=4)
    assert ops.use_paged_kernel("kernel", **shape)
    assert not ops.use_paged_kernel("kernel", **shape, budget=64)
    assert not ops.use_paged_kernel("xla", **shape)
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", "64")
    assert ops.vmem_budget_bytes() == 64
    assert not ops.use_paged_kernel("kernel", **shape)
    monkeypatch.delenv("REPRO_VMEM_BUDGET_BYTES")
    # a "kernel" config whose working set exceeds the budget must still
    # produce oracle-exact results (it silently runs the fallback)
    cfg_x, cfg_tiny = _paged_pair()
    cfg_tiny = dataclasses.replace(cfg_tiny, vmem_budget=64)
    _drive(cfg_x, cfg_tiny, offs=[0, 0, 0], n_steps=4)


def test_gather_pages_owned_redirects_to_scratch():
    """`gather_pages(owned=...)`: table entries past the owned prefix read
    the scratch row, not whatever (other requests') pages the unused
    entries happen to name."""
    hkv, d, w = 2, 4, 4
    pool = jnp.arange(9 * hkv * d, dtype=jnp.float32).reshape(9, hkv, d)
    page_ids = jnp.asarray([[0, 1], [1, 0]], jnp.int32)   # slot 1 unused
    out = ops.gather_pages(pool, page_ids, w,
                           owned=jnp.asarray([1, 2], jnp.int32))
    ref = np.asarray(pool)
    # slot 0: first page real, second page -> scratch row replicated
    np.testing.assert_array_equal(np.asarray(out)[0, :w], ref[0:w])
    np.testing.assert_array_equal(np.asarray(out)[0, w:],
                                  np.broadcast_to(ref[8], (w, hkv, d)))
    # slot 1 owns both pages: untouched
    np.testing.assert_array_equal(
        np.asarray(out)[1], np.concatenate([ref[4:8], ref[0:4]]))


# ------------------------------------------------ fused chunk-prefill kernel --


def _chunk_pair(s_route=1, external=True):
    cfg_x = mdec.DecodeConfig(window=W, k=K, s=s_route, prefill_impl="xla",
                              external_finalize=external)
    return cfg_x, dataclasses.replace(cfg_x, prefill_impl="kernel")


def _drive_chunks(cfg_x, cfg_k, n_trains, n_totals, chunk, m_slot=4,
                  hkv=2, g=2, d=16, stagger=True, seed=5):
    """Chunk-prefill the kernel and the XLA oracle side by side over a
    shuffled page pool; slots advance on alternating steps (ragged resume
    points + inactive rows in every dispatch).  State tensors and owned
    pages are compared BIT-exactly after every dispatch; outputs allclose
    on valid positions."""
    s_n = len(n_totals)
    key = jax.random.PRNGKey(seed)
    n_pages = s_n * m_slot + 2
    table = np.random.default_rng(seed).permutation(n_pages)[: s_n * m_slot]
    pt = jnp.asarray(table.reshape(s_n, m_slot), jnp.int32)
    nmax = max(n_totals)
    q = jax.random.normal(key, (s_n, hkv, g, nmax, d))
    k, v = (jax.random.normal(kk, (s_n, hkv, nmax, d))
            for kk in jax.random.split(key, 2))
    st_x = mdec.init_paged_state(hkv, d, n_pages, s_n, m_slot, cfg_x,
                                 jnp.float32)
    st_k = mdec.init_paged_state(hkv, d, n_pages, s_n, m_slot, cfg_k,
                                 jnp.float32)
    step = jax.jit(mdec.mita_batched_chunk_prefill, static_argnames="cfg")
    done = np.zeros(s_n, np.int32)
    it = 0
    while (done < np.asarray(n_totals)).any():
        act = done < np.asarray(n_totals)
        if stagger and s_n > 1:
            act = act & (np.arange(s_n) % 2 == it % 2)
        it += 1
        if not act.any():
            continue
        nv = np.where(act, np.minimum(chunk, np.asarray(n_totals) - done), 0)
        qc = np.zeros((s_n, hkv, g, chunk, d), np.float32)
        kc = np.zeros((s_n, hkv, chunk, d), np.float32)
        vc = np.zeros((s_n, hkv, chunk, d), np.float32)
        for s in range(s_n):
            if act[s]:
                sl = slice(done[s], done[s] + nv[s])
                qc[s, :, :, : nv[s]] = np.asarray(q[s, :, :, sl])
                kc[s, :, : nv[s]] = np.asarray(k[s, :, sl])
                vc[s, :, : nv[s]] = np.asarray(v[s, :, sl])
        args = (jnp.asarray(qc), jnp.asarray(kc), jnp.asarray(vc), pt,
                jnp.arange(s_n, dtype=jnp.int32), jnp.asarray(done),
                jnp.asarray(nv), jnp.asarray(n_trains, jnp.int32),
                jnp.asarray(act))
        o_x, st_x = step(st_x, *args, cfg=cfg_x)
        o_k, st_k = step(st_k, *args, cfg=cfg_k)
        o_x, o_k = np.asarray(o_x), np.asarray(o_k)
        for s in range(s_n):
            np.testing.assert_allclose(
                o_k[s][:, :, : nv[s]], o_x[s][:, :, : nv[s]], atol=2e-5,
                err_msg=f"out slot {s} step {it}")
        for f in ("lm_q", "lm_v", "expert_idx", "expert_valid", "q_sum",
                  "pre_lm_q", "pre_q_sum"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st_k, f)), np.asarray(getattr(st_x, f)),
                err_msg=f"{f} step {it}")
        # owned pages bit-exact (the trailing scratch row soaks up write
        # order differences between the flat scatter and the DMA loop)
        np.testing.assert_array_equal(np.asarray(st_k.k_pool)[:-1],
                                      np.asarray(st_x.k_pool)[:-1],
                                      err_msg=f"k_pool step {it}")
        np.testing.assert_array_equal(np.asarray(st_k.v_pool)[:-1],
                                      np.asarray(st_x.v_pool)[:-1],
                                      err_msg=f"v_pool step {it}")
        done = done + nv
    return st_x, st_k


@pytest.mark.parametrize("s_route,external", [(1, True), (2, True),
                                              (1, False)])
def test_chunk_kernel_matches_xla_ragged(s_route, external):
    """Kernel vs XLA over shuffled pages, ragged resume points (slots
    advance on alternating dispatches, so every dispatch mixes active and
    inactive rows), preemption-recompute rows (n_total > n_train replicates
    decode-time landmark availability), multi-expert routing, and both
    finalize modes.  All state — landmarks, expert rows, both q_sum
    systems, owned pages — is compared bit-exactly after every dispatch."""
    _drive_chunks(*_chunk_pair(s_route=s_route, external=external),
                  n_trains=[32, 16, 20], n_totals=[32, 24, 28], chunk=8)


def test_chunk_kernel_nonaligned_heads():
    """Non-window-aligned prompts (the n//m landmark-ends quirk: w' = 10
    for n = 20, w' = n for single-landmark prompts) through the kernel,
    bit-identical to the XLA oracle — including a chunk length SHORTER
    than w', which forces the eager landmark-query commit to cross a
    dispatch before its score context exists."""
    _drive_chunks(*_chunk_pair(), n_trains=[20, 12], n_totals=[20, 12],
                  chunk=8)


def test_chunk_kernel_inactive_slots_untouched():
    """A dispatch with an inactive row leaves that slot's landmark/expert/
    q_sum state and every owned page bit-identical (checked every dispatch
    by the driver since slots alternate), and a fully-prefilled batch
    matches the single-slot oracle's final state."""
    cfg_x, cfg_k = _chunk_pair()
    st_x, st_k = _drive_chunks(cfg_x, cfg_k, n_trains=[16, 16],
                               n_totals=[16, 16], chunk=16)
    # cross-check one slot against the single-slot chunk op
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (2, 2, 2, 16, 16))
    k, v = (jax.random.normal(kk, (2, 2, 16, 16))
            for kk in jax.random.split(key, 2))
    n_pages = 2 * 4 + 2
    table = np.random.default_rng(5).permutation(n_pages)[: 2 * 4]
    pt = jnp.asarray(table.reshape(2, 4), jnp.int32)
    st1 = mdec.init_paged_state(2, 16, n_pages, 2, 4, cfg_x, jnp.float32)
    _, st1 = jax.jit(mdec.mita_chunk_prefill, static_argnames="cfg")(
        st1, q[0], k[0], v[0], pt[0], 0, 0, 16, 16, cfg_x)
    np.testing.assert_allclose(np.asarray(st_k.lm_q)[0],
                               np.asarray(st1.lm_q)[0], atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_k.q_sum)[0],
                               np.asarray(st1.q_sum)[0], atol=2e-5)
    np.testing.assert_array_equal(np.asarray(st_k.expert_idx)[0],
                                  np.asarray(st1.expert_idx)[0])


def test_chunk_kernel_recompute_round_trip():
    """Preemption recompute at the core level: build a state by chunked
    prefill of prompt-then-generated (n_train < n_total), rebuild it from
    scratch in one go, and require the kernel and oracle to agree
    bit-exactly on both builds AND the two builds to agree with each other
    (recompute-from-prompt is deterministic)."""
    cfg_x, cfg_k = _chunk_pair()
    st_a, _ = _drive_chunks(cfg_x, cfg_k, n_trains=[16], n_totals=[32],
                            chunk=8, stagger=False)
    st_b, _ = _drive_chunks(cfg_x, cfg_k, n_trains=[16], n_totals=[32],
                            chunk=16, stagger=False)
    for f in ("lm_q", "lm_v", "expert_idx", "expert_valid", "q_sum"):
        np.testing.assert_allclose(
            np.asarray(getattr(st_a, f)), np.asarray(getattr(st_b, f)),
            atol=2e-5, err_msg=f"{f} chunk-size invariance")


def test_prefill_impl_dispatch(monkeypatch):
    """`use_prefill_kernel`: tri-state impl + VMEM budget + the
    REPRO_PREFILL_IMPL env override flip dispatch without touching
    numerics (the XLA path IS the fallback)."""
    shape = dict(nc=16, window=W, m=4, k_width=K, g=2, d=16, itemsize=4)
    assert ops.use_prefill_kernel("kernel", **shape)
    assert not ops.use_prefill_kernel("kernel", **shape, budget=64)
    assert not ops.use_prefill_kernel("xla", **shape)
    with pytest.raises(ValueError, match="prefill impl"):
        ops.use_prefill_kernel("bogus", **shape)
    monkeypatch.setenv("REPRO_PREFILL_IMPL", "xla")
    assert not ops.use_prefill_kernel("kernel", **shape)
    monkeypatch.setenv("REPRO_PREFILL_IMPL", "kernel")
    assert ops.use_prefill_kernel("xla", **shape)
    monkeypatch.delenv("REPRO_PREFILL_IMPL")
    # an oversized "kernel" config silently runs the oracle
    cfg_x, cfg_k = _chunk_pair()
    cfg_tiny = dataclasses.replace(cfg_k, vmem_budget=64)
    _drive_chunks(cfg_x, cfg_tiny, n_trains=[16], n_totals=[16], chunk=16)


@pytest.mark.parametrize("qb", [4, 2, 1, None])
def test_chunk_kernel_forced_tile_sweep(qb, monkeypatch):
    """VMEM-budget-driven tiling flips: REPRO_VMEM_BUDGET_BYTES values
    computed from the estimator force every local-branch tile size the
    selector can produce (q_block = nw, nw/2, 1) and, below the smallest
    tile, the counted XLA fallback — parity must be bit-exact at every
    tile shape (the tiled kernel merges no partials across tiles, so no
    tolerance loosening is allowed)."""
    shape = dict(nc=32, window=W, m=4, k_width=K, g=2, d=16, itemsize=4)
    need = {b: ops.chunk_prefill_vmem_bytes(**shape, q_block=b)
            for b in (4, 2, 1)}
    assert need[1] < need[2] < need[4]
    budget = need[qb] if qb else need[1] - 1
    # the env override reaches the selector (budget=0 reads it)...
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", str(budget))
    assert ops.select_prefill_q_block(**shape) == qb
    monkeypatch.delenv("REPRO_VMEM_BUDGET_BYTES")
    # ...while the drive pins the budget via DecodeConfig so each swept
    # value is part of the static jit key (an env flip alone would reuse
    # the first parameterization's compiled trace and tile size)
    cfg_x, cfg_k = _chunk_pair()
    cfg_k = dataclasses.replace(cfg_k, vmem_budget=budget)
    with ops.scoped_fallback_counters() as fb:
        _drive_chunks(cfg_x, cfg_k, n_trains=[32, 32], n_totals=[32, 32],
                      chunk=32)
    if qb is None:
        assert fb["prefill"] >= 1      # counted, and still oracle-exact
    else:
        assert fb["prefill"] == 0


# ---------------------------------------------- fused paged-finalize kernel --


def _finalize_pair(s_route=1):
    cfg_x = mdec.DecodeConfig(window=W, k=K, s=s_route, finalize_impl="xla",
                              external_finalize=True)
    return cfg_x, dataclasses.replace(cfg_x, finalize_impl="kernel")


def _finalize_state(cfg, s_n=4, m_slot=4, hkv=2, d=16, seed=9):
    """A paged state with fully random pools, landmarks, and window-query
    accumulators over a SHUFFLED page table — nothing about the finalize
    may depend on pool layout beyond what the table names."""
    n_pages = s_n * m_slot + 2
    table = np.random.default_rng(seed).permutation(n_pages)[: s_n * m_slot]
    pt = jnp.asarray(table.reshape(s_n, m_slot), jnp.int32)
    st = mdec.init_paged_state(hkv, d, n_pages, s_n, m_slot, cfg,
                               jnp.float32)
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    return st._replace(
        k_pool=jax.random.normal(ks[0], st.k_pool.shape, st.k_pool.dtype),
        v_pool=jax.random.normal(ks[1], st.v_pool.shape, st.v_pool.dtype),
        q_sum=jax.random.normal(ks[2], st.q_sum.shape, jnp.float32),
        lm_q=jax.random.normal(ks[3], st.lm_q.shape, st.lm_q.dtype),
        lm_v=jax.random.normal(ks[4], st.lm_v.shape, st.lm_v.dtype)), pt


_FIN_FIELDS = ("lm_q", "lm_v", "expert_idx", "expert_valid", "q_sum")


@pytest.mark.parametrize("t_new,due", [
    ((8, 16, 0, 29), (True, True, False, False)),
    ((32, 8, 24, 5), (True, True, True, False)),
])
def test_finalize_kernel_matches_xla(t_new, due):
    """Finalize kernel vs the `_paged_finalize` XLA oracle over a shuffled
    page table, ragged per-slot t (first/middle/last window ordinals),
    non-due and inactive (t = 0) slots: landmarks, expert rows, validity,
    and q_sum bit-exact; pools untouched."""
    cfg_x, cfg_k = _finalize_pair()
    st, pt = _finalize_state(cfg_x)
    td = jnp.asarray(t_new, jnp.int32)
    dd = jnp.asarray(due)
    fin = jax.jit(mdec.mita_paged_finalize, static_argnames="cfg")
    st_x = fin(st, pt, td, dd, cfg=cfg_x)
    st_k = fin(st, pt, td, dd, cfg=cfg_k)
    for f in _FIN_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(st_k, f)),
                                      np.asarray(getattr(st_x, f)),
                                      err_msg=f)
    for f in ("k_pool", "v_pool"):
        np.testing.assert_array_equal(np.asarray(getattr(st_k, f)),
                                      np.asarray(getattr(st_x, f)),
                                      err_msg=f)
    # non-due rows pass through bit-exactly (q_sum zeroing is due-gated)
    for f in _FIN_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(st_k, f))[~np.asarray(due)],
            np.asarray(getattr(st, f))[~np.asarray(due)],
            err_msg=f"{f} non-due passthrough")


def test_finalize_kernel_in_decode_loop():
    """The finalize kernel inside the full external-finalize decode drive:
    the `_drive` loop re-runs with the KERNEL finalize on one side and the
    XLA finalize on the other (decode steps identical), pinning the
    integration point `_paged_finalize` dispatches through."""
    cfg_x = mdec.DecodeConfig(window=W, k=K, s=1, paged_impl="xla",
                              external_finalize=True, finalize_impl="xla")
    cfg_k = dataclasses.replace(cfg_x, finalize_impl="kernel")
    key = jax.random.PRNGKey(3)
    b, hkv, g, d, n_steps = 3, 2, 2, 16, 24
    q = jax.random.normal(key, (b, hkv, g, n_steps, d))
    k, v = (jax.random.normal(kk, (b, hkv, n_steps, d))
            for kk in jax.random.split(key, 2))
    m = (n_steps + W - 1) // W
    n_pages = b * m + 2
    table = np.random.default_rng(3).permutation(n_pages)[: b * m]
    pt = jnp.asarray(table.reshape(b, m), jnp.int32)
    st_x = mdec.init_paged_state(hkv, d, n_pages, b, m, cfg_x, jnp.float32)
    st_k = st_x
    step = jax.jit(lambda s, *a: mdec.mita_paged_decode_step(s, *a, cfg_x))
    fin = jax.jit(mdec.mita_paged_finalize, static_argnames="cfg")
    offs = [0, 5, 11]
    t = np.zeros(b, np.int32)
    m_done = np.zeros(b, np.int32)
    for i in range(n_steps):
        act = np.array([offs[s] <= i for s in range(b)])
        due = act & (t % W == 0) & (t // W > m_done)
        if due.any():
            td, dd = jnp.asarray(t), jnp.asarray(due)
            st_x = fin(st_x, pt, td, dd, cfg=cfg_x)
            st_k = fin(st_k, pt, td, dd, cfg=cfg_k)
            for f in _FIN_FIELDS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(st_k, f)),
                    np.asarray(getattr(st_x, f)), err_msg=f"{f} step {i}")
            m_done = np.where(due, t // W, m_done)
        qi = jnp.stack([q[s, :, :, (i - offs[s]) % n_steps]
                        for s in range(b)])
        ki = jnp.stack([k[s, :, (i - offs[s]) % n_steps] for s in range(b)])
        vi = jnp.stack([v[s, :, (i - offs[s]) % n_steps] for s in range(b)])
        td, ad = jnp.asarray(t), jnp.asarray(act)
        o_x, st_x = step(st_x, qi, ki, vi, pt, td, ad)
        o_k, st_k = step(st_k, qi, ki, vi, pt, td, ad)
        np.testing.assert_array_equal(np.asarray(o_k), np.asarray(o_x),
                                      err_msg=f"decode out step {i}")
        t = t + act


def test_finalize_impl_dispatch(monkeypatch):
    """`use_finalize_kernel`: tri-state impl + VMEM budget + the
    REPRO_FINALIZE_IMPL env override flip dispatch without touching
    numerics (the XLA path IS the fallback)."""
    shape = dict(window=W, m=4, k_width=K, d=16, itemsize=4)
    assert ops.use_finalize_kernel("kernel", **shape)
    assert not ops.use_finalize_kernel("kernel", **shape, budget=64)
    assert not ops.use_finalize_kernel("xla", **shape)
    with pytest.raises(ValueError, match="finalize impl"):
        ops.use_finalize_kernel("bogus", **shape)
    monkeypatch.setenv("REPRO_FINALIZE_IMPL", "xla")
    assert not ops.use_finalize_kernel("kernel", **shape)
    monkeypatch.setenv("REPRO_FINALIZE_IMPL", "kernel")
    assert ops.use_finalize_kernel("xla", **shape)
    monkeypatch.delenv("REPRO_FINALIZE_IMPL")
    # an oversized "kernel" config silently runs the oracle, counted
    cfg_x, cfg_k = _finalize_pair()
    cfg_tiny = dataclasses.replace(cfg_k, vmem_budget=64)
    st, pt = _finalize_state(cfg_x)
    td = jnp.asarray([8, 16, 0, 29], jnp.int32)
    dd = jnp.asarray([True, True, False, False])
    fin = jax.jit(mdec.mita_paged_finalize, static_argnames="cfg")
    with ops.scoped_fallback_counters() as fb:
        st_t = fin(st, pt, td, dd, cfg=cfg_tiny)
    assert fb["finalize"] >= 1 and fb["prefill"] == 0
    st_x = fin(st, pt, td, dd, cfg=cfg_x)
    for f in _FIN_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(st_t, f)),
                                      np.asarray(getattr(st_x, f)),
                                      err_msg=f)


def test_fallback_counters_reset_and_scope():
    """`reset_fallback_counters` zeroes all three counters and re-arms the
    warn-once flags; `scoped_fallback_counters` reports only its block's
    deltas while the globals keep accumulating for backend snapshots."""
    ops.reset_fallback_counters()
    assert ops.fallback_counters() == {"prefill": 0, "paged": 0,
                                       "finalize": 0}
    shape = dict(nc=16, window=W, m=4, k_width=K, g=2, d=16)
    with pytest.warns(RuntimeWarning, match="VMEM budget"):
        with ops.scoped_fallback_counters() as fb:
            assert not ops.use_prefill_kernel("kernel", **shape, budget=64)
    assert fb == {"prefill": 1, "paged": 0, "finalize": 0}
    assert ops.fallback_counters()["prefill"] == 1   # global still counts
    with ops.scoped_fallback_counters() as fb2:
        pass
    assert fb2 == {"prefill": 0, "paged": 0, "finalize": 0}
    ops.reset_fallback_counters()
    # the warn flag is re-armed: the next budget fallback warns again
    with pytest.warns(RuntimeWarning, match="VMEM budget"):
        ops.use_prefill_kernel("kernel", **shape, budget=64)
    ops.reset_fallback_counters()


def test_paged_kernel_dma_pipeline_parity(monkeypatch):
    """REPRO_DMA_PIPELINE=0 (serial expert-row DMAs) and =1 (double-
    buffered) produce identical decode steps — the pipeline only reorders
    copies into disjoint destination rows."""
    cfg_x, cfg_k = _paged_pair(s_route=2)
    monkeypatch.setenv("REPRO_DMA_PIPELINE", "0")
    _drive(cfg_x, cfg_k, offs=[0, 3, 7], n_steps=12)
    monkeypatch.setenv("REPRO_DMA_PIPELINE", "1")
    _drive(cfg_x, cfg_k, offs=[0, 3, 7], n_steps=12)


def test_block_q_env_default(monkeypatch):
    """REPRO_BLOCK_Q feeds `ops.default_block_q`, reachable via
    AttnConfig.block_q = 0.  Checked on the pallas routed path, which is
    block-size INVARIANT (the span path's documented drop condition
    depends on block size, so it is not a valid invariance probe)."""
    q, k, v = _qkv(n=128)
    cfg = MiTAConfig(m=8, k=16, s=1, causal=True)
    ref = mita_attention_sparse(q, k, v, cfg, impl="pallas", block_q=128)
    monkeypatch.setenv("REPRO_BLOCK_Q", "32")
    assert ops.default_block_q() == 32
    out = mita_attention_sparse(q, k, v, cfg, impl="pallas",
                                block_q=ops.default_block_q())
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
    # AttnConfig plumbs 0 -> env default (modules.attention_apply)
    from repro.models import modules as nn
    acfg = nn.AttnConfig(window=16, k=16, block_q=0)
    assert (acfg.block_q or ops.default_block_q()) == 32
