"""Supervisor + chaos unit battery: the failure policy itself.

The conformance suite (tests/test_backend_conformance.py) pins the
cross-backend properties — supervised chaos parity, mid-step leak
freedom — so this file drills the policy mechanics on the cheap MiTA
cell: deterministic schedules, each fault kind's exact lifecycle
(retry / quarantine / ladder rung), deadline + rejection accounting,
stall relief under allocator spikes, straggler counting, the
`AllocatorInvariantError` no-retry contract, and the snapshot/restore
journal (round-trip, file atomicity, and its validation errors).
"""

import dataclasses
import functools
import os
import time

import jax
import numpy as np
import pytest

from repro.models import transformer as tfm
from repro.models.modules import AttnConfig, ModelConfig
from repro.serve import (AllocatorInvariantError, ChaosBackend, ChaosConfig,
                         EngineConfig, InjectedFault, Request, ServingEngine,
                         Supervisor, SupervisorConfig, SupervisionExhausted)
from repro.serve.backends.mita import MiTABackend
from repro.serve.supervisor import DEGRADATION_RUNGS

W = 8


@functools.lru_cache(maxsize=None)
def _cell():
    cfg = ModelConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=89,
        attn=AttnConfig(window=W, k=W, backend="mita_ref"))
    return cfg, tfm.lm_init(jax.random.PRNGKey(0), cfg)


def _engine(ecfg=None, chaos=None):
    cfg, params = _cell()
    ecfg = ecfg or EngineConfig(n_slots=2, pages_per_slot=4, n_pages=12,
                                prefill_chunk=W)
    backend = MiTABackend(params, cfg, ecfg)
    if chaos is not None:
        backend = ChaosBackend(backend, chaos)
    return ServingEngine(params, cfg, ecfg, backend=backend)


def _requests(specs, seed=7, **kw):
    cfg, _ = _cell()
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, ln)
                    .astype(np.int32), max_new_tokens=g, **kw)
            for i, (ln, g) in enumerate(specs)]


def _tokens(done):
    return {f.rid: f.tokens.tolist() for f in done
            if f.reason == "complete"}


SPECS = [(W, 4), (2 * W, 6), (W, 3)]


@functools.lru_cache(maxsize=None)
def _reference():
    return tuple(sorted(_tokens(_engine().run(_requests(SPECS))).items()))


def _ref():
    return dict(_reference())


# ----------------------------------------------------------- chaos itself --

def test_chaos_schedule_is_deterministic():
    """Same ChaosConfig + same trace => identical fault schedule, counts,
    and (supervised) identical tokens."""
    chaos = ChaosConfig(seed=9, p_fault=0.3, transient_len=2,
                        p_slot_fault=0.5,
                        ops=("decode_step", "prefill_chunks"))
    outs = []
    for _ in range(2):
        eng = _engine(chaos=chaos)
        sup = Supervisor(eng, SupervisorConfig(max_retries=2))
        done = sup.run(_requests(SPECS))
        outs.append((eng.backend.n_injected, eng.backend.n_faults_started,
                     sup.stats()["retries"], sup.stats()["quarantined"],
                     tuple(sorted(_tokens(done).items()))))
    assert outs[0] == outs[1]
    assert outs[0][0] > 0


def test_chaos_inject_validates():
    cb = ChaosBackend(object(), ChaosConfig())
    with pytest.raises(ValueError, match="unknown op"):
        cb.inject("no_such_op")
    with pytest.raises(ValueError, match="unknown fault kind"):
        cb.inject("decode_step", kind="cosmic_ray")


# ------------------------------------------------------- fault lifecycles --

def test_transient_fault_retries_to_parity():
    """A transient fault is absorbed entirely by the retry loop: no
    quarantine, no rungs, bit-identical streams, counted retries."""
    eng = _engine(chaos=ChaosConfig(transient_len=2))
    sup = Supervisor(eng, SupervisorConfig(max_retries=3))
    cb = eng.backend
    for r in _requests(SPECS):
        sup.submit(r)
    while not eng.active.any():
        sup.step()
    cb.inject("decode_step")        # raises twice, then heals
    while sup.step():
        pass
    st = sup.stats()
    assert _tokens(eng.finished) == _ref()
    assert st["retries"] == 2 and st["quarantined"] == 0
    assert st["degradation_level"] == 0
    assert eng.alloc.in_use == 0 and eng.alloc.refs == {}


def test_slot_fault_quarantines_only_victim():
    """A slot-bound fault evicts ONLY the implicated slot; the victim
    resurrects through recompute-from-prompt bit-identically and the
    rest of the batch never stops."""
    eng = _engine(chaos=ChaosConfig())
    sup = Supervisor(eng, SupervisorConfig(max_retries=1))
    cb = eng.backend
    for r in _requests(SPECS):
        sup.submit(r)
    while not eng.active.any():
        sup.step()
    victim = int(np.nonzero(eng.active)[0][0])
    cb.inject("decode_step", kind="slot", slots=(victim,))
    while sup.step():
        pass
    st = sup.stats()
    assert _tokens(eng.finished) == _ref()
    assert st["quarantined"] == 1
    assert st["degradation_level"] == 0
    assert eng.stats()["preemptions"] >= 1
    assert eng.alloc.in_use == 0 and eng.alloc.refs == {}


def test_persistent_fault_walks_ladder_to_parity():
    """A batch-wide persistent fault climbs exactly as many rungs as it
    takes to clear, the rungs land in stats()/degradations, and the
    degraded engine still gates bit-parity."""
    eng = _engine(chaos=ChaosConfig(persistent_clears_at=2))
    sup = Supervisor(eng, SupervisorConfig(max_retries=1))
    eng.backend.inject("decode_step", kind="persistent")
    done = sup.run(_requests(SPECS))
    st = sup.stats()
    sup.close()
    assert _tokens(done) == _ref()
    assert st["degradation_level"] == 2
    assert sup.degradations == ["spec_off", "prefix_cache_off"]
    assert DEGRADATION_RUNGS[st["degradation_level"]] == "prefix_cache_off"
    assert eng.alloc.in_use == 0


def test_unclearable_fault_exhausts_supervision():
    """A fault nothing clears must end in SupervisionExhausted — loudly,
    not a spin."""
    eng = _engine(chaos=ChaosConfig(persistent_clears_at=99))
    sup = Supervisor(eng, SupervisorConfig(max_retries=1))
    eng.backend.inject("decode_step", kind="persistent")
    with pytest.raises(SupervisionExhausted, match="ladder"):
        sup.run(_requests(SPECS))
    sup.close()


def test_mita_verify_fault_is_retry_safe():
    """MiTA's landmark drafter is stateless, so a verify-step fault can
    be retried without corrupting the stream — the spec'd supervised run
    stays bit-identical to spec_k=0 (the recurrent self-drafters commit
    state at draft time, which is why generic chaos configs gate faults
    at `draft_steps` instead)."""
    base = dataclasses.replace(
        EngineConfig(n_slots=2, pages_per_slot=4, n_pages=16,
                     prefill_chunk=W, sample_device="fused"))
    ref = _tokens(_engine(base).run(_requests(SPECS)))
    ecfg = dataclasses.replace(base, spec_k=3)
    eng = _engine(ecfg, chaos=ChaosConfig(seed=2, p_fault=0.3,
                                          transient_len=2,
                                          ops=("verify_step",)))
    sup = Supervisor(eng, SupervisorConfig(max_retries=3))
    done = sup.run(_requests(SPECS))
    assert _tokens(done) == ref
    assert eng.backend.n_injected > 0
    assert eng.alloc.in_use == 0


# --------------------------------------------- admission robustness paths --

def test_deadline_expired_finishes_with_reason():
    eng = _engine()
    sup = Supervisor(eng)
    reqs = _requests(SPECS)
    ok = [sup.submit(dataclasses.replace(
        r, deadline_ms=0.01 if r.rid == 1 else None)) for r in reqs]
    assert all(ok)
    time.sleep(0.005)
    while sup.step():
        pass
    by_rid = {f.rid: f for f in eng.finished}
    assert by_rid[1].reason == "deadline_expired" and by_rid[1].cancelled
    assert {r: f.tokens.tolist() for r, f in by_rid.items()
            if f.reason == "complete"} \
        == {r: t for r, t in _ref().items() if r != 1}
    assert sup.stats()["deadline_expired"] == 1
    assert eng.alloc.in_use == 0


def test_rejection_surfaces_through_supervisor():
    eng = _engine()
    sup = Supervisor(eng)
    huge = Request(rid=0, prompt=np.zeros(50 * W, np.int32),
                   max_new_tokens=4)
    assert sup.submit(huge) is False
    assert eng.finished[0].reason == "rejected"
    assert sup.stats()["rejected"] == 1


def test_allocator_invariant_error_is_never_retried(monkeypatch):
    eng = _engine()
    sup = Supervisor(eng, SupervisorConfig(max_retries=5))
    monkeypatch.setattr(eng, "step", lambda: (_ for _ in ()).throw(
        AllocatorInvariantError("page accounting corrupt")))
    with pytest.raises(AllocatorInvariantError):
        sup.step()
    assert sup.stats()["retries"] == 0 and sup.n_faults == 0


# -------------------------------------------------- pressure & stragglers --

def test_alloc_spikes_drain_via_stall_relief():
    """Spikes grab REAL pages every dispatch; stall relief must release
    them so the trace completes, with parity and zero leaks."""
    eng = _engine(chaos=ChaosConfig(alloc_spike_every=1,
                                    alloc_spike_pages=3,
                                    alloc_spike_len=50))
    sup = Supervisor(eng, SupervisorConfig(stall_steps=3))
    done = sup.run(_requests(SPECS))
    assert _tokens(done) == _ref()
    assert eng.backend.n_spikes >= 1
    assert eng.alloc.in_use == 0 and eng.alloc.refs == {}


def test_straggler_counter_reaches_stats():
    eng = _engine()
    sup = Supervisor(eng)
    for dt in (0.01, 0.01, 0.01, 0.01, 1.0):
        sup.timer.observe(dt)
    assert sup.stats()["stragglers"] == 1


def test_injected_straggler_is_detected():
    """`p_slow` dispatch delays must trip the shared StepTimer EWMA."""
    chaos = ChaosConfig(seed=4, p_slow=0.12, slow_s=0.3,
                        ops=("decode_step",))
    eng = _engine(chaos=chaos)
    sup = Supervisor(eng, SupervisorConfig(straggler_threshold=3.0))
    done = sup.run(_requests(SPECS))
    assert _tokens(done) == _ref()
    assert eng.backend.n_slowed >= 1
    assert sup.stats()["stragglers"] >= 1


# ------------------------------------------------------------ crash recovery --

def test_snapshot_restore_roundtrip_is_bit_exact(tmp_path):
    """Kill mid-trace, restore on a fresh engine from the journal file:
    the union of pre-kill and post-restore streams is bit-identical to
    the uninterrupted run, counters carry over, deadlines re-arm."""
    eng = _engine(chaos=ChaosConfig(seed=1, p_fault=0.25, transient_len=1,
                                    ops=("decode_step",)))
    sup = Supervisor(eng, SupervisorConfig(max_retries=2))
    for r in _requests(SPECS):
        sup.submit(r)
    for _ in range(5):
        if not sup.step():
            break
    path = str(tmp_path / "snap.json")
    sup.save_snapshot(path)
    assert not os.path.exists(path + ".tmp"), "atomic write left its tmp"
    snap = Supervisor.load_snapshot(path)

    eng2 = _engine()
    sup2 = Supervisor(eng2)
    sup2.restore(snap)
    while sup2.step():
        pass
    assert _tokens(eng2.finished) == _ref()
    assert eng2.n_retries == snap["counters"]["retries"]
    assert eng2.alloc.in_use == 0 and eng2.alloc.refs == {}


def test_restore_validation_errors():
    eng = _engine()
    sup = Supervisor(eng)
    for r in _requests(SPECS):
        sup.submit(r)
    sup.step()
    snap = sup.snapshot()

    with pytest.raises(ValueError, match="fresh engine"):
        sup.restore(snap)           # this engine already has work

    bad = dict(snap, backend="nope")
    with pytest.raises(ValueError, match="backend"):
        Supervisor(_engine()).restore(bad)

    if any(row["tokens"] for row in snap["requests"]):
        mono = _engine(EngineConfig(n_slots=2, pages_per_slot=4,
                                    n_pages=12, prefill_chunk=0))
        with pytest.raises(ValueError, match="chunked prefill"):
            Supervisor(mono).restore(snap)


def test_snapshot_of_drained_engine_restores_finished_only():
    eng = _engine()
    sup = Supervisor(eng)
    sup.run(_requests(SPECS))
    snap = sup.snapshot()
    assert snap["requests"] == []
    eng2 = _engine()
    sup2 = Supervisor(eng2)
    sup2.restore(snap)
    assert not sup2.step()          # nothing to do
    assert _tokens(eng2.finished) == _ref()
