"""Compressed DP gradient reduction: correctness + wire-byte verification
(runs in a subprocess with 8 fake devices, like test_distributed)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim.compression import dequantize_int8, quantize_int8

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(1e-3, 1e3))
def test_quantize_roundtrip_bound(seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = quantize_int8(g)
    err = np.max(np.abs(np.asarray(dequantize_int8(q, s) - g)))
    assert err <= float(s) * 0.5 + 1e-9   # half-ulp of the quant grid


def _run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_compressed_allreduce_matches_psum_and_compresses_wire():
    print(_run_sub("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compression import compressed_grad_mean
        from repro.analysis.roofline import collective_bytes

        mesh = jax.make_mesh((8,), ("data",))
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1024, 64)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (259,))}

        @functools.partial(shard_map, mesh=mesh, in_specs=(P(),),
                           out_specs=P(), check_rep=False)
        def comp(gg):
            out, _ = compressed_grad_mean(gg, "data", 8)
            return out

        @functools.partial(shard_map, mesh=mesh, in_specs=(P(),),
                           out_specs=P(), check_rep=False)
        def exact(gg):
            return jax.tree.map(lambda x: jax.lax.pmean(x, "data"), gg)

        with mesh:
            r_comp = jax.jit(comp)(g)
            r_exact = jax.jit(exact)(g)
            # identical inputs on every shard -> mean == input; quantization
            # error bounded by one grid step
            for k in g:
                q_err = np.max(np.abs(np.asarray(r_comp[k] - r_exact[k])))
                tol = 2.5 * float(jnp.max(jnp.abs(g[k]))) / 127.0
                assert q_err < tol, (k, q_err, tol)

            cb_comp = collective_bytes(jax.jit(comp).lower(g).compile().as_text())
            cb_exact = collective_bytes(jax.jit(exact).lower(g).compile().as_text())
            wire_comp = sum(cb_comp.values())
            wire_exact = sum(cb_exact.values())
            print("wire bytes: compressed", wire_comp, "exact", wire_exact)
            assert wire_comp < wire_exact / 2.5, (wire_comp, wire_exact)
        print("OK")
    """))


@pytest.mark.slow
def test_dp_compressed_training_converges():
    print(_run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.optim import OptConfig, adamw_init, adamw_update
        from repro.optim.compression import (dp_compressed_train_step,
                                             init_error_feedback)
        from repro.models.modules import ModelConfig, AttnConfig
        from repro.models.transformer import lm_init, lm_loss
        from repro.data import DataConfig, synthetic_batch

        mesh = jax.make_mesh((8,), ("data",))
        cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv=2,
                          d_ff=128, vocab=128,
                          attn=AttnConfig(window=16, k=16))
        # lr 2e-3 over the full scheduled horizon: this test first ran when
        # the hypothesis collection errors were fixed, and at lr 1e-3 / 25
        # of 30 scheduled steps its loss drop sat within noise of the 0.3
        # bound (0.294-0.309 depending on the RNG stream)
        ocfg = OptConfig(lr=2e-3, warmup_steps=2, total_steps=30)
        params = lm_init(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        err = init_error_feedback(params)
        step = jax.jit(dp_compressed_train_step(
            lambda p, b: lm_loss(p, b, cfg),
            lambda g, o, p: adamw_update(g, o, p, ocfg), mesh))
        data = DataConfig(vocab=128, seq_len=64, global_batch=8)
        with mesh:
            losses = []
            for i in range(30):
                params, opt, err, m = step(params, opt, err,
                                           synthetic_batch(data, i))
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.3, losses[::6]
        print("loss", losses[0], "->", losses[-1], "OK")
    """))
