"""Hypothesis shim: property tests run on a bare interpreter.

Prefers the real `hypothesis` (pin in requirements-dev.txt) and falls back
to a tiny seeded-random emulation of the subset this suite uses
(`given` + `settings` + integers/floats/sampled_from/booleans strategies).
The fallback draws `max_examples` samples from a per-test deterministic
RNG — no shrinking, no database, but the properties still execute.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare interpreters
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    st = _Strategies()

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 10)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in strategies), **kwargs)
            # NOT functools.wraps: __wrapped__ would make pytest resolve the
            # original signature and demand the drawn params as fixtures
            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(wrapper, attr, getattr(fn, attr))
            return wrapper
        return deco
