"""Core MiTA semantics: oracle equivalences + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.baselines import (full_attention, linear_attention,
                                  local_attention, moba_attention)
from repro.core.combine import (Partial, combine, partial_from_logits,
                                partial_from_scores)
from repro.core.mita import MiTAConfig, mita_attention
from repro.core.mita_sparse import aux_load_balance, mita_attention_sparse

RNG = jax.random.PRNGKey(0)


def qkv(b=2, h=2, n=64, d=16, key=RNG):
    return tuple(jax.random.normal(k, (b, h, n, d))
                 for k in jax.random.split(key, 3))


# ----------------------------------------------------------- combine math ---

@settings(max_examples=20, deadline=None)
@given(st.integers(2, 5), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_combine_equals_concat_softmax(n1, n2, seed):
    """Branch-wise online-softmax combine == one softmax over the concat."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = 4
    l1 = jax.random.normal(k1, (3, n1)) * 3
    v1 = jax.random.normal(k2, (3, n1, d))
    l2 = jax.random.normal(k3, (3, n2)) * 3
    v2 = jax.random.normal(k4, (3, n2, d))
    out = combine([partial_from_logits(l1, v1), partial_from_logits(l2, v2)])
    cat_l = jnp.concatenate([l1, l2], axis=-1)
    cat_v = jnp.concatenate([v1, v2], axis=-2)
    p = jax.nn.softmax(cat_l, axis=-1)
    ref = jnp.einsum("bn,bnd->bd", p, cat_v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_combine_fully_masked_is_zero():
    l = jnp.full((2, 4), -jnp.inf)
    v = jnp.ones((2, 4, 3))
    out = combine([partial_from_logits(l, v, mask=jnp.zeros((2, 4), bool))])
    assert np.all(np.asarray(out) == 0.0)


# -------------------------------------------------------- MiTA invariants ---

def test_route_only_full_k_equals_full_attention():
    q, k, v = qkv()
    cfg = MiTAConfig(m=4, k=64, route_only=True)
    out = mita_attention(q, k, v, cfg)
    ref = full_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8]),
       st.sampled_from([8, 16]), st.integers(1, 2))
def test_causal_no_future_leak(seed, m, k_width, s):
    """Property: causal MiTA output at position t is independent of all
    inputs at positions > t."""
    key = jax.random.PRNGKey(seed)
    b, h, n, d = 1, 2, 64, 8
    q, k, v = (jax.random.normal(kk, (b, h, n, d))
               for kk in jax.random.split(key, 3))
    cfg = MiTAConfig(m=m, k=k_width, s=s, causal=True)
    out1 = mita_attention(q, k, v, cfg)
    cut = 40
    k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed + 1), 3)
    q2 = q.at[..., cut:, :].set(jax.random.normal(k2, (b, h, n - cut, d)))
    kk2 = k.at[..., cut:, :].set(jax.random.normal(k3, (b, h, n - cut, d)))
    v2 = v.at[..., cut:, :].set(jax.random.normal(k4, (b, h, n - cut, d)))
    out2 = mita_attention(q2, kk2, v2, cfg)
    # positions strictly before the first window containing `cut`
    w = n // m
    safe = (cut // w) * w
    np.testing.assert_allclose(np.asarray(out1[..., :safe, :]),
                               np.asarray(out2[..., :safe, :]), atol=1e-6)


@pytest.mark.parametrize("impl", ["sorted", "capacity", "pallas"])
@pytest.mark.parametrize("causal,s", [(False, 1), (True, 1), (True, 2)])
def test_sparse_matches_reference(impl, causal, s):
    q, k, v = qkv(n=128)
    cfg = MiTAConfig(m=8, k=16, s=s, causal=causal)
    ref = mita_attention(q, k, v, cfg)
    out = mita_attention_sparse(q, k, v, cfg, impl=impl, block_q=32,
                                expert_span=8, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_sparse_gqa_group_landmarks():
    b, hkv, g, n, d = 2, 2, 3, 64, 8
    key = RNG
    q = jax.random.normal(key, (b, hkv, g, n, d))
    k, v = (jax.random.normal(kk, (b, hkv, 1, n, d))
            for kk in jax.random.split(key, 2))
    q_lm = jnp.mean(q, axis=2, keepdims=True)
    cfg = MiTAConfig(m=8, k=8, causal=True)
    ref = mita_attention(q, k, v, cfg, q_landmarks=q_lm)
    for impl in ("sorted", "capacity", "pallas"):
        out = mita_attention_sparse(q, k, v, cfg, impl=impl, block_q=32,
                                    expert_span=8, capacity_factor=8.0,
                                    q_landmarks=q_lm)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, err_msg=impl)


def test_ablation_variants_finite():
    q, k, v = qkv()
    for cfg in [MiTAConfig(m=8, k=8, compress_only=True),
                MiTAConfig(m=8, k=8, route_only=True),
                MiTAConfig(m=8, k=8, causal=True, include_local=False),
                MiTAConfig(m=8, k=8, landmark="random")]:
        out = mita_attention(q, k, v, cfg)
        assert np.isfinite(np.asarray(out)).all(), cfg


def test_aux_load_balance_uniform_is_one():
    # perfectly uniform assignment -> loss ~ 1, skewed -> > 1
    n, m = 512, 8
    r_uniform = jnp.tile(jnp.eye(m), (n // m, 1)) * 10.0
    cfg = MiTAConfig(m=m, k=4)
    v = float(aux_load_balance(r_uniform[None], cfg))
    assert abs(v - 1.0) < 0.05
    r_skew = jnp.zeros((n, m)).at[:, 0].set(10.0)
    v2 = float(aux_load_balance(r_skew[None], cfg))
    assert v2 > 2.0


# -------------------------------------------------------------- baselines ---

def test_moba_all_blocks_equals_full_causal():
    q, k, v = qkv()
    ref = full_attention(q, k, v, causal=True)
    out = moba_attention(q, k, v, block_size=8, top_blocks=7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_local_attention_first_block_matches_full():
    q, k, v = qkv()
    ref = full_attention(q, k, v, causal=True)
    out = local_attention(q, k, v, window=16, causal=True)
    np.testing.assert_allclose(np.asarray(out[..., :16, :]),
                               np.asarray(ref[..., :16, :]), atol=2e-5)


def test_linear_attention_causal_matches_bidir_prefix():
    """Causal linear attention at the last position == bidirectional over
    the full sequence (the cumulative state covers everything)."""
    q, k, v = qkv(n=32)
    c = linear_attention(q, k, v, causal=True)
    b = linear_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(c[..., -1, :]),
                               np.asarray(b[..., -1, :]), rtol=1e-4,
                               atol=1e-5)


def test_agent_equals_compress_only():
    """Agent Attention is MiTA's compress-only degenerate case (paper §4)."""
    q, k, v = qkv()
    cfg = MiTAConfig(m=8, k=8, compress_only=True)
    out = mita_attention(q, k, v, cfg)
    # manual agent attention: agents = pooled queries
    from repro.core.landmarks import pool1d
    import math
    d = q.shape[-1]
    agents = pool1d(q, 8)
    agent_v = full_attention(agents, k, v)
    out_ref = full_attention(q, agents, agent_v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=2e-5)
