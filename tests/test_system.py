"""End-to-end system tests: the real launch drivers on reduced configs."""

import numpy as np
import pytest


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main
    rc = main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "6",
               "--batch", "4", "--seq", "64",
               "--ckpt-dir", str(tmp_path / "ckpt"), "--ckpt-every", "3"])
    assert rc == 0
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    assert mgr.latest_step() == 6


def test_train_resume_after_failure(tmp_path):
    from repro.launch.train import main
    ckpt = str(tmp_path / "ckpt")
    with pytest.raises(RuntimeError, match="simulated node failure"):
        main(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "8",
              "--batch", "4", "--seq", "64", "--ckpt-dir", ckpt,
              "--ckpt-every", "2", "--simulate-failure", "5"])
    # restart resumes from the last checkpoint and completes
    rc = main(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "8",
               "--batch", "4", "--seq", "64", "--ckpt-dir", ckpt,
               "--ckpt-every", "2", "--resume"])
    assert rc == 0


def test_serve_driver_end_to_end(capsys):
    from repro.launch.serve import main
    rc = main(["--arch", "qwen3-0.6b", "--smoke", "--batch", "2",
               "--prompt-len", "64", "--gen", "8"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "decode:" in out and "tok/s" in out


def test_training_reduces_loss():
    """A small MiTA transformer must actually learn the synthetic stream."""
    import jax
    from repro.configs.registry import ShapeSpec, get_arch
    from repro.data import DataConfig, synthetic_batch
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_cell, family_fns
    from repro.optim import OptConfig, adamw_init

    arch = get_arch("tinyllama-1.1b", smoke=True)
    mesh = make_host_mesh(1, 1)
    shape = ShapeSpec("t", "train", 64, 8)
    cell = build_cell(arch, shape, mesh,
                      opt_cfg=OptConfig(lr=1e-3, warmup_steps=2,
                                        total_steps=40))
    fns = family_fns(arch)
    with mesh:
        params = fns["init"](jax.random.PRNGKey(0))
        opt = adamw_init(params)
        step = jax.jit(cell.fn)
        dcfg = DataConfig(vocab=arch.model.vocab, seq_len=64, global_batch=8)
        losses = []
        for i in range(30):
            b = synthetic_batch(dcfg, i)
            params, opt, m = step(params, opt, b)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]
