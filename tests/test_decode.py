"""Decode/train parity — the serving-correctness contract.

Causal MiTA evaluated incrementally (cache + landmark maintenance) must
equal the training-time full-sequence computation at every position, for
every model family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mita import MiTAConfig, mita_attention
from repro.core import mita_decode as mdec
from repro.models.modules import AttnConfig, ModelConfig


def test_core_decode_matches_causal_mita():
    B, Hkv, G, N, d = 2, 2, 2, 64, 16
    w, K = 8, 8
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, Hkv, G, N, d))
    k, v = (jax.random.normal(kk, (B, Hkv, 1, N, d))
            for kk in jax.random.split(key, 2))
    q_lm = jnp.mean(q, axis=2, keepdims=True)
    cfg = MiTAConfig(m=N // w, k=K, s=1, causal=True)
    train_out = mita_attention(q, k, v, cfg, q_landmarks=q_lm)

    dcfg = mdec.DecodeConfig(window=w, k=K, s=1)
    st = mdec.init_decode_state(B, Hkv, d, N, dcfg, jnp.float32)
    step = jax.jit(lambda s, qq, kk, vv: mdec.mita_decode_step(s, qq, kk, vv, dcfg))
    for t in range(N):
        o, st = step(st, q[:, :, :, t], k[:, :, 0, t], v[:, :, 0, t])
        np.testing.assert_allclose(
            np.asarray(o), np.asarray(train_out[:, :, :, t]), atol=3e-5,
            err_msg=f"t={t}")


def test_prefill_then_decode_matches_forward():
    """lm_prefill + lm_decode_step == lm_forward logits, position by position."""
    from repro.models.transformer import (lm_init, lm_forward, lm_prefill,
                                          lm_decode_step)
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                      vocab=97,
                      attn=AttnConfig(window=8, k=8, backend="mita_ref"))
    rng = jax.random.PRNGKey(0)
    params = lm_init(rng, cfg)
    B, N, extra = 2, 48, 8
    tokens = jax.random.randint(rng, (B, N + extra), 0, cfg.vocab)
    ref, _ = lm_forward(params, tokens, cfg)
    last, states = lm_prefill(params, tokens[:, :N], cfg, capacity=N + extra)
    np.testing.assert_allclose(np.asarray(last), np.asarray(ref[:, N - 1]),
                               atol=3e-4)
    step = jax.jit(lambda p, s, t, pos: lm_decode_step(p, s, t, pos, cfg))
    for i in range(extra):
        logits, states = step(params, states, tokens[:, N + i],
                              jnp.asarray(N + i))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref[:, N + i]), atol=3e-4,
                                   err_msg=f"decode step {i}")


def test_full_attention_decode_state():
    """Quadratic-baseline decode cache is exact too."""
    from repro.core.baselines import full_attention
    B, Hkv, G, N, d = 1, 2, 1, 32, 8
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (B, Hkv, G, N, d))
    k, v = (jax.random.normal(kk, (B, Hkv, 1, N, d))
            for kk in jax.random.split(key, 2))
    ref = full_attention(q, jnp.broadcast_to(k, q.shape),
                         jnp.broadcast_to(v, q.shape), causal=True)
    st = mdec.init_full_state(B, Hkv, d, N, jnp.float32)
    for t in range(N):
        o, st = mdec.full_decode_step(st, q[:, :, :, t], k[:, :, 0, t],
                                      v[:, :, 0, t])
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref[:, :, :, t]),
                                   atol=2e-5)


def test_whisper_decode_parity():
    from repro.models import whisper as wh
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128,
                      vocab=97,
                      attn=AttnConfig(window=8, k=8, backend="mita_ref"))
    B, T_enc, N = 2, 48, 24
    params = wh.whisper_init(jax.random.PRNGKey(0), cfg, t_enc=T_enc)
    rng = jax.random.PRNGKey(4)
    audio = jax.random.normal(rng, (B, T_enc, cfg.d_model))
    tokens = jax.random.randint(rng, (B, N), 0, cfg.vocab)
    enc = wh.whisper_encode(params, audio, cfg)
    ref = wh.whisper_decode_train(params, enc, tokens, cfg)
    st = wh.whisper_init_serve(params, audio, cfg, capacity=32)
    step = jax.jit(lambda p, s, t, pos: wh.whisper_decode_step(p, s, t, pos, cfg))
    for i in range(N):
        lg, st = step(params, st, tokens[:, i], jnp.asarray(i))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, i]),
                                   atol=3e-4, err_msg=f"step {i}")


def test_ssd_chunked_equals_recurrence():
    """State-space duality: chunked (train) form == recurrent (decode) form."""
    from repro.models.mamba2 import ssd_chunked
    B, L, H, P, S = 2, 96, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.5
    b = jax.random.normal(ks[3], (B, L, S))
    c = jax.random.normal(ks[4], (B, L, S))
    y = ssd_chunked(x, dt, a_log, b, c, chunk=32)
    da = dt * (-jnp.exp(a_log))[None, None, :]
    h = jnp.zeros((B, H, P, S))
    outs = []
    for t in range(L):
        h = h * jnp.exp(da[:, t])[..., None, None] + jnp.einsum(
            "bh,bhp,bs->bhps", dt[:, t], x[:, t], b[:, t])
        outs.append(jnp.einsum("bhps,bs->bhp", h, c[:, t]))
    ref = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_external_finalize_decode():
    """External (serve-loop) landmark finalize: exact parity with inline
    finalize at every non-window-final position; the documented 1/w
    exception (last token of each window sees one fewer expert) holds."""
    B, Hkv, G, N, d = 1, 2, 1, 64, 16
    w, K = 8, 8
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (B, Hkv, G, N, d))
    k, v = (jax.random.normal(kk, (B, Hkv, 1, N, d))
            for kk in jax.random.split(key, 2))

    inline = mdec.DecodeConfig(window=w, k=K, s=1)
    ext = mdec.DecodeConfig(window=w, k=K, s=1, external_finalize=True)
    st_i = mdec.init_decode_state(B, Hkv, d, N, inline, jnp.float32)
    st_e = mdec.init_decode_state(B, Hkv, d, N, ext, jnp.float32)
    step_i = jax.jit(lambda s, qq, kk_, vv: mdec.mita_decode_step(s, qq, kk_, vv, inline))
    step_e = jax.jit(lambda s, qq, kk_, vv: mdec.mita_decode_step(s, qq, kk_, vv, ext))
    fin = jax.jit(lambda s: mdec.mita_finalize_if_due(s, ext))

    for t in range(N):
        st_e = fin(st_e)   # serve loop: finalize before the step when due
        o_i, st_i = step_i(st_i, q[:, :, :, t], k[:, :, 0, t], v[:, :, 0, t])
        o_e, st_e = step_e(st_e, q[:, :, :, t], k[:, :, 0, t], v[:, :, 0, t])
        if (t + 1) % w != 0:   # non-window-final tokens: exact parity
            np.testing.assert_allclose(np.asarray(o_e), np.asarray(o_i),
                                       atol=3e-5, err_msg=f"t={t}")
    # states converge after each boundary: landmark caches identical
    np.testing.assert_allclose(np.asarray(fin(st_e).lm_q),
                               np.asarray(st_i.lm_q), atol=3e-5)
