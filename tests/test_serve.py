"""Continuous-batching serving engine: paged-cache parity + scheduler
invariants.

The serving-correctness contract has two layers:
  * core: `mita_paged_decode_step` over a shared pool with arbitrary page
    assignment must equal `mita_decode_step` on a per-request monolithic
    cache, at every position, for any slot activity pattern;
  * engine: greedy tokens emitted through the scheduler (mixed lengths,
    slot reuse, page recycling) must be IDENTICAL to the static-batch
    `launch.serve` baseline for every request.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mita_decode as mdec
from repro.launch.serve import static_generate
from repro.models import transformer as tfm
from repro.models.modules import AttnConfig, ModelConfig
from repro.serve import EngineConfig, Request, ServingEngine

W, K = 8, 8


def _cfg(backend="mita_ref", external=False):
    return ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                       vocab=97,
                       attn=AttnConfig(window=W, k=K, backend=backend,
                                       external_finalize=external))


# ------------------------------------------------------------------- core --

def test_paged_step_matches_monolithic():
    """Shared pool + shuffled page tables == per-request monolithic caches,
    every position."""
    B, Hkv, G, N, d = 3, 2, 2, 48, 16
    cfg = mdec.DecodeConfig(window=W, k=K, s=1)
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, Hkv, G, N, d))
    k, v = (jax.random.normal(kk, (B, Hkv, N, d))
            for kk in jax.random.split(key, 2))
    m = N // W
    n_pages = B * m + 3
    table = np.random.default_rng(0).permutation(n_pages)[: B * m]
    page_table = jnp.asarray(table.reshape(B, m), jnp.int32)

    st_m = mdec.init_decode_state(B, Hkv, d, N, cfg, jnp.float32)
    st_p = mdec.init_paged_state(Hkv, d, n_pages, B, m, cfg, jnp.float32)
    step_m = jax.jit(lambda s, *a: mdec.mita_decode_step(s, *a, cfg))
    step_p = jax.jit(lambda s, *a: mdec.mita_paged_decode_step(s, *a, cfg))
    t = jnp.zeros((B,), jnp.int32)
    active = jnp.ones((B,), bool)
    for i in range(N):
        o_m, st_m = step_m(st_m, q[:, :, :, i], k[:, :, i], v[:, :, i])
        o_p, st_p = step_p(st_p, q[:, :, :, i], k[:, :, i], v[:, :, i],
                           page_table, t, active)
        np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_m),
                                   atol=1e-5, err_msg=f"t={i}")
        t = t + 1


def test_paged_staggered_slots():
    """Slots at different progress in ONE fused step: a slot admitted
    mid-flight matches a fresh monolithic cache; inactive slots emit
    zeros."""
    B, Hkv, G, N, d = 2, 2, 1, 32, 8
    cfg = mdec.DecodeConfig(window=W, k=K, s=1)
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (B, Hkv, G, N, d))
    k, v = (jax.random.normal(kk, (B, Hkv, N, d))
            for kk in jax.random.split(key, 2))
    m = N // W
    st_p = mdec.init_paged_state(Hkv, d, 2 * m, B, m, cfg, jnp.float32)
    page_table = jnp.asarray(np.arange(2 * m).reshape(B, m), jnp.int32)
    refs = [mdec.init_decode_state(1, Hkv, d, N, cfg, jnp.float32)
            for _ in range(B)]
    step_m = jax.jit(lambda s, *a: mdec.mita_decode_step(s, *a, cfg))
    step_p = jax.jit(lambda s, *a: mdec.mita_paged_decode_step(s, *a, cfg))
    offs = [0, 11]                       # slot 1 joins at step 11
    t = jnp.zeros((B,), jnp.int32)
    for i in range(N):
        act = np.array([offs[s] <= i < offs[s] + N for s in range(B)])
        qi = jnp.stack([q[s, :, :, (i - offs[s]) % N] for s in range(B)])
        ki = jnp.stack([k[s, :, (i - offs[s]) % N] for s in range(B)])
        vi = jnp.stack([v[s, :, (i - offs[s]) % N] for s in range(B)])
        o_p, st_p = step_p(st_p, qi, ki, vi, page_table, t, jnp.asarray(act))
        for s in range(B):
            if act[s]:
                o_m, refs[s] = step_m(refs[s], qi[s:s + 1], ki[s:s + 1],
                                      vi[s:s + 1])
                np.testing.assert_allclose(np.asarray(o_p[s]),
                                           np.asarray(o_m[0]), atol=1e-5,
                                           err_msg=f"i={i} slot={s}")
            else:
                assert np.all(np.asarray(o_p[s]) == 0.0)
        t = t + jnp.asarray(act)


def test_paged_external_finalize_matches_monolithic():
    B, Hkv, G, N, d = 2, 2, 1, 32, 8
    cfg = mdec.DecodeConfig(window=W, k=K, s=1, external_finalize=True)
    key = jax.random.PRNGKey(13)
    q = jax.random.normal(key, (B, Hkv, G, N, d))
    k, v = (jax.random.normal(kk, (B, Hkv, N, d))
            for kk in jax.random.split(key, 2))
    m = N // W
    st_p = mdec.init_paged_state(Hkv, d, 2 * m, B, m, cfg, jnp.float32)
    st_m = mdec.init_decode_state(B, Hkv, d, N, cfg, jnp.float32)
    page_table = jnp.asarray(np.arange(2 * m).reshape(B, m), jnp.int32)
    step_m = jax.jit(lambda s, *a: mdec.mita_decode_step(s, *a, cfg))
    step_p = jax.jit(lambda s, *a: mdec.mita_paged_decode_step(s, *a, cfg))
    fin_m = jax.jit(lambda s: mdec.mita_finalize_if_due(s, cfg))
    fin_p = jax.jit(lambda s, *a: mdec.mita_paged_finalize(s, *a, cfg))
    t = jnp.zeros((B,), jnp.int32)
    active = jnp.ones((B,), bool)
    m_done = np.zeros(B, int)
    for i in range(N):
        tn = np.full(B, i)
        due = (tn % W == 0) & (tn // W > m_done)
        if due.any():
            st_p = fin_p(st_p, page_table, t, jnp.asarray(due))
            m_done = np.where(due, tn // W, m_done)
        st_m = fin_m(st_m)
        o_m, st_m = step_m(st_m, q[:, :, :, i], k[:, :, i], v[:, :, i])
        o_p, st_p = step_p(st_p, q[:, :, :, i], k[:, :, i], v[:, :, i],
                           page_table, t, active)
        np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_m),
                                   atol=1e-5, err_msg=f"t={i}")
        t = t + 1


def test_pack_prefill_matches_monolithic_prefill():
    """Mid-window prefill packed into shuffled pages continues exactly
    like a monolithic prefill state."""
    B, Hkv, G, N, d = 2, 2, 2, 48, 16
    cfg = mdec.DecodeConfig(window=W, k=K, s=1)
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (B, Hkv, G, N, d))
    k, v = (jax.random.normal(kk, (B, Hkv, 1, N, d))
            for kk in jax.random.split(key, 2))
    n_pre = 20                                 # partial final window
    cap_pre = ((n_pre + W - 1) // W) * W
    m = N // W
    n_pages = B * m + 2
    table = np.random.default_rng(1).permutation(n_pages)[: B * m]
    page_table = jnp.asarray(table.reshape(B, m), jnp.int32)

    st_p = mdec.init_paged_state(Hkv, d, n_pages, B, m, cfg, jnp.float32)
    refs = []
    for s in range(B):
        pre = mdec.mita_prefill_state(q[s:s + 1, :, :, :n_pre],
                                      k[s:s + 1, :, :, :n_pre],
                                      v[s:s + 1, :, :, :n_pre], cfg,
                                      capacity=cap_pre)
        st_p = mdec.pack_prefill_into_pages(
            st_p, pre, s, page_table[s, : cap_pre // W], cfg)
        refs.append(mdec.mita_prefill_state(
            q[s:s + 1, :, :, :n_pre], k[s:s + 1, :, :, :n_pre],
            v[s:s + 1, :, :, :n_pre], cfg, capacity=N))
    t = jnp.full((B,), n_pre, jnp.int32)
    active = jnp.ones((B,), bool)
    step_m = jax.jit(lambda s, *a: mdec.mita_decode_step(s, *a, cfg))
    step_p = jax.jit(lambda s, *a: mdec.mita_paged_decode_step(s, *a, cfg))
    for i in range(n_pre, N):
        o_p, st_p = step_p(st_p, q[:, :, :, i], k[:, :, 0, i], v[:, :, 0, i],
                           page_table, t, active)
        for s in range(B):
            o_m, refs[s] = step_m(refs[s], q[s:s + 1, :, :, i],
                                  k[s:s + 1, :, 0, i], v[s:s + 1, :, 0, i])
            np.testing.assert_allclose(np.asarray(o_p[s]), np.asarray(o_m[0]),
                                       atol=1e-5, err_msg=f"i={i} slot={s}")
        t = t + 1


# ----------------------------------------------------------------- engine --

def _requests(cfg, n, lens, gens, seed=7):
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(21)
    reqs = []
    for i in range(n):
        ln = int(rng.choice(lens))
        p = np.asarray(jax.random.randint(jax.random.fold_in(key, i), (ln,),
                                          0, cfg.vocab))
        reqs.append(Request(rid=i, prompt=p,
                            max_new_tokens=int(rng.choice(gens))))
    return reqs


def test_engine_matches_static_greedy():
    """Engine greedy tokens == static-batch baseline tokens, per request,
    with more requests than slots (slot reuse mid-trace)."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    B, N, gen = 4, 24, 10
    prompts = jax.random.randint(jax.random.PRNGKey(7), (B, N), 0, cfg.vocab)
    pages = (N + gen + W - 1) // W
    scfg = _cfg(external=True)      # engine default is external finalize
    ref, _ = static_generate(params, scfg, prompts, gen, capacity=pages * W)

    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=3, pages_per_slot=pages, n_pages=3 * pages + 2))
    done = eng.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                            max_new_tokens=gen) for i in range(B)])
    assert len(done) == B
    for i, f in enumerate(done):
        np.testing.assert_array_equal(f.tokens, ref[i], err_msg=f"req {i}")


def test_engine_inline_finalize_matches_static():
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    B, N, gen = 3, 16, 9
    prompts = jax.random.randint(jax.random.PRNGKey(8), (B, N), 0, cfg.vocab)
    pages = (N + gen + W - 1) // W
    ref, _ = static_generate(params, cfg, prompts, gen, capacity=pages * W)
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=2, pages_per_slot=pages, n_pages=2 * pages,
        finalize="inline"))
    done = eng.run([Request(rid=i, prompt=np.asarray(prompts[i]),
                            max_new_tokens=gen) for i in range(B)])
    for i, f in enumerate(done):
        np.testing.assert_array_equal(f.tokens, ref[i], err_msg=f"req {i}")


def test_engine_mixed_lengths_page_recycling():
    """Mixed prompt/gen lengths through a pool tight enough to force page
    recycling; every request still matches its own single-request static
    decode, and allocator invariants hold after every step."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    pages_per_slot, n_pages = 5, 12
    reqs = _requests(cfg, 8, lens=[8, 16, 24], gens=[2, 5, 9, 13])
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=3, pages_per_slot=pages_per_slot, n_pages=n_pages))
    for r in reqs:
        eng.submit(r)
    while eng.step():
        # invariant: active slots own disjoint page sets from the free list
        owned = [p for pages in eng.slot_pages.values() for p in pages]
        assert len(owned) == len(set(owned)), "page double-booked"
        assert not set(owned) & set(eng.alloc.free), "owned page in free list"
        assert len(owned) + len(eng.alloc.free) == n_pages, "page leaked"
    done = sorted(eng.finished, key=lambda f: f.rid)
    assert len(done) == len(reqs)
    scfg = _cfg(external=True)
    for f, r in zip(done, reqs):
        ref, _ = static_generate(params, scfg, jnp.asarray(r.prompt)[None],
                                 r.max_new_tokens,
                                 capacity=pages_per_slot * W)
        np.testing.assert_array_equal(f.tokens, ref[0],
                                      err_msg=f"req {f.rid}")


def test_engine_temperature_sampling_batch_invariant():
    """Temperature sampling keys derive from (rid, token index): a request
    sampled alone equals the same request sampled inside a busy batch."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg, 4, lens=[16], gens=[6], seed=3)
    for r in reqs:
        r.temperature = 0.9
    ecfg = EngineConfig(n_slots=3, pages_per_slot=4, n_pages=12)
    together = ServingEngine(params, cfg, ecfg).run(reqs)
    alone = ServingEngine(params, cfg, ecfg).run([reqs[2]])
    np.testing.assert_array_equal(together[2].tokens, alone[0].tokens)


def test_engine_rejects_oversized_and_bad_pool():
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=2, pages_per_slot=2, n_pages=4))
    # a never-fitting prompt is structured backpressure, not an exception:
    # submit sheds it with a typed FinishedRequest(reason="rejected")
    assert eng.submit(Request(rid=0, prompt=np.zeros(16, np.int32),
                              max_new_tokens=8)) is False
    shed = eng.finished[-1]
    assert shed.rid == 0 and shed.reason == "rejected"
    assert not shed.cancelled and len(shed.tokens) == 0
    assert eng.stats()["rejected"] == 1
    assert "pages" in eng.reject_reasons[0]
    # the rid is NOT burned: a right-sized resubmission is accepted
    assert eng.submit(Request(rid=0, prompt=np.zeros(8, np.int32),
                              max_new_tokens=4)) is True
    # malformed submissions are caller bugs and still raise
    with pytest.raises(ValueError, match="non-empty"):
        eng.submit(Request(rid=1, prompt=np.zeros(0, np.int32),
                           max_new_tokens=4))
    with pytest.raises(ValueError, match="in flight"):
        eng.submit(Request(rid=0, prompt=np.zeros(8, np.int32),
                           max_new_tokens=4))
    with pytest.raises(ValueError, match="deadlock"):
        ServingEngine(params, cfg, EngineConfig(
            n_slots=2, pages_per_slot=8, n_pages=4))
    with pytest.raises(ValueError, match="MiTA"):
        full = dataclasses.replace(
            cfg, attn=dataclasses.replace(cfg.attn, backend="full"))
        ServingEngine(params, full, EngineConfig())


def test_engine_fused_sampling_bit_identical_to_host():
    """On-device sampling (`sample_device="fused"`): the engine downloads
    [S] int32 tokens instead of [S, V] logits, and every request's greedy
    tokens are BIT-identical to the host-sampling engine and the static
    baseline.  Mixed lengths force slot reuse mid-trace."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg, 8, lens=[8, 16, 24], gens=[2, 5, 9, 13])
    ecfg = EngineConfig(n_slots=3, pages_per_slot=5, n_pages=12)
    host = ServingEngine(params, cfg, ecfg).run(reqs)
    fused = ServingEngine(
        params, cfg,
        dataclasses.replace(ecfg, sample_device="fused")).run(reqs)
    assert len(fused) == len(reqs)
    for h, f in zip(host, fused):
        np.testing.assert_array_equal(f.tokens, h.tokens,
                                      err_msg=f"req {h.rid}")
    scfg = _cfg(external=True)
    for f, r in zip(fused, reqs):
        ref, _ = static_generate(params, scfg, jnp.asarray(r.prompt)[None],
                                 r.max_new_tokens, capacity=5 * W)
        np.testing.assert_array_equal(f.tokens, ref[0],
                                      err_msg=f"req {f.rid} vs static")


def test_engine_fused_temperature_matches_host_and_batching():
    """Fused temperature sampling uses the same (rid, index) threefry
    derivation as the host sampler: fused == host on the same trace, and
    a request sampled alone equals the same request inside a busy batch
    (preemption/batching invariance carries over to the device sampler)."""
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg, 4, lens=[16], gens=[6], seed=3)
    for r in reqs:
        r.temperature = 0.9
    ecfg = EngineConfig(n_slots=3, pages_per_slot=4, n_pages=12,
                        sample_device="fused")
    fused = ServingEngine(params, cfg, ecfg).run(reqs)
    host = ServingEngine(
        params, cfg,
        dataclasses.replace(ecfg, sample_device="host")).run(reqs)
    for h, f in zip(host, fused):
        np.testing.assert_array_equal(f.tokens, h.tokens,
                                      err_msg=f"req {h.rid}")
    alone = ServingEngine(params, cfg, ecfg).run([reqs[2]])
    np.testing.assert_array_equal(fused[2].tokens, alone[0].tokens)


def test_engine_rejects_bad_sample_device():
    cfg = _cfg()
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="sample_device"):
        ServingEngine(params, cfg, EngineConfig(sample_device="gpu"))
