"""Backend conformance: ONE shared battery against every `DecodeBackend`.

Every test below runs once per backend through the single ``cell``
fixture — adding a backend to ``BACKENDS`` subjects it to the whole
contract with zero new test code:

  * alloc → prefill → decode greedy tokens == the backend's own
    static/full-forward reference (chunked admission, slot reuse);
  * preempt → recompute parity: an evicted victim re-emits identical
    tokens;
  * retire releases EVERYTHING: no page, slot, or refcount survives a
    drained trace;
  * ``stats()`` returns exactly the centralized schema
    (`serve.backends.STATS_SCHEMA`) — bench rows and dashboards can key
    on it without per-backend special cases;
  * the speculative triple (draft/verify/rollback): streams with
    ``spec_k > 0`` are bit-identical to ``spec_k = 0`` in every drafting
    mode the backend supports (the recurrent backends' synthetic "stress"
    mode forces rejections so rollback is genuinely exercised);
  * a hypothesis schedule fuzzer: random prompts/lengths/spec_k with
    cancel injection, parity + allocator-leak invariants on every run.
"""

import dataclasses
import functools

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.models import mamba2 as m2
from repro.models import rglru as rglru_mod
from repro.models import transformer as tfm
from repro.models.modules import AttnConfig, ModelConfig
from repro.serve import (ChaosBackend, ChaosConfig, EngineConfig,
                         InjectedFault, Request, ServingEngine, Supervisor,
                         SupervisorConfig)
from repro.serve.backends import (BACKEND_STAT_KEYS, ENGINE_STAT_KEYS,
                                  STATS_SCHEMA, BackendBase)
from repro.serve.backends.mita import MiTABackend
from repro.serve.backends.recurrent import Mamba2Backend, RGLRUBackend

W = 8
BACKENDS = ("mita", "mamba2", "rglru")
# drafting modes each backend supports (mita's "auto" = landmark
# self-draft; recurrent "self" never rejects, "stress" always does)
SPEC_MODES = {"mita": ("auto",), "mamba2": ("self", "stress"),
              "rglru": ("self", "stress")}


@functools.lru_cache(maxsize=None)
def _cell(name):
    key = jax.random.PRNGKey(0)
    if name == "mita":
        cfg = ModelConfig(
            n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=97,
            attn=AttnConfig(window=W, k=W, backend="mita_ref"))
        return cfg, tfm.lm_init(key, cfg), MiTABackend
    if name == "mamba2":
        cfg = ModelConfig(
            n_layers=2, d_model=32, n_heads=1, n_kv=1, d_ff=0, vocab=97,
            attn=AttnConfig(window=W, backend="full"))
        return cfg, m2.mamba_init(key, cfg), Mamba2Backend
    cfg = ModelConfig(
        n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=97,
        attn=AttnConfig(window=W, k=W, backend="mita_ref"))
    return cfg, rglru_mod.rg_init(key, cfg), RGLRUBackend


@pytest.fixture(params=BACKENDS)
def cell(request):
    """THE conformance fixture: ``(name, cfg, params, engine factory)``."""
    name = request.param
    cfg, params, mk = _cell(name)

    def engine(ecfg):
        return ServingEngine(params, cfg, ecfg,
                             backend=mk(params, cfg, ecfg))

    return name, cfg, params, engine


def _requests(vocab, specs, temperature=0.0, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, vocab, ln).astype(np.int32),
                    max_new_tokens=g, temperature=temperature)
            for i, (ln, g) in enumerate(specs)]


def _tokens(done):
    return {f.rid: f.tokens.tolist() for f in done if not f.cancelled}


# --------------------------------------------------------------- the battery

def test_alloc_prefill_decode_reference_parity(cell):
    """Chunked admission with slot reuse: every request's greedy stream is
    bit-identical to the backend's static/full-forward reference."""
    name, cfg, params, engine = cell
    reqs = _requests(cfg.vocab, [(W, 4), (2 * W, 7), (3 * W, 3), (W, 6)])
    ecfg = EngineConfig(n_slots=2, pages_per_slot=5, n_pages=12,
                        prefill_chunk=W)
    eng = engine(ecfg)
    done = eng.run(reqs)
    assert len(done) == len(reqs)
    ref = eng.backend.fresh()
    for f, r in zip(sorted(done, key=lambda f: f.rid), reqs):
        expect = ref.static_reference(r.prompt[None], r.max_new_tokens)
        np.testing.assert_array_equal(f.tokens, expect[0],
                                      err_msg=f"{name} req {f.rid}")


def test_preempt_recompute_parity(cell):
    """A low-priority victim evicted mid-decode by high-priority arrivals
    re-emits exactly the stream it would have produced unpreempted."""
    name, cfg, params, engine = cell
    victim = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (2 * W,),
                                           0, cfg.vocab))
    ecfg = EngineConfig(n_slots=2, pages_per_slot=6, n_pages=8,
                        prefill_chunk=2 * W)
    ref = engine(ecfg).run(
        [Request(rid=0, prompt=victim, max_new_tokens=16)])[0].tokens

    eng = engine(ecfg)
    eng.submit(Request(rid=0, prompt=victim, max_new_tokens=16, priority=0))
    for _ in range(6):
        eng.step()
    hp = jax.random.randint(jax.random.PRNGKey(5), (2, 2 * W), 0, cfg.vocab)
    for i in (1, 2):
        eng.submit(Request(rid=i, prompt=np.asarray(hp[i - 1]),
                           max_new_tokens=16, priority=5))
    while eng.step():
        pass
    done = sorted(eng.finished, key=lambda f: f.rid)
    assert len(done) == 3
    assert eng.n_preemptions >= 1, "scenario no longer triggers preemption"
    np.testing.assert_array_equal(done[0].tokens, ref,
                                  err_msg=f"{name} victim diverged")


def test_retire_releases_everything(cell):
    """After a drained trace: zero pages in use, zero refcounts, every
    slot free, nothing active — for every backend, cache off."""
    name, cfg, params, engine = cell
    ecfg = EngineConfig(n_slots=3, pages_per_slot=5, n_pages=15,
                        prefill_chunk=W)
    eng = engine(ecfg)
    eng.run(_requests(cfg.vocab, [(W, 3), (2 * W, 5), (W, 2), (2 * W, 4)]))
    assert eng.alloc.in_use == 0, f"{name}: pages leaked"
    assert eng.alloc.refs == {}, f"{name}: refcounts leaked"
    assert sorted(eng.alloc.free) == list(range(ecfg.n_pages))
    assert not eng.active.any() and not eng.slot_pages
    assert sorted(eng.free_slots) == list(range(ecfg.n_slots))


def test_stats_schema_is_exact(cell):
    """`stats()` returns EXACTLY the centralized schema — the engine's
    scheduler counters plus the backend counters, no drift either way —
    and the backend's own `stats()` covers `BACKEND_STAT_KEYS`."""
    name, cfg, params, engine = cell
    eng = engine(EngineConfig(n_slots=2, pages_per_slot=4, n_pages=8,
                              prefill_chunk=W))
    eng.run(_requests(cfg.vocab, [(W, 2)]))
    st = eng.stats()
    assert set(st) == STATS_SCHEMA, (
        f"{name}: stats keys drifted from serve.backends.STATS_SCHEMA: "
        f"extra={set(st) - STATS_SCHEMA} missing={STATS_SCHEMA - set(st)}")
    assert set(eng.backend.stats()) == BACKEND_STAT_KEYS
    assert st["backend"] == name
    assert "backend" in ENGINE_STAT_KEYS


def test_speculative_parity_all_modes(cell):
    """The draft/verify/rollback triple is LOSSLESS: with any supported
    spec_mode and spec_k, greedy and tempered streams are bit-identical to
    the spec_k=0 engine, requests retire after the same number of emitted
    tokens, and the accept/rollback counters are consistent."""
    name, cfg, params, engine = cell
    specs = [(W, 5), (2 * W - 3, 9), (2 * W, 4), (5, 11)]
    for temp in (0.0, 0.8):
        base_ecfg = EngineConfig(n_slots=3, pages_per_slot=4, n_pages=24,
                                 prefill_chunk=W, sample_device="fused")
        base = _tokens(engine(base_ecfg).run(
            _requests(cfg.vocab, specs, temperature=temp)))
        for mode in SPEC_MODES[name]:
            eng = engine(dataclasses.replace(base_ecfg, spec_k=3,
                                             spec_mode=mode))
            got = _tokens(eng.run(_requests(cfg.vocab, specs,
                                            temperature=temp)))
            assert got == base, (f"{name} spec_mode={mode} temp={temp} "
                                 "diverged from spec_k=0")
            st = eng.stats()
            assert st["spec_accepted"] <= st["spec_drafted"]
            # a rollback implies >= 1 drafted-but-rejected token
            assert st["spec_rollbacks"] \
                <= st["spec_drafted"] - st["spec_accepted"]
            if mode == "self":       # exact self-drafts never reject
                assert st["spec_rollbacks"] == 0
                assert st["spec_accepted"] == st["spec_drafted"] > 0
            if mode == "stress":     # synthetic drafts exercise rollback
                assert st["spec_rollbacks"] > 0


def test_speculation_contract_surface(cell):
    """Protocol surface: the backend advertises `supports_speculation`,
    `draft_horizon` returns a per-slot nonnegative int array, and the
    engine refuses spec_k > 0 without fused sampling."""
    name, cfg, params, engine = cell
    eng = engine(EngineConfig(n_slots=2, pages_per_slot=4, n_pages=8))
    assert eng.backend.supports_speculation
    h = eng.backend.draft_horizon(np.array([0, 5, W - 1, W, 3 * W + 2]))
    assert h.shape == (5,) and np.issubdtype(h.dtype, np.integer)
    assert (h >= 0).all()
    with pytest.raises(ValueError, match="fused"):
        engine(EngineConfig(n_slots=2, pages_per_slot=4, n_pages=8,
                            spec_k=2))


def test_base_backend_refuses_speculation():
    """A backend that does not override the triple raises, and the engine
    rejects spec_k > 0 against it up front."""
    b = BackendBase(None, None, EngineConfig())
    assert not b.supports_speculation
    for call in (lambda: b.draft_steps(*[None] * 9),
                 lambda: b.verify_step(*[None] * 10),
                 lambda: b.rollback(None, None)):
        with pytest.raises(NotImplementedError, match="speculative"):
            call()
    # the default horizon is unbounded (no backend-internal boundary)
    assert (b.draft_horizon(np.zeros(3, np.int32))
            == np.iinfo(np.int32).max).all()


# ------------------------------------------------ fault & leak conformance --

def test_midstep_exception_leaks_no_pages(cell):
    """A backend raising mid-`step()` must leave the scheduler consistent:
    after the exception propagates, draining the SAME engine returns the
    pool to zero pages / zero refcounts and every stream still matches the
    static reference.  All three dispatch sites are exercised — monolithic
    admission (`prefill_group`, the rollback path), chunked prefill, and
    decode — for every backend."""
    name, cfg, params, engine = cell
    mkcls = _cell(name)[2]
    specs = [(W, 3), (2 * W, 4)]
    for chunk, op in ((0, "prefill_group"), (W, "prefill_chunks"),
                      (W, "decode_step")):
        ecfg = EngineConfig(n_slots=2, pages_per_slot=4, n_pages=10,
                            prefill_chunk=chunk)
        cb = ChaosBackend(mkcls(params, cfg, ecfg), ChaosConfig())
        eng = ServingEngine(params, cfg, ecfg, backend=cb)
        for r in _requests(cfg.vocab, specs):
            eng.submit(r)
        if op == "decode_step":     # land the fault after prefill finished
            while not eng.active.any():
                eng.step()
        cb.inject(op, raises=1)
        with pytest.raises(InjectedFault):
            while eng.step():
                pass
        while eng.step():           # fault healed: same engine drains
            pass
        assert eng.alloc.in_use == 0, f"{name}/{op}: pages leaked"
        assert eng.alloc.refs == {}, f"{name}/{op}: refcounts leaked"
        ref = cb.inner.fresh()
        for f, r in zip(sorted(eng.finished, key=lambda f: f.rid),
                        _requests(cfg.vocab, specs)):
            np.testing.assert_array_equal(
                f.tokens, ref.static_reference(r.prompt[None],
                                               r.max_new_tokens)[0],
                err_msg=f"{name}/{op}: stream diverged after fault")


def test_supervised_chaos_parity(cell):
    """Seeded chaos (transient + slot-bound faults + allocator spikes)
    under the supervisor: every request completes bit-identical to the
    fault-free engine, the pool drains to zero, and the robustness
    counters in `stats()` actually move — for every backend."""
    name, cfg, params, engine = cell
    mkcls = _cell(name)[2]
    specs = [(W, 4), (2 * W, 6), (W, 3), (2 * W, 5)]
    ecfg = EngineConfig(n_slots=2, pages_per_slot=4, n_pages=12,
                        prefill_chunk=W)
    ref = _tokens(engine(ecfg).run(_requests(cfg.vocab, specs)))
    chaos = ChaosConfig(seed=5, p_fault=0.3, transient_len=2,
                        p_slot_fault=0.4, alloc_spike_every=5,
                        alloc_spike_pages=2,
                        ops=("decode_step", "prefill_chunks"))
    cb = ChaosBackend(mkcls(params, cfg, ecfg), chaos)
    eng = ServingEngine(params, cfg, ecfg, backend=cb)
    sup = Supervisor(eng, SupervisorConfig(max_retries=2, stall_steps=4))
    done = sup.run(_requests(cfg.vocab, specs))
    sup.close()
    assert _tokens(done) == ref, f"{name}: supervised streams diverged"
    assert eng.alloc.in_use == 0 and eng.alloc.refs == {}
    assert cb.n_injected > 0, f"{name}: chaos schedule fired nothing"
    assert sup.stats()["retries"] > 0


# ------------------------------------------------------- schedule fuzzing --

@settings(max_examples=6, deadline=None)
@given(st.sampled_from(["mita", "mamba2"]), st.integers(1, 4),
       st.booleans(), st.booleans(), st.integers(0, 2**31 - 1))
def test_speculative_schedule_fuzz(name, spec_k, cancel, chaos, seed):
    """Property: ANY random schedule — prompt lengths, generation budgets,
    staggered arrivals, optional mid-trace cancellation, optional seeded
    chaos (supervised transient/slot faults + allocator spikes) — produces
    token streams bit-identical to the fault-free spec_k=0 engine for
    every request that ran to completion, and the allocator ends every
    trace with zero pages in use (mita exercises the landmark drafter;
    mamba2 the stress mode, so rollback replay is fuzzed too).  Chaos only
    intercepts ops whose faults fire BEFORE any state mutation
    (`draft_steps` is gated pre-draft, never `verify_step`), so a retried
    step replays against unchanged backend state by construction."""
    cfg, params, mk = _cell(name)
    rng = np.random.default_rng(seed)
    servable = [5, 6, W, W + 2, 2 * W - 2, 2 * W]
    specs = [(int(rng.choice(servable)), int(rng.integers(2, 10)))
             for _ in range(5)]
    mode = "auto" if name == "mita" else "stress"

    def run(k, with_chaos):
        ecfg = EngineConfig(n_slots=2, pages_per_slot=4, n_pages=16,
                            prefill_chunk=W, sample_device="fused",
                            spec_k=k, spec_mode=mode if k else "auto")
        backend = mk(params, cfg, ecfg)
        cb = None
        if with_chaos:
            backend = cb = ChaosBackend(backend, ChaosConfig(
                seed=seed ^ 0xC0FFEE, p_fault=0.2, transient_len=2,
                p_slot_fault=0.3, alloc_spike_every=7, alloc_spike_pages=2,
                ops=("decode_step", "prefill_chunks", "draft_steps")))
        eng = ServingEngine(params, cfg, ecfg, backend=backend)
        sup = Supervisor(eng, SupervisorConfig(max_retries=2,
                                               stall_steps=4)) \
            if with_chaos else None
        step = sup.step if sup is not None else eng.step
        pend = _requests(cfg.vocab, specs, seed=seed)
        idx = steps = 0
        while idx < len(pend) or eng.waiting or eng.prefilling \
                or eng.active.any():
            while idx < len(pend) and idx <= steps:
                eng.submit(pend[idx])
                idx += 1
            if cancel and steps == 3:
                eng.cancel(1)
            step()
            steps += 1
        if cb is not None:
            cb.release_spikes()
            sup.close()
        assert eng.alloc.in_use == 0 and eng.alloc.refs == {}, "page leak"
        return _tokens([f for f in eng.finished
                        if f.reason == "complete"])

    got, base = run(spec_k, chaos), run(0, False)
    # the one cancel target may legitimately finish before the cancel
    # fires in one run but not the other (spec_k / retries shift how many
    # tokens a loop iteration emits); every request completed in BOTH
    # runs must be bit-identical, and no other request may go missing
    ctx = f"{name} spec_k={spec_k} cancel={cancel} chaos={chaos} seed={seed}"
    assert set(got) ^ set(base) <= ({1} if cancel else set()), (
        f"{ctx}: completed-request sets diverged beyond the cancel target")
    for r in set(got) & set(base):
        assert got[r] == base[r], f"{ctx}: rid {r} diverged"


# ---------------------------------------------- VMEM fallback regression --

def test_vmem_fallback_during_speculative_verify():
    """Regression: an oversized working set under `paged_impl="kernel"`
    with a 1-byte VMEM budget must degrade the speculative VERIFY program
    to the XLA path (warning once, counting every fallback) — and the
    degraded engine's streams stay bit-identical to an explicit
    `paged_impl="xla"` run.  The verify/draft programs are lru_cached by
    config, so a vocab unique to this test guarantees fresh traces."""
    from repro.kernels import ops

    def cfg_for(impl, budget):
        return ModelConfig(
            n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=101,
            attn=AttnConfig(window=W, k=W, backend="mita_ref",
                            paged_impl=impl, vmem_budget=budget))

    specs = [(W, 6), (2 * W, 5)]
    ecfg = EngineConfig(n_slots=2, pages_per_slot=4, n_pages=12,
                        sample_device="fused", spec_k=2)

    def run(impl, budget):
        cfg = cfg_for(impl, budget)
        params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
        eng = ServingEngine(params, cfg, ecfg,
                            backend=MiTABackend(params, cfg, ecfg))
        done = eng.run(_requests(cfg.vocab, specs))
        return _tokens(done), eng.stats()

    base = ops.paged_kernel_fallbacks()
    ops._PAGED_FALLBACK_WARNED = False
    with pytest.warns(RuntimeWarning, match="VMEM budget"):
        got, st = run("kernel", 1)
    assert ops.paged_kernel_fallbacks() > base, "fallback not counted"
    assert st["paged_kernel_fallbacks"] >= 1, \
        "backend stats missed the fallback delta"
    want, st_xla = run("xla", 0)
    assert got == want, "degraded kernel path diverged from explicit XLA"
    assert st_xla["paged_kernel_fallbacks"] == 0
