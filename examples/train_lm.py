"""End-to-end training driver example: a few hundred steps of a MiTA LM with
checkpoint/restart, on the qwen3-family architecture.

CPU note: the default here is a ~6M-param reduced qwen3 so the run finishes
on this container; on TPU hardware drop `--smoke` to train the real config
on the production mesh (see src/repro/launch/train.py and DESIGN.md).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()
    sys.exit(train_main([
        "--arch", "qwen3-0.6b", "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "256",
        "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--resume",
        "--log-every", "10",
    ]))
