"""Continuous-batching serving example: mixed prompt/generation lengths
through the paged MiTA engine — requests are admitted and retired every
step, so short generations free their slot (and pages) for waiting work
instead of idling until the longest request finishes.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.core.mita_decode import window_aligned
from repro.data import DataConfig, synthetic_batch
from repro.models import transformer as tfm
from repro.serve import EngineConfig, Request, ServingEngine


def main():
    arch = get_arch("tinyllama-1.1b", smoke=True)
    cfg = arch.model
    w = cfg.attn.window
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    prompt_lens = [2 * w, 4 * w, 6 * w]
    pool = {n: np.asarray(synthetic_batch(
        DataConfig(vocab=cfg.vocab, seq_len=n, global_batch=16), 0)["tokens"])
        for n in prompt_lens}
    reqs = []
    for i in range(24):
        n = prompt_lens[int(rng.integers(len(prompt_lens)))]
        reqs.append(Request(
            rid=i, prompt=pool[n][i % 16],
            max_new_tokens=int(rng.integers(4, 33)),
            temperature=0.8))

    pages = window_aligned(max(prompt_lens) + 32, w) // w
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=8, pages_per_slot=pages, n_pages=12 * pages))

    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(f.tokens) for f in done)
    print(f"{len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s aggregate, {eng.steps} fused steps)")
    for f in done[:4]:
        print(f"  req {f.rid}: {len(f.tokens):2d} tokens "
              f"-> {f.tokens[:10].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
