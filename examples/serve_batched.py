"""Continuous-batching serving example: mixed priorities and prompt lengths
through the paged MiTA engine with chunked prefill.

Requests are admitted and retired every step, so short generations free
their slot (and pages) for waiting work instead of idling until the longest
request finishes.  The trace mixes two priority classes: a batch-class
(priority 0) long prompt arrives first and starts prefilling in
window-aligned chunks interleaved with the decode batch; then a burst of
interactive (priority 1) short prompts lands, outranks it, and — the pool
being sized just over one long request's budget — preempts it (pages
released, later rebuilt by recompute-from-prompt, emitting the same
tokens it would have unpreempted; see docs/serving.md for the lifecycle).

Run:  PYTHONPATH=src python examples/serve_batched.py

Expected output (timings vary; request/token counts are deterministic for
the fixed seeds, and the script asserts every request finished and that
the batch-class request was preempted at least once):

    16 requests, 320 tokens in ~Xs (~Y tok/s, Z fused steps)
    scheduler: chunks=C preemptions=P pages_high_water=H   (P >= 1)
      req  0 (prio 1): 23 tokens -> [197, 160, 240, ...]
      ...
    all 16 requests finished; batch-class request survived P preemption(s)
"""

import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.core.mita_decode import window_aligned
from repro.data import DataConfig, synthetic_batch
from repro.models import transformer as tfm
from repro.serve import EngineConfig, Request, ServingEngine


def main():
    arch = get_arch("tinyllama-1.1b", smoke=True)
    cfg = arch.model
    w = cfg.attn.window
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    short_lens = [w, 2 * w]
    long_len = 8 * w
    pool = {n: np.asarray(synthetic_batch(
        DataConfig(vocab=cfg.vocab, seq_len=n, global_batch=16), 0)["tokens"])
        for n in short_lens + [long_len]}

    # 15 interactive requests (priority 1) + 1 batch-class long prompt
    # (priority 0) that admits chunk-by-chunk and gets preempted
    reqs = []
    for i in range(15):
        n = short_lens[int(rng.integers(len(short_lens)))]
        reqs.append(Request(
            rid=i, prompt=pool[n][i % 16],
            max_new_tokens=int(rng.integers(8, 33)),
            temperature=0.8, priority=1))
    long_req = Request(rid=15, prompt=pool[long_len][0], max_new_tokens=8,
                       priority=0)
    reqs.append(long_req)

    # pool sized TIGHT (just over one long request's budget) so the
    # batch-class prompt must yield its pages to interactive arrivals
    pages = window_aligned(long_len + 32, w) // w
    eng = ServingEngine(params, cfg, EngineConfig(
        n_slots=4, pages_per_slot=pages, n_pages=pages + 6,
        prefill_chunk=2 * w, reserve_pages=2))

    t0 = time.perf_counter()
    # the long prompt arrives first and starts prefilling chunk-by-chunk...
    eng.submit(long_req)
    for _ in range(6):
        eng.step()
    # ...then the interactive burst lands, outranks it, and evicts it
    done = eng.run(reqs[:15])
    dt = time.perf_counter() - t0
    total = sum(len(f.tokens) for f in done)
    st = eng.stats()
    print(f"{len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s aggregate, {eng.steps} fused steps)")
    print(f"scheduler: chunks={st['chunks']} "
          f"preemptions={st['preemptions']} "
          f"pages_high_water={st['pages_high_water']}")
    for f in done[:4]:
        req = next(r for r in reqs if r.rid == f.rid)
        print(f"  req {f.rid:2d} (prio {req.priority}): "
              f"{len(f.tokens):2d} tokens -> {f.tokens[:10].tolist()}")
    assert len(done) == len(reqs), "a request was lost"
    long_done = next(f for f in done if f.rid == 15)
    assert st["preemptions"] >= 1, \
        "pool no longer tight enough to demonstrate preemption"
    print(f"all {len(done)} requests finished; batch-class request "
          f"survived {long_done.preemptions} preemption(s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
