"""Batched serving example: prefill a batch of prompts, decode with the
incremental MiTA cache — O(m + s·k + w) per token instead of O(context).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    sys.exit(serve_main([
        "--arch", "tinyllama-1.1b", "--smoke",
        "--batch", "8", "--prompt-len", "256", "--gen", "48",
        "--temperature", "0.8",
    ]))
