"""Algorithmic generalization (paper Appendix C / Fig. 9): train a model
with one attention mechanism, run inference with another.

The paper's headline finding: standard attention and MiTA generalize to
each other remarkably well — a model trained with full attention keeps >95%
of its accuracy when MiTA replaces attention at inference (linear-complexity
inference for free), while compression-only mechanisms transfer worse.

Run:  PYTHONPATH=src python examples/algorithmic_generalization.py
"""

import dataclasses

import jax

from benchmarks.common import tiny_vit_cfg
from repro.models import vit

STEPS, N, PATCH_DIM, CLASSES = 60, 128, 48, 10


def train(backend: str):
    from repro.optim import OptConfig, adamw_init, adamw_update
    cfg = tiny_vit_cfg(backend, N, m=16, k=16)
    params = vit.vit_init(jax.random.PRNGKey(0), cfg, PATCH_DIM, CLASSES)
    opt = adamw_init(params)
    ocfg = OptConfig(lr=2e-3, warmup_steps=5, total_steps=STEPS)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(vit.vit_loss)(p, b, cfg)
        return *adamw_update(g, o, p, ocfg)[:2], loss

    for i in range(STEPS):
        batch = vit.synthetic_vision_batch(
            jax.random.PRNGKey(1000 + i), 32, N, PATCH_DIM, CLASSES,
            n_signal=3, noise=1.2)
        params, opt, _ = step(params, opt, batch)
    return params, cfg


def evaluate(params, cfg, infer_backend: str) -> float:
    cfg_b = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, backend=infer_backend))
    batch = vit.synthetic_vision_batch(
        jax.random.PRNGKey(9), 256, N, PATCH_DIM, CLASSES,
        n_signal=3, noise=1.2)
    return float(vit.vit_accuracy(params, batch, cfg_b))


if __name__ == "__main__":
    print("training attention -> inference attention accuracy matrix")
    for train_backend in ("full", "mita"):
        params, cfg = train(train_backend)
        row = {ib: evaluate(params, cfg, ib)
               for ib in ("full", "mita", "agent")}
        print(f"  train={train_backend:5s}: " +
              "  ".join(f"infer-{k}={v:.3f}" for k, v in row.items()))
    print("(expect: full<->mita transfer retains most accuracy; "
          "agent transfer degrades — paper Fig. 9)")
