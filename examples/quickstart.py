"""Quickstart: MiTA attention as a drop-in module + a tiny training loop.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

# 1) --- MiTA as a standalone attention op --------------------------------
from repro.core.mita import MiTAConfig, mita_attention
from repro.core.mita_sparse import mita_attention_sparse

B, H, N, d = 2, 4, 256, 32
q, k, v = (jax.random.normal(key, (B, H, N, d))
           for key in jax.random.split(jax.random.PRNGKey(0), 3))

cfg = MiTAConfig(m=16, k=32, s=1, causal=True)   # 16 experts, top-32 each
out_ref = mita_attention(q, k, v, cfg)                 # semantic reference
out_fast = mita_attention_sparse(q, k, v, cfg)         # production path
print(f"MiTA out: {out_fast.shape}, ref-vs-fast max err: "
      f"{jnp.max(jnp.abs(out_ref - out_fast)):.2e}")
print(f"each query attends to m + k·s = {cfg.m + cfg.k * cfg.s} of {N} pairs")

# 2) --- a MiTA language model in five lines ------------------------------
from repro.models.modules import AttnConfig, ModelConfig
from repro.models.transformer import lm_init, lm_loss
from repro.optim import OptConfig, adamw_init, adamw_update
from repro.data import DataConfig, synthetic_batch

mcfg = ModelConfig(n_layers=2, d_model=128, n_heads=4, n_kv=2, d_ff=256,
                   vocab=211, attn=AttnConfig(backend="mita", window=32, k=32))
params = lm_init(jax.random.PRNGKey(0), mcfg)
opt = adamw_init(params)
ocfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)


@jax.jit
def train_step(p, o, batch):
    loss, g = jax.value_and_grad(lambda pp: lm_loss(pp, batch, mcfg))(p)
    p, o, m = adamw_update(g, o, p, ocfg)
    return p, o, loss


data = DataConfig(vocab=mcfg.vocab, seq_len=128, global_batch=8)
for step in range(30):
    params, opt, loss = train_step(params, opt, synthetic_batch(data, step))
    if step % 10 == 0 or step == 29:
        print(f"step {step:3d}  loss {float(loss):.4f}")
print("done — see examples/train_lm.py for the full driver "
      "(checkpointing, restart, mesh).")
