"""Compressed data-parallel gradient reduction (beyond-paper distributed
optimization trick; see the brief's 1000+-node requirements).

A GSPMD train step reduces gradients with implicit f32/bf16 all-reduces.
For pure-DP segments (the cross-pod axis at scale) this module provides an
explicit shard_map-based reduction that moves **int8** on the wire:

  1. per-tensor absmax-quantize the local gradient to int8 (+f32 scale);
  2. reduce-scatter via `all_to_all` (each device receives the int8 chunks
     of its segment from every peer — 1 byte/element on the wire);
  3. dequantize + sum locally in f32, re-quantize the reduced segment;
  4. `all_gather` the int8 segments (1 byte/element again).

Wire bytes: 2·(n−1)/n·size·1B vs 2·(n−1)/n·size·4B for an f32 ring
all-reduce — a 4× reduction, verified by HLO collective-byte counting in
tests/test_compression.py.

Quantization error is handled with standard **error feedback** (Seide et
al., 1-bit SGD): the residual (g − Q(g)) is carried in the optimizer state
and added to the next step's gradient, making the scheme unbiased over
time.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(g: jax.Array):
    """Per-tensor symmetric absmax quantization. Returns (q int8, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _compressed_allreduce_leaf(g: jax.Array, axis_name: str, n: int):
    """Mean-all-reduce one tensor with int8 wire traffic (inside shard_map)."""
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    q, scale = quantize_int8(flat)

    # reduce-scatter: all_to_all the n chunks; receive peers' copies of OUR
    # segment
    chunks = q.reshape(n, -1)
    recv = jax.lax.all_to_all(chunks, axis_name, split_axis=0,
                              concat_axis=0, tiled=True)       # [n, seg]
    scales = jax.lax.all_gather(scale, axis_name)              # [n]
    seg = jnp.sum(recv.astype(jnp.float32).reshape(n, -1)
                  * scales[:, None], axis=0) / n               # mean

    q2, s2 = quantize_int8(seg)
    segs = jax.lax.all_gather(q2, axis_name)                   # [n, seg] int8
    s2s = jax.lax.all_gather(s2, axis_name)                    # [n]
    full = (segs.astype(jnp.float32) * s2s[:, None]).reshape(-1)
    if pad:
        full = full[:-pad]
    return full.reshape(g.shape)


def compressed_grad_mean(grads: Any, axis_name: str, n: int,
                         err: Any = None):
    """Mean-reduce a gradient pytree across ``axis_name`` with int8 wire
    traffic and error feedback.  Returns (reduced_grads, new_err)."""
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def leaf(g, e):
        g_fb = g.astype(jnp.float32) + e
        reduced = _compressed_allreduce_leaf(g_fb, axis_name, n)
        # residual of the *local* quantization (the part not transmitted)
        q, s = quantize_int8(g_fb)
        new_e = g_fb - dequantize_int8(q, s)
        return reduced, new_e

    out = jax.tree.map(leaf, grads, err)
    reduced = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return reduced, new_err


def dp_compressed_train_step(loss_fn, opt_update, mesh, axis: str = "data"):
    """Build a pure-DP train step with compressed gradient reduction.

    ``loss_fn(params, batch) -> loss``;
    ``opt_update(grads, opt_state, params) -> (params, opt_state, metrics)``.
    Params replicated; batch sharded over ``axis``.  The returned step has
    signature (params, opt_state, err, batch) -> (params, opt, err, metrics).
    """
    from jax.experimental.shard_map import shard_map
    n = mesh.shape[axis]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(), P(), P(axis)),
        out_specs=(P(), P(), P(), P()),
        check_rep=False)
    def step(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, err = compressed_grad_mean(grads, axis, n, err)
        params, opt_state, metrics = opt_update(grads, opt_state, params)
        metrics["loss"] = jax.lax.pmean(loss, axis)
        return params, opt_state, err, metrics

    return step


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
