"""AdamW + schedules, pure JAX (no optax in this environment).

Optimizer state is a pytree mirroring params (f32 moments — master precision
even when params are bf16), so it shards with the same partition specs as the
parameters (ZeRO-style sharding falls out of the mesh rules for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      step=jnp.zeros((), jnp.int32))


def cosine_schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(grads, state: AdamWState, params, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g, state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, n):
        mhat = m / bc1
        nhat = n / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, AdamWState(mu=mu, nu=nu, step=step), metrics
