"""RecurrentGemma-style hybrid model (Griffin): RG-LRU recurrent blocks
interleaved 2:1 with (MiTA/local) attention blocks.

Per DESIGN.md §Arch-applicability: the paper's MiTA replaces the *local
attention* layers only; RG-LRU layers are attention-free — in the paper's
taxonomy they are already "scaling by compression" (a recurrent shared
expert), so MiTA is inapplicable there by construction.

The RG-LRU recurrence  h_t = a_t ⊙ h_{t-1} + sqrt(1-a_t²) ⊙ (i_t ⊙ x_t)
is evaluated with `jax.lax.associative_scan` (O(log N) depth) at training
time and a single-step update at decode time.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import mita_decode as mdec
from repro.models import modules as nn
from repro.models import transformer as tfm

Params = dict[str, Any]

_C = 8.0            # RG-LRU decay sharpness constant
_CONV_K = 4         # temporal conv width


# ----------------------------------------------------------------- RG-LRU ---

def rglru_block_init(rng, cfg: nn.ModelConfig) -> Params:
    d = cfg.d_model
    dr = d   # recurrent width == d_model (RecurrentGemma convention)
    ks = jax.random.split(rng, 7)
    return {
        "ln": jnp.zeros((d,), cfg.param_dtype),
        "w_in": nn.dense_init(ks[0], d, dr, cfg.param_dtype),
        "w_gate": nn.dense_init(ks[1], d, dr, cfg.param_dtype),
        "conv": (jax.random.normal(ks[2], (_CONV_K, dr)) * 0.1).astype(cfg.param_dtype),
        "w_a": nn.dense_init(ks[3], dr, dr, cfg.param_dtype),
        "b_a": jnp.zeros((dr,), cfg.param_dtype),
        "w_x": nn.dense_init(ks[4], dr, dr, cfg.param_dtype),
        "b_x": jnp.zeros((dr,), cfg.param_dtype),
        "lam": jnp.full((dr,), 0.5, cfg.param_dtype),   # Λ (softplus'd)
        "w_out": nn.dense_init(ks[5], dr, d, cfg.param_dtype),
    }


def _rglru_gates(p: Params, xc: jax.Array, ct):
    """a_t (log-space) and gated input for the recurrence."""
    r = jax.nn.sigmoid(xc @ p["w_a"].astype(ct) + p["b_a"].astype(ct))
    i = jax.nn.sigmoid(xc @ p["w_x"].astype(ct) + p["b_x"].astype(ct))
    log_a = (-_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i.astype(jnp.float32) * xc.astype(jnp.float32))
    return a, gated


def rglru_block_apply(p: Params, x: jax.Array, cfg: nn.ModelConfig):
    """x: [B, N, D] -> [B, N, D]."""
    ct = cfg.compute_dtype
    xn = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(xn @ p["w_gate"].astype(ct))
    xi = xn @ p["w_in"].astype(ct)

    # causal depthwise temporal conv (width 4)
    xpad = jnp.pad(xi, ((0, 0), (_CONV_K - 1, 0), (0, 0)))
    xc = sum(xpad[:, j: j + xi.shape[1]] * p["conv"][j].astype(ct)
             for j in range(_CONV_K))

    a, gated = _rglru_gates(p, xc, ct)

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(op, (a, gated), axis=1)
    y = (h.astype(ct) * gate) @ p["w_out"].astype(ct)
    return x + y


class RGLRUState(NamedTuple):
    h: jax.Array        # [B, Dr] recurrent state (f32)
    conv: jax.Array     # [B, _CONV_K-1, Dr] trailing conv inputs


def rglru_init_state(batch: int, dr: int) -> RGLRUState:
    return RGLRUState(h=jnp.zeros((batch, dr), jnp.float32),
                      conv=jnp.zeros((batch, _CONV_K - 1, dr), jnp.float32))


def rglru_block_decode(p: Params, x: jax.Array, st: RGLRUState,
                       cfg: nn.ModelConfig):
    """x: [B, D] single step."""
    ct = cfg.compute_dtype
    xn = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(xn @ p["w_gate"].astype(ct))
    xi = xn @ p["w_in"].astype(ct)

    hist = jnp.concatenate([st.conv, xi[:, None, :].astype(jnp.float32)], axis=1)
    xc = sum(hist[:, j] * p["conv"][j].astype(jnp.float32)
             for j in range(_CONV_K)).astype(ct)

    a, gated = _rglru_gates(p, xc, ct)
    h = a * st.h + gated
    y = (h.astype(ct) * gate) @ p["w_out"].astype(ct)
    return x + y, RGLRUState(h=h, conv=hist[:, 1:])


# ------------------------------------------------------------- super-block --

def super_block_init(rng, cfg: nn.ModelConfig) -> Params:
    """(RG-LRU, RG-LRU, attention+FFN) — the Griffin 2:1 pattern."""
    ks = jax.random.split(rng, 4)
    return {
        "rec1": rglru_block_init(ks[0], cfg),
        "rec2": rglru_block_init(ks[1], cfg),
        "attn_blk": tfm.block_init(ks[2], cfg),
        "ffn1": nn.swiglu_init(ks[3], cfg),
        "ln_f1": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }


def super_block_apply(p: Params, x: jax.Array, cfg: nn.ModelConfig,
                      positions: jax.Array):
    x = rglru_block_apply(p["rec1"], x, cfg)
    x = x + nn.swiglu_apply(p["ffn1"], nn.rms_norm(x, p["ln_f1"]), cfg)
    x = rglru_block_apply(p["rec2"], x, cfg)
    x, _ = tfm.block_apply(p["attn_blk"], x, cfg, positions)
    return x


# ------------------------------------------------------------------ model ---

def rg_init(rng, cfg: nn.ModelConfig) -> Params:
    n_super = max(1, cfg.n_layers // 3)
    k_emb, k_blocks, _ = jax.random.split(rng, 3)
    keys = jax.random.split(k_blocks, n_super)
    return {
        "emb": nn.embedding_init(k_emb, cfg),
        "supers": jax.vmap(lambda k: super_block_init(k, cfg))(keys),
        "ln_f": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }


def rg_forward(params: Params, tokens: jax.Array, cfg: nn.ModelConfig):
    x = nn.embed(params["emb"], tokens, cfg)
    positions = jnp.arange(tokens.shape[1])

    def body(h, sp):
        return super_block_apply(sp, h, cfg, positions), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["supers"], unroll=cfg.scan_unroll)
    x = nn.rms_norm(x, params["ln_f"])
    return nn.unembed(params["emb"], x, cfg), jnp.zeros((), jnp.float32)


def rg_loss(params, batch, cfg: nn.ModelConfig):
    logits, _ = rg_forward(params, batch["tokens"], cfg)
    return nn.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


class RGSuperState(NamedTuple):
    rec1: RGLRUState
    rec2: RGLRUState
    attn: Any


def rg_init_decode_states(cfg: nn.ModelConfig, batch: int, capacity: int):
    n_super = max(1, cfg.n_layers // 3)
    dr = cfg.d_model
    if cfg.attn.backend in ("mita", "mita_ref"):
        attn_state = mdec.init_decode_state(
            batch, cfg.n_kv, cfg.dh, capacity,
            mdec.DecodeConfig(window=cfg.attn.window, k=cfg.attn.k, s=cfg.attn.s),
            dtype=cfg.compute_dtype)
    else:
        # local attention decode only needs a sliding window of cache
        attn_state = mdec.init_full_state(
            batch, cfg.n_kv, cfg.dh, min(capacity, cfg.attn.local_window),
            dtype=cfg.compute_dtype)
    one = RGSuperState(rec1=rglru_init_state(batch, dr),
                       rec2=rglru_init_state(batch, dr),
                       attn=attn_state)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_super,) + a.shape), one)


def rg_decode_step(params: Params, states, token: jax.Array, pos: jax.Array,
                   cfg: nn.ModelConfig):
    x = nn.embed(params["emb"], token, cfg)

    def body(h, layer):
        sp, st = layer
        h, r1 = rglru_block_decode(sp["rec1"], h, st.rec1, cfg)
        h = h + nn.swiglu_apply(sp["ffn1"], nn.rms_norm(h, sp["ln_f1"]), cfg)
        h, r2 = rglru_block_decode(sp["rec2"], h, st.rec2, cfg)
        h, a = tfm.block_decode(sp["attn_blk"], h, st.attn, cfg, pos)
        return h, RGSuperState(rec1=r1, rec2=r2, attn=a)

    x, new_states = jax.lax.scan(body, x, (params["supers"], states),
                                 unroll=cfg.scan_unroll)
    logits = nn.unembed(params["emb"], nn.rms_norm(x, params["ln_f"]), cfg)
    return logits, new_states


# ------------------------------------------------------ slot-addressed ops --
#
# Serving entry points (repro.serve.backends.recurrent).  The RG-LRU
# recurrence and conv history are constant-size per slot; the super-block's
# attention layer keeps a bounded per-slot monolithic cache with its OWN
# per-slot position (`tfm.init_slot_attn_state` / `block_decode_slots`
# vmap), so one program serves slots at independent progress.
# `rg_prefill_chunk` is chunk-parallel — bulk hoisted RG-LRU/FFN layers
# plus a minimal per-token attention scan — but every token's arithmetic
# is EXACTLY the decode-step update (pinned bit-identical against
# `rg_prefill_chunk_seq`), which is what makes recompute-from-prompt
# preemption bit-exact.

def _super_block_step(sp: Params, x: jax.Array, st: RGSuperState,
                      cfg: nn.ModelConfig, pos: jax.Array):
    """One token through one super-block with per-slot positions.
    x: [S, D]; pos: [S]."""
    h, r1 = rglru_block_decode(sp["rec1"], x, st.rec1, cfg)
    h = h + nn.swiglu_apply(sp["ffn1"], nn.rms_norm(h, sp["ln_f1"]), cfg)
    h, r2 = rglru_block_decode(sp["rec2"], h, st.rec2, cfg)
    h, a = tfm.block_decode_slots(sp["attn_blk"], h, st.attn, cfg, pos)
    return h, RGSuperState(rec1=r1, rec2=r2, attn=a)


def rg_slot_states(cfg: nn.ModelConfig, n_slots: int, capacity: int):
    """Stacked per-super-block slot states: RG-LRU leaves [NS, S, ...],
    attention leaves [NS, S, 1, ...] with per-slot ``t`` of shape [NS, S]
    (each slot a B == 1 monolithic cache of ``capacity`` tokens)."""
    n_super = max(1, cfg.n_layers // 3)
    one = RGSuperState(rec1=rglru_init_state(n_slots, cfg.d_model),
                       rec2=rglru_init_state(n_slots, cfg.d_model),
                       attn=tfm.init_slot_attn_state(cfg, n_slots, capacity))
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_super,) + a.shape),
                        one)


def rg_slot_decode_step(params: Params, states, token: jax.Array,
                        pos: jax.Array, cfg: nn.ModelConfig):
    """One token for the whole slot batch at PER-SLOT positions.
    token: [S] int32; pos: [S] int32.  Returns (logits [S, V], states)."""
    x = nn.embed(params["emb"], token, cfg)

    def body(h, layer):
        sp, st = layer
        return _super_block_step(sp, h, st, cfg, pos)

    x, new_states = jax.lax.scan(body, x, (params["supers"], states),
                                 unroll=cfg.scan_unroll)
    logits = nn.unembed(params["emb"], nn.rms_norm(x, params["ln_f"]), cfg)
    return logits, new_states


def _rglru_block_prefill(p: Params, x: jax.Array, st: RGLRUState,
                         valid: jax.Array, n_valid: jax.Array,
                         cfg: nn.ModelConfig):
    """Chunk-parallel RG-LRU layer: norm, projections, causal conv, and
    gates run ONCE over the whole [S, nc] chunk; only the O(nc) diagonal
    recurrence h_t = a_t·h_{t-1} + gated_t is scanned.  Per-token
    arithmetic (ops, operand order, dtypes) is EXACTLY
    `rglru_block_decode`'s — valid tokens are a prefix per row, so every
    valid token sees the same conv history and recurrence inputs the
    sequential scan would feed it, making the rebuilt state and every
    valid position's output bit-identical.

    x: [S, nc, D]; valid: [S, nc] bool; n_valid: [S] i32.
    """
    ct = cfg.compute_dtype
    _, nc, _ = x.shape
    xn = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(xn @ p["w_gate"].astype(ct))
    xi = xn @ p["w_in"].astype(ct)

    # token j's conv history rows are exactly padded[:, j : j + _CONV_K]
    padded = jnp.concatenate([st.conv, xi.astype(jnp.float32)], axis=1)
    xc = sum(padded[:, j: j + nc] * p["conv"][j].astype(jnp.float32)
             for j in range(_CONV_K)).astype(ct)

    a, gated = _rglru_gates(p, xc, ct)

    def tstep(h_prev, inp):
        a_t, g_t, vj = inp
        h_new = a_t * h_prev + g_t
        return jnp.where(vj[:, None], h_new, h_prev), h_new

    h_fin, hs = jax.lax.scan(
        tstep, st.h,
        (jnp.moveaxis(a, 0, 1), jnp.moveaxis(gated, 0, 1), valid.T))
    h = jnp.moveaxis(hs, 0, 1)                            # [S, nc, Dr] f32
    y = (h.astype(ct) * gate) @ p["w_out"].astype(ct)
    # final conv tail = the last _CONV_K-1 raw inputs at each row's last
    # valid token; n_valid == 0 indexes straight back into st.conv
    idx = (n_valid[:, None] + jnp.arange(_CONV_K - 1)[None, :])[..., None]
    conv_fin = jnp.take_along_axis(padded, idx, axis=1)
    return x + y, RGLRUState(h=h_fin, conv=conv_fin)


def _super_block_prefill(sp: Params, x: jax.Array, st: RGSuperState,
                         cfg: nn.ModelConfig, pos: jax.Array,
                         valid: jax.Array, n_valid: jax.Array):
    """One super-block over a whole [S, nc] chunk: both RG-LRU layers and
    the FFN are chunk-parallel (`_rglru_block_prefill` + bulk swiglu);
    only the attention layer — whose per-slot monolithic cache appends one
    row per token — keeps a per-token scan, masking its state by validity
    exactly as the sequential path does."""
    from repro.core import slotted

    h, r1 = _rglru_block_prefill(sp["rec1"], x, st.rec1, valid, n_valid, cfg)
    h = h + nn.swiglu_apply(sp["ffn1"], nn.rms_norm(h, sp["ln_f1"]), cfg)
    h, r2 = _rglru_block_prefill(sp["rec2"], h, st.rec2, valid, n_valid, cfg)

    def tstep(ast, inp):
        hj, vj, pj = inp
        y, a_new = tfm.block_decode_slots(sp["attn_blk"], hj, ast, cfg, pj)
        return slotted.where_slots(vj, a_new, ast), y

    ast, ys = jax.lax.scan(tstep, st.attn,
                           (jnp.moveaxis(h, 0, 1), valid.T, pos.T))
    return jnp.moveaxis(ys, 0, 1), RGSuperState(rec1=r1, rec2=r2, attn=ast)


def rg_prefill_chunk(params: Params, states, tokens: jax.Array,
                     t0: jax.Array, n_valid: jax.Array, cfg: nn.ModelConfig):
    """Chunk-parallel prefill of one fixed-shape chunk into a subset of
    slots (`_super_block_prefill` per super-block): the RG-LRU layers and
    FFNs run as bulk [S, nc] ops with only the diagonal recurrence (and
    the cache-appending attention sub-step) scanned per token.
    Bit-identical — states and valid-position outputs — to
    `rg_prefill_chunk_seq`'s token-sequential scan of the exact decode
    update (pinned by tests/test_recurrent_prefill.py), so preemption
    recompute stays exact while TTFT drops with the chunk width.

    tokens: [S, nc] int32; t0: [S] int32 resume points (rotary positions
    continue at t0 + j); n_valid: [S] int32 valid tokens per row (0 leaves
    the row's state untouched).  ONE compiled shape per chunk length
    serves every chunk of every request at any resume point.

    Returns (logits [S, V] at each row's last valid position, states).
    """
    _, nc = tokens.shape
    x = nn.embed(params["emb"], tokens, cfg)              # [S, nc, D]
    valid = jnp.arange(nc)[None, :] < n_valid[:, None]    # [S, nc]
    pos = t0[:, None] + jnp.arange(nc)                    # [S, nc]

    def body(h, layer):
        sp, st = layer
        return _super_block_prefill(sp, h, st, cfg, pos, valid, n_valid)

    x, new_states = jax.lax.scan(body, x, (params["supers"], states),
                                 unroll=cfg.scan_unroll)
    x = nn.rms_norm(x, params["ln_f"])
    last = jnp.take_along_axis(
        x, jnp.maximum(n_valid - 1, 0)[:, None, None], axis=1)[:, 0]
    return nn.unembed(params["emb"], last, cfg), new_states


def rg_prefill_chunk_seq(params: Params, states, tokens: jax.Array,
                         t0: jax.Array, n_valid: jax.Array,
                         cfg: nn.ModelConfig):
    """Token-sequential reference for `rg_prefill_chunk`: a `lax.scan` of
    the EXACT `_super_block_step` decode update, masked per token by
    validity.  Kept as the bit-identity oracle for the chunk-parallel
    path (and its bench baseline).
    """
    from repro.core import slotted

    _, nc = tokens.shape
    x = nn.embed(params["emb"], tokens, cfg)              # [S, nc, D]
    valid = jnp.arange(nc)[None, :] < n_valid[:, None]    # [S, nc]
    pos = t0[:, None] + jnp.arange(nc)                    # [S, nc]

    def body(h, layer):
        sp, st = layer

        def tstep(st, inp):
            xj, vj, pj = inp
            y, st_new = _super_block_step(sp, xj, st, cfg, pj)
            return slotted.where_slots(vj, st_new, st), y

        st, ys = jax.lax.scan(
            tstep, st, (jnp.moveaxis(h, 0, 1), valid.T, pos.T))
        return jnp.moveaxis(ys, 0, 1), st

    x, new_states = jax.lax.scan(body, x, (params["supers"], states),
                                 unroll=cfg.scan_unroll)
    x = nn.rms_norm(x, params["ln_f"])
    last = jnp.take_along_axis(
        x, jnp.maximum(n_valid - 1, 0)[:, None, None], axis=1)[:, 0]
    return nn.unembed(params["emb"], last, cfg), new_states
