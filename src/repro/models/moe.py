"""Mixture-of-Experts FFN — fine-grained (DeepSeekMoE) and coarse (DBRX).

Token dispatch uses sort-based capacity routing (static shapes, EP-shardable):
tokens are ranked within their expert's queue via a stable argsort — no
[T, E, C] one-hot dispatch tensors.  Expert compute is a dense
[E, C, d] × [E, d, f] batched matmul, sharded over the "model" (EP) axis.

Note the symmetry the paper's fast-weight framing makes explicit: this module
routes tokens to *slow-weight* experts; MiTA routes queries to *fast-weight*
(key/value) experts.  Both use the same capacity machinery.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import modules as nn

Params = dict[str, Any]


def moe_init(rng, cfg: nn.ModelConfig) -> Params:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": nn.dense_init(ks[0], d, e, jnp.float32),  # router in f32
        "wi": (jax.random.normal(ks[1], (e, d, f)) * scale).astype(cfg.param_dtype),
        "wg": (jax.random.normal(ks[2], (e, d, f)) * scale).astype(cfg.param_dtype),
        "wo": (jax.random.normal(ks[3], (e, f, d)) / math.sqrt(f)).astype(cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = nn.swiglu_init(ks[4], cfg,
                                     d_ff=cfg.n_shared_experts * cfg.d_ff)
    return p


def _dispatch_slots(assign: jax.Array, n_experts: int):
    """Rank of each sub-token within its expert queue (stable), via sort.

    assign: [T] int32 expert ids (n_experts = drop sentinel allowed).
    Returns slot: [T] int32.
    """
    t = assign.shape[-1]
    order = jnp.argsort(assign, axis=-1, stable=True)
    a_sorted = jnp.take_along_axis(assign, order, axis=-1)
    counts = jax.ops.segment_sum(jnp.ones((t,), jnp.int32), a_sorted,
                                 num_segments=n_experts + 1)
    starts = jnp.cumsum(counts) - counts
    slot_sorted = jnp.arange(t, dtype=jnp.int32) - starts[a_sorted]
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(slot_sorted, inv, axis=-1)


def _ep_constraint(x: jax.Array) -> jax.Array:
    """Pin the expert-parallel layout of an [E, C, ...] buffer.

    Without this, GSPMD shards the expert matmuls over the expert dim only
    (16 of 256 chips' worth of parallelism) — measured as a 16x per-chip
    FLOP inflation in the dry-run (EXPERIMENTS.md §Perf, dbrx cell).  The
    constraint shards experts over "model" AND each expert's capacity slots
    over the data axes, making the token->expert redistribution an
    all-to-all and the einsum fully partitioned (EP × DP).
    """
    from jax.sharding import PartitionSpec as P
    rest = (None,) * (x.ndim - 2)
    for dp in (("pod", "data"), ("data",), None):
        try:
            return jax.lax.with_sharding_constraint(
                x, P("model", dp, *rest))
        except (ValueError, KeyError, RuntimeError):
            continue
    return x  # no mesh context (single-device tests)


def _group_constraint(x: jax.Array, major: str) -> jax.Array:
    """Constrain a grouped buffer.

    major == "data":  [G, ...] with G on the DP axes and NOTHING on
    "model" — every dispatch/combine gather and scatter is then strictly
    shard-local (a gather touching a model-sharded dim degenerates to a
    replicate+all-reduce; measured in §Perf iteration 3).
    major == "model": [E, G, ...] expert-major (EP×DP) for the expert
    matmuls.  The transpose between the two layouts is the canonical MoE
    all-to-all, which GSPMD partitions natively."""
    from jax.sharding import PartitionSpec as P
    rest = (None,) * (x.ndim - 2)
    for dp in (("pod", "data"), ("data",), None):
        try:
            if major == "data":
                return jax.lax.with_sharding_constraint(
                    x, P(dp, None, *rest))
            return jax.lax.with_sharding_constraint(
                x, P("model", dp, *rest))
        except (ValueError, KeyError, RuntimeError):
            continue
    return x


def moe_apply(params: Params, x: jax.Array, cfg: nn.ModelConfig):
    """x: [B, N, D].  Returns (out, aux_load_balance_loss).

    Grouped capacity dispatch (GSPMD MoE layout, §Perf iterations 1-3):
    tokens are split into G groups aligned with the data shards; routing,
    slotting, and the index-scatter/row-gather dispatch are *local to each
    group* (no cross-shard scatter); the [G, E, Cg, d] -> [E, G, Cg, d]
    transpose between the data-major and expert-major layouts is the one
    all-to-all, which GSPMD partitions natively.  Per-group capacity
    Cg = ceil(Tg·K/E · capacity_factor) (standard grouped-MoE semantics).
    """
    b, n, d = x.shape
    e, kk = cfg.n_experts, cfg.moe_top_k
    ct = cfg.compute_dtype
    t = b * n
    g = math.gcd(t, getattr(cfg, "moe_groups", 0) or 16)
    tg = t // g
    tokens = x.reshape(g, tg, d)

    gates = jax.nn.softmax(
        (tokens.astype(jnp.float32) @ params["router"]), axis=-1)  # [G,Tg,E]
    gate_w, gate_idx = jax.lax.top_k(gates, kk)                    # [G,Tg,K]
    gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)

    cap = max(8, int(math.ceil(tg * kk / e * cfg.moe_capacity_factor)))
    cap = ((cap + 7) // 8) * 8

    assign = gate_idx.reshape(g, tg * kk)
    slot = jax.vmap(lambda a: _dispatch_slots(a, e))(assign)
    keep = slot < cap
    dst = jnp.where(keep, assign * cap + slot, e * cap)            # [G, Tg·K]

    # local index-scatter (int32 only) + local row-gather per group
    rows = jnp.broadcast_to(
        (jnp.arange(tg * kk, dtype=jnp.int32) // kk)[None], dst.shape)
    src = jax.vmap(lambda d_, r_: jnp.zeros((e * cap + 1,), jnp.int32)
                   .at[d_].set(r_))(dst, rows)[:, : e * cap]       # [G, E·Cg]
    xe = jnp.take_along_axis(tokens.astype(ct), src[..., None], axis=1)
    xe = _group_constraint(xe.reshape(g, e, cap, d), "data")

    # the MoE all-to-all: data-major -> expert-major
    xe = _group_constraint(jnp.swapaxes(xe, 0, 1), "model")        # [E,G,Cg,d]

    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, params["wg"].astype(ct)))
    h = h * jnp.einsum("egcd,edf->egcf", xe, params["wi"].astype(ct))
    ye = jnp.einsum("egcf,efd->egcd", h, params["wo"].astype(ct))
    ye = _group_constraint(ye, "model")

    # all-to-all back, then local combine per group
    ye = _group_constraint(jnp.swapaxes(ye, 0, 1), "data")         # [G,E,Cg,d]
    ypad = jnp.concatenate(
        [ye.reshape(g, e * cap, d),
         jnp.zeros((g, 1, d), ct)], axis=1)
    y_tok = jnp.take_along_axis(ypad, dst[..., None], axis=1)
    y_tok = y_tok.reshape(g, tg, kk, d)
    w = jnp.where(keep.reshape(g, tg, kk), gate_w, 0.0).astype(ct)
    out = jnp.einsum("gtkd,gtk->gtd", y_tok, w).reshape(b, n, d)

    if cfg.n_shared_experts:
        out = out + nn.swiglu_apply(params["shared"], x, cfg)

    # switch-style load-balance aux loss
    frac = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32),
                    axis=(0, 1))
    imp = jnp.mean(gates, axis=(0, 1))
    aux = e * jnp.sum(frac * imp)
    return out, aux
