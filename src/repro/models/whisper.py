"""Whisper-style encoder-decoder (audio backbone only; conv frontend stub).

The modality frontend is a STUB per the brief: ``input_specs`` supplies
precomputed frame embeddings [B, T_enc, D] (what the two conv layers would
produce).  MiTA applies to the *encoder* self-attention in its native
bidirectional form and to the decoder self-attention causally; cross-
attention stays full (T_enc = 1500 is small) — DESIGN.md §Arch-applicability.

Decode: decoder self-attention cache + cross-attention K/V precomputed once
from the encoder output.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import mita_decode as mdec
from repro.core.baselines import full_attention
from repro.models import modules as nn
from repro.models import transformer as tfm

Params = dict[str, Any]


def _xattn_init(rng, cfg: nn.ModelConfig) -> Params:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.dh
    ks = jax.random.split(rng, 4)
    return {"wq": nn.dense_init(ks[0], d, h * dh, cfg.param_dtype),
            "wk": nn.dense_init(ks[1], d, h * dh, cfg.param_dtype),
            "wv": nn.dense_init(ks[2], d, h * dh, cfg.param_dtype),
            "wo": nn.dense_init(ks[3], h * dh, d, cfg.param_dtype)}


def _xattn_kv(p: Params, enc: jax.Array, cfg: nn.ModelConfig):
    b, t, _ = enc.shape
    h, dh = cfg.n_heads, cfg.dh
    ct = cfg.compute_dtype
    k = (enc @ p["wk"].astype(ct)).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    v = (enc @ p["wv"].astype(ct)).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    return k, v


def _xattn_apply(p: Params, x: jax.Array, k: jax.Array, v: jax.Array,
                 cfg: nn.ModelConfig) -> jax.Array:
    """x: [B, N, D] queries; k/v: [B, H, T, dh] from the encoder."""
    b, n, _ = x.shape
    h, dh = cfg.n_heads, cfg.dh
    ct = cfg.compute_dtype
    q = (x @ p["wq"].astype(ct)).reshape(b, n, h, dh).transpose(0, 2, 1, 3)
    o = full_attention(q, k, v, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(b, n, h * dh)
    return o @ p["wo"].astype(ct)


def enc_block_init(rng, cfg: nn.ModelConfig) -> Params:
    ks = jax.random.split(rng, 2)
    return {"ln1": jnp.zeros((cfg.d_model,), cfg.param_dtype),
            "ln2": jnp.zeros((cfg.d_model,), cfg.param_dtype),
            "attn": nn.attention_init(ks[0], cfg),
            "mlp": nn.gelu_mlp_init(ks[1], cfg)}


def dec_block_init(rng, cfg: nn.ModelConfig) -> Params:
    ks = jax.random.split(rng, 3)
    return {"ln1": jnp.zeros((cfg.d_model,), cfg.param_dtype),
            "ln2": jnp.zeros((cfg.d_model,), cfg.param_dtype),
            "ln3": jnp.zeros((cfg.d_model,), cfg.param_dtype),
            "attn": nn.attention_init(ks[0], cfg),
            "xattn": _xattn_init(ks[1], cfg),
            "mlp": nn.gelu_mlp_init(ks[2], cfg)}


def whisper_init(rng, cfg: nn.ModelConfig, t_enc: int = 1500) -> Params:
    ks = jax.random.split(rng, 5)
    enc_keys = jax.random.split(ks[0], cfg.n_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_pos": (jax.random.normal(ks[2], (t_enc, cfg.d_model)) * 0.01
                    ).astype(cfg.param_dtype),
        "enc": jax.vmap(lambda k: enc_block_init(k, cfg))(enc_keys),
        "enc_ln": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "dec": jax.vmap(lambda k: dec_block_init(k, cfg))(dec_keys),
        "dec_ln": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "emb": nn.embedding_init(ks[3], cfg),
    }


def whisper_encode(params: Params, audio_embeds: jax.Array,
                   cfg: nn.ModelConfig) -> jax.Array:
    """audio_embeds: [B, T_enc, D] (conv-frontend stub output)."""
    import dataclasses
    if cfg.attn.enc_window:
        cfg = dataclasses.replace(
            cfg, attn=dataclasses.replace(cfg.attn,
                                          window=cfg.attn.enc_window))
    t = audio_embeds.shape[1]
    x = audio_embeds.astype(cfg.compute_dtype) \
        + params["enc_pos"][:t].astype(cfg.compute_dtype)
    positions = jnp.arange(t)

    def body(h, bp):
        a = nn.attention_apply(bp["attn"], nn.rms_norm(h, bp["ln1"]), cfg,
                               positions, bidir=True)
        h = h + a
        h = h + nn.gelu_mlp_apply(bp["mlp"], nn.rms_norm(h, bp["ln2"]), cfg)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc"], unroll=cfg.scan_unroll)
    return nn.rms_norm(x, params["enc_ln"])


def whisper_decode_train(params: Params, enc_out: jax.Array,
                         tokens: jax.Array, cfg: nn.ModelConfig):
    x = nn.embed(params["emb"], tokens, cfg)
    positions = jnp.arange(tokens.shape[1])

    def body(h, bp):
        a = nn.attention_apply(bp["attn"], nn.rms_norm(h, bp["ln1"]), cfg,
                               positions)
        h = h + a
        k, v = _xattn_kv(bp["xattn"], enc_out, cfg)
        h = h + _xattn_apply(bp["xattn"], nn.rms_norm(h, bp["ln2"]), k, v, cfg)
        h = h + nn.gelu_mlp_apply(bp["mlp"], nn.rms_norm(h, bp["ln3"]), cfg)
        return h, None

    x, _ = jax.lax.scan(body, x, params["dec"], unroll=cfg.scan_unroll)
    x = nn.rms_norm(x, params["dec_ln"])
    return nn.unembed(params["emb"], x, cfg)


def whisper_loss(params: Params, batch: dict, cfg: nn.ModelConfig):
    enc = whisper_encode(params, batch["audio_embeds"], cfg)
    logits = whisper_decode_train(params, enc, batch["tokens"], cfg)
    return nn.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


# ----------------------------------------------------------------- serving --

class WhisperDecState(NamedTuple):
    self_state: Any      # per-layer self-attention cache
    xk: jax.Array        # [B, H, T_enc, dh] cross K (precomputed)
    xv: jax.Array


def whisper_init_serve(params: Params, audio_embeds: jax.Array,
                       cfg: nn.ModelConfig, capacity: int):
    """Encode audio once; build stacked decoder states."""
    enc = whisper_encode(params, audio_embeds, cfg)
    b = enc.shape[0]

    def per_layer(bp):
        k, v = _xattn_kv(bp["xattn"], enc, cfg)
        return k, v

    xk, xv = jax.lax.scan(lambda _, bp: (0, per_layer(bp)), 0,
                          params["dec"], unroll=cfg.scan_unroll)[1]
    if cfg.attn.backend in ("mita", "mita_ref"):
        one = mdec.init_decode_state(
            b, cfg.n_kv, cfg.dh, capacity,
            mdec.DecodeConfig(window=cfg.attn.window, k=cfg.attn.k,
                              s=cfg.attn.s), dtype=cfg.compute_dtype)
    else:
        one = mdec.init_full_state(b, cfg.n_kv, cfg.dh, capacity,
                                   dtype=cfg.compute_dtype)
    self_states = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
    return WhisperDecState(self_state=self_states, xk=xk, xv=xv)


def whisper_decode_step(params: Params, state: WhisperDecState,
                        token: jax.Array, pos: jax.Array, cfg: nn.ModelConfig):
    x = nn.embed(params["emb"], token, cfg)

    def body(h, layer):
        bp, st, xk, xv = layer
        a, st = tfm.attention_decode(bp["attn"], nn.rms_norm(h, bp["ln1"]),
                                     st, cfg, pos)
        h = h + a
        h = h + _xattn_apply(bp["xattn"],
                             nn.rms_norm(h, bp["ln2"])[:, None, :],
                             xk, xv, cfg)[:, 0]
        h = h + nn.gelu_mlp_apply(bp["mlp"], nn.rms_norm(h, bp["ln3"]), cfg)
        return h, st

    x, new_self = jax.lax.scan(
        body, x, (params["dec"], state.self_state, state.xk, state.xv),
        unroll=cfg.scan_unroll)
    logits = nn.unembed(params["emb"], nn.rms_norm(x, params["dec_ln"]), cfg)
    return logits, state._replace(self_state=new_self)
