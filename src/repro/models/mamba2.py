"""Mamba-2 (SSD — state-space duality) model, attention-free.

Per DESIGN.md §Arch-applicability: MiTA is inapplicable (no attention); in
the paper's taxonomy the SSD state *is* the compressed fast-weight module
(scaling-by-compression with a recurrent expert).  Implemented with the
chunk-parallel SSD algorithm (Dao & Gu, 2024, "minimal SSD"): quadratic
attention-like matmuls inside chunks (MXU-friendly) + a linear recurrence
across chunk states — O(N·Q) compute, O(N/Q) sequential depth.

Decode is the dual recurrent form: h ← h·exp(dtA) + dt·B⊗x, y = C·h + D·x.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import modules as nn

Params = dict[str, Any]

_CONV_K = 4
_CHUNK = 64


def _segsum(x: jax.Array) -> jax.Array:
    """[..., T] -> [..., T, T]: L[i, j] = sum_{j < t <= i} x_t, -inf above
    the diagonal (the 1-semiseparable decay mask of SSD)."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, chunk: int = _CHUNK):
    """Chunk-parallel SSD.

    x:  [B, L, H, P]   inputs per head
    dt: [B, L, H]      positive step sizes (already softplus'd)
    a_log: [H]         negative state decay (A = -exp(a_log))
    b, c: [B, L, S]    input/output projections (single group)
    Returns y: [B, L, H, P].
    """
    bsz, l, h, p = x.shape
    s = b.shape[-1]
    nc = l // chunk
    q = chunk

    da = dt * (-jnp.exp(a_log.astype(jnp.float32)))[None, None, :]  # [B,L,H]
    xdt = x * dt[..., None]

    # reshape to chunks
    da_c = da.reshape(bsz, nc, q, h).transpose(0, 3, 1, 2)       # [B,H,C,Q]
    x_c = xdt.reshape(bsz, nc, q, h, p)                          # [B,C,Q,H,P]
    b_c = b.reshape(bsz, nc, q, s)
    c_c = c.reshape(bsz, nc, q, s)

    a_cs = jnp.cumsum(da_c, axis=-1)                             # [B,H,C,Q]

    # 1) intra-chunk (diagonal blocks):  Y[i] += sum_{j<=i} C_i·B_j L_ij x_j
    lmask = jnp.exp(_segsum(da_c))                               # [B,H,C,Q,Q]
    cb = jnp.einsum("bcis,bcjs->bcij", c_c, b_c)                 # [B,C,Q,Q]
    y_diag = jnp.einsum("bcij,bhcij,bcjhp->bcihp",
                        cb, lmask, x_c)

    # 2) chunk final states: state[c] = sum_j exp(A_end - A_j) B_j x_j
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)                # [B,H,C,Q]
    states = jnp.einsum("bcjs,bhcj,bcjhp->bchps", b_c, decay_states, x_c)

    # 3) inter-chunk linear recurrence over chunk states
    chunk_decay = jnp.exp(a_cs[..., -1])                         # [B,H,C]

    def op(left, right):
        al, sl = left
        ar, sr = right
        return al * ar, sl * ar[..., None, None] + sr

    dec_t = chunk_decay.transpose(0, 2, 1)                       # [B,C,H]
    _, states_inc = jax.lax.associative_scan(op, (dec_t, states), axis=1)
    # states_inc[c] = state at END of chunk c; we need state BEFORE chunk c
    prev = jnp.concatenate(
        [jnp.zeros_like(states_inc[:, :1]), states_inc[:, :-1]], axis=1)

    # 4) state -> output contribution
    state_decay = jnp.exp(a_cs)                                  # [B,H,C,Q]
    y_off = jnp.einsum("bcis,bhci,bchps->bcihp", c_c, state_decay, prev)

    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y


def mamba_block_init(rng, cfg: nn.ModelConfig) -> Params:
    d = cfg.d_model
    d_in = 2 * d                       # expand factor 2
    hdim = 64
    heads = d_in // hdim
    s = getattr(cfg, "ssm_state", 0) or 128
    ks = jax.random.split(rng, 6)
    return {
        "ln": jnp.zeros((d,), cfg.param_dtype),
        "w_in": nn.dense_init(ks[0], d, 2 * d_in + 2 * s + heads, cfg.param_dtype),
        "conv": (jax.random.normal(ks[1], (_CONV_K, d_in + 2 * s)) * 0.1
                 ).astype(cfg.param_dtype),
        "a_log": jnp.zeros((heads,), cfg.param_dtype),
        "dt_bias": jnp.full((heads,), -1.0, cfg.param_dtype),
        "d_skip": jnp.ones((heads,), cfg.param_dtype),
        "ln_y": jnp.zeros((d_in,), cfg.param_dtype),
        "w_out": nn.dense_init(ks[2], d_in, d, cfg.param_dtype),
    }


def _mamba_proj(p: Params, xn: jax.Array, cfg: nn.ModelConfig):
    d = cfg.d_model
    d_in = 2 * d
    hdim = 64
    heads = d_in // hdim
    s = 128
    ct = cfg.compute_dtype
    # Pad the projection width to a 32-multiple: a trailing remainder
    # column rides a different XLA:CPU GEMM micro-kernel whose reduction
    # order depends on the M dimension, which would break the pinned
    # bit-identity between the chunk-parallel prefill ([S·nc, D] GEMM) and
    # the per-token decode step ([S, D] GEMM).  Zero columns are sliced
    # off; every real column's dot product is unchanged arithmetic.
    w_in = p["w_in"].astype(ct)
    pad = (-w_in.shape[-1]) % 32
    if pad:
        w_in = jnp.concatenate(
            [w_in, jnp.zeros((w_in.shape[0], pad), w_in.dtype)], axis=-1)
    zxbcdt = (xn @ w_in)[..., :2 * d_in + 2 * s + heads]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: 2 * d_in + 2 * s]
    dt = zxbcdt[..., 2 * d_in + 2 * s:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return z, xbc, dt, (d_in, hdim, heads, s)


def mamba_block_apply(p: Params, x: jax.Array, cfg: nn.ModelConfig):
    ct = cfg.compute_dtype
    bsz, l, d = x.shape
    xn = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    z, xbc, dt, (d_in, hdim, heads, s) = _mamba_proj(p, xn, cfg)

    xpad = jnp.pad(xbc, ((0, 0), (_CONV_K - 1, 0), (0, 0)))
    xbc = jax.nn.silu(sum(xpad[:, j: j + l] * p["conv"][j].astype(ct)
                          for j in range(_CONV_K)))
    xs = xbc[..., :d_in].reshape(bsz, l, heads, hdim)
    b = xbc[..., d_in: d_in + s]
    c = xbc[..., d_in + s:]

    chunk = min(_CHUNK, l)
    y = ssd_chunked(xs.astype(jnp.float32), dt, p["a_log"],
                    b.astype(jnp.float32), c.astype(jnp.float32), chunk=chunk)
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, l, d_in).astype(ct)
    y = nn.rms_norm(y * jax.nn.silu(z), p["ln_y"], cfg.norm_eps)
    return x + y @ p["w_out"].astype(ct)


def mamba_init(rng, cfg: nn.ModelConfig) -> Params:
    k_emb, k_blocks = jax.random.split(rng)
    keys = jax.random.split(k_blocks, cfg.n_layers)
    return {
        "emb": nn.embedding_init(k_emb, cfg),
        "blocks": jax.vmap(lambda k: mamba_block_init(k, cfg))(keys),
        "ln_f": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }


def mamba_forward(params: Params, tokens: jax.Array, cfg: nn.ModelConfig):
    x = nn.embed(params["emb"], tokens, cfg)

    def body(h, bp):
        return mamba_block_apply(bp, h, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
    x = nn.rms_norm(x, params["ln_f"])
    return nn.unembed(params["emb"], x, cfg), jnp.zeros((), jnp.float32)


def mamba_loss(params, batch, cfg: nn.ModelConfig):
    logits, _ = mamba_forward(params, batch["tokens"], cfg)
    return nn.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


class MambaState(NamedTuple):
    h: jax.Array      # [B, H, P, S] ssm state (f32)
    conv: jax.Array   # [B, _CONV_K-1, d_in + 2S]


def mamba_init_decode_states(cfg: nn.ModelConfig, batch: int, capacity: int):
    d_in, s = 2 * cfg.d_model, 128
    heads = d_in // 64
    one = MambaState(h=jnp.zeros((batch, heads, 64, s), jnp.float32),
                     conv=jnp.zeros((batch, _CONV_K - 1, d_in + 2 * s), jnp.float32))
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)


def mamba_block_decode(p: Params, x: jax.Array, st: MambaState,
                       cfg: nn.ModelConfig):
    """x: [B, D]."""
    ct = cfg.compute_dtype
    bsz, d = x.shape
    xn = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    z, xbc, dt, (d_in, hdim, heads, s) = _mamba_proj(p, xn[:, None, :], cfg)
    z, xbc, dt = z[:, 0], xbc[:, 0], dt[:, 0]

    hist = jnp.concatenate([st.conv, xbc[:, None, :].astype(jnp.float32)], axis=1)
    xbc = jax.nn.silu(sum(hist[:, j] * p["conv"][j].astype(jnp.float32)
                          for j in range(_CONV_K))).astype(jnp.float32)
    xs = xbc[..., :d_in].reshape(bsz, heads, hdim)
    b = xbc[..., d_in: d_in + s]
    c = xbc[..., d_in + s:]

    da = jnp.exp(dt * (-jnp.exp(p["a_log"].astype(jnp.float32)))[None, :])
    h = st.h * da[..., None, None] + jnp.einsum(
        "bh,bhp,bs->bhps", dt, xs, b)
    y = jnp.einsum("bhps,bs->bhp", h, c) \
        + xs * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, d_in).astype(ct)
    y = nn.rms_norm(y * jax.nn.silu(z), p["ln_y"], cfg.norm_eps)
    return x + y @ p["w_out"].astype(ct), MambaState(h=h, conv=hist[:, 1:])


def mamba_decode_step(params: Params, states, token: jax.Array,
                      pos: jax.Array, cfg: nn.ModelConfig):
    x = nn.embed(params["emb"], token, cfg)

    def body(h, layer):
        bp, st = layer
        h, st = mamba_block_decode(bp, h, st, cfg)
        return h, st

    x, new_states = jax.lax.scan(body, x, (params["blocks"], states),
                                 unroll=cfg.scan_unroll)
    logits = nn.unembed(params["emb"], nn.rms_norm(x, params["ln_f"]), cfg)
    return logits, new_states


# ------------------------------------------------------ slot-addressed ops --
#
# Serving entry points (repro.serve.backends.recurrent).  The SSD state is
# the paper-taxonomy compressed fast-weight module: CONSTANT size per
# request, so a "slot" is just an index into the batch axis — no paging
# indirection.  Three ops give the continuous-batching scheduler everything
# it needs: zero a slot at admission (`core.slotted.zero_slot`), advance a
# fixed-shape chunk of prompt for any subset of slots
# (`mamba_prefill_chunk`), and step the whole slot batch
# (`mamba_decode_step` — lanes are independent, so a slot's tokens never
# depend on its neighbours).  Preemption recompute = re-running the same
# chunk scans over prompt + emitted tokens: the per-token update below IS
# the decode-step update, so the rebuilt state is bit-identical.

def mamba_slot_states(cfg: nn.ModelConfig, n_slots: int):
    """Stacked per-layer slot states (leaves [L, S, ...])."""
    return mamba_init_decode_states(cfg, n_slots, 0)


def _mamba_block_prefill(p: Params, x: jax.Array, st: MambaState,
                         valid: jax.Array, n_valid: jax.Array,
                         cfg: nn.ModelConfig):
    """One layer's chunk-parallel prefill step.

    Every position-local op — norm, input projection, causal conv, gates,
    skip/output path — runs ONCE over the whole [S, nc] chunk; only the
    O(nc) SSD state recurrence and its per-token readout stay sequential.
    The per-token arithmetic (ops, operand order, dtypes, einsum
    expressions) is EXACTLY `mamba_block_decode`'s — valid tokens are a
    prefix per row, so each token's conv history and recurrence inputs
    equal what the sequential scan would feed it, and the rebuilt state
    plus every valid position's output are bit-identical to scanning the
    decode step token-by-token.

    x: [S, nc, D]; valid: [S, nc] bool; n_valid: [S] i32.
    """
    ct = cfg.compute_dtype
    bsz, nc, _ = x.shape
    xn = nn.rms_norm(x, p["ln"], cfg.norm_eps)
    z, xbc, dt, (d_in, hdim, heads, s) = _mamba_proj(p, xn, cfg)

    # token j's conv history rows are exactly padded[:, j : j + _CONV_K]
    padded = jnp.concatenate([st.conv, xbc.astype(jnp.float32)], axis=1)
    xbc = jax.nn.silu(sum(padded[:, j: j + nc]
                          * p["conv"][j].astype(jnp.float32)
                          for j in range(_CONV_K))).astype(jnp.float32)
    xs = xbc[..., :d_in].reshape(bsz, nc, heads, hdim)
    b = xbc[..., d_in: d_in + s]
    c = xbc[..., d_in + s:]
    da = jnp.exp(dt * (-jnp.exp(p["a_log"].astype(jnp.float32)))[None, None, :])

    def tstep(h_prev, inp):
        dt_t, xs_t, b_t, c_t, da_t, vj = inp
        h_new = h_prev * da_t[..., None, None] + jnp.einsum(
            "bh,bhp,bs->bhps", dt_t, xs_t, b_t)
        y_t = jnp.einsum("bhps,bs->bhp", h_new, c_t)
        return jnp.where(vj[:, None, None, None], h_new, h_prev), y_t

    h_fin, ys = jax.lax.scan(
        tstep, st.h,
        (jnp.moveaxis(dt, 0, 1), jnp.moveaxis(xs, 0, 1),
         jnp.moveaxis(b, 0, 1), jnp.moveaxis(c, 0, 1),
         jnp.moveaxis(da, 0, 1), valid.T))

    y = jnp.moveaxis(ys, 0, 1) \
        + xs * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, nc, d_in).astype(ct)
    y = nn.rms_norm(y * jax.nn.silu(z), p["ln_y"], cfg.norm_eps)
    # final conv tail = the last _CONV_K-1 raw inputs at each row's last
    # valid token; n_valid == 0 indexes straight back into st.conv
    idx = (n_valid[:, None] + jnp.arange(_CONV_K - 1)[None, :])[..., None]
    conv_fin = jnp.take_along_axis(padded, idx, axis=1)
    return x + y @ p["w_out"].astype(ct), MambaState(h=h_fin, conv=conv_fin)


def mamba_prefill_chunk(params: Params, states, tokens: jax.Array,
                        t0: jax.Array, n_valid: jax.Array,
                        cfg: nn.ModelConfig):
    """Chunk-parallel prefill of one fixed-shape chunk into a subset of
    slots (`_mamba_block_prefill` per layer): the chunk's GEMMs, conv, and
    gates are bulk [S, nc] ops; only the SSD recurrence itself is scanned.
    Bit-identical — states and valid-position outputs — to
    `mamba_prefill_chunk_seq`'s token-sequential scan of the exact decode
    update (pinned by tests/test_recurrent_prefill.py), so
    recompute-from-prompt preemption stays exact while TTFT drops by
    roughly the chunk width's worth of per-token dispatch latency.

    tokens: [S, nc] int32 (rows with n_valid == 0 are untouched);
    t0: [S] int32 resume points (unused by the position-free SSD
    recurrence; kept for signature parity with the hybrid model);
    n_valid: [S] int32 valid tokens per row.  ONE compiled shape per chunk
    length serves every chunk of every request at any resume point.

    Returns (logits [S, V] at each row's last valid position, states).
    """
    del t0
    _, nc = tokens.shape
    x = nn.embed(params["emb"], tokens, cfg)              # [S, nc, D]
    valid = jnp.arange(nc)[None, :] < n_valid[:, None]    # [S, nc]

    def body(h, layer):
        bp, st = layer
        return _mamba_block_prefill(bp, h, st, valid, n_valid, cfg)

    x, new_states = jax.lax.scan(body, x, (params["blocks"], states),
                                 unroll=cfg.scan_unroll)
    x = nn.rms_norm(x, params["ln_f"])
    last = jnp.take_along_axis(
        x, jnp.maximum(n_valid - 1, 0)[:, None, None], axis=1)[:, 0]
    return nn.unembed(params["emb"], last, cfg), new_states


def mamba_prefill_chunk_seq(params: Params, states, tokens: jax.Array,
                            t0: jax.Array, n_valid: jax.Array,
                            cfg: nn.ModelConfig):
    """Token-sequential reference for `mamba_prefill_chunk`: a `lax.scan`
    of the EXACT `mamba_block_decode` update, masked per token by
    validity.  Kept as the bit-identity oracle for the chunk-parallel path
    (and its bench baseline) — a row's state after its chunks equals the
    state the decode path would have built token-by-token.
    """
    del t0
    from repro.core import slotted

    _, nc = tokens.shape
    x = nn.embed(params["emb"], tokens, cfg)              # [S, nc, D]
    valid = jnp.arange(nc)[None, :] < n_valid[:, None]    # [S, nc]

    def body(h, layer):
        bp, st = layer

        def tstep(st, inp):
            xj, vj = inp
            y, st_new = mamba_block_decode(bp, xj, st, cfg)
            return slotted.where_slots(vj, st_new, st), y

        st, ys = jax.lax.scan(tstep, st,
                              (jnp.moveaxis(h, 0, 1), valid.T))
        return jnp.moveaxis(ys, 0, 1), st

    x, new_states = jax.lax.scan(body, x, (params["blocks"], states),
                                 unroll=cfg.scan_unroll)
    x = nn.rms_norm(x, params["ln_f"])
    last = jnp.take_along_axis(
        x, jnp.maximum(n_valid - 1, 0)[:, None, None], axis=1)[:, 0]
    return nn.unembed(params["emb"], last, cfg), new_states
