"""Decoder-only transformer LM (dense or MoE FFN) with MiTA attention.

Covers tinyllama, qwen3-*, stablelm (dense), deepseek-moe, dbrx (MoE) and the
LM backbone of internvl2.  Scan-over-layers keeps HLO size and compile time
independent of depth; per-layer params are stacked on axis 0.

Three entry points:
  * ``lm_loss``         — training objective (next-token CE + MoE aux).
  * ``lm_prefill``      — full forward that also builds per-layer decode
                          states (KV cache + MiTA landmark/expert caches).
  * ``lm_decode_step``  — one token for the whole batch, O(m + s·k + w)
                          attention per layer (`core.mita_decode`).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import mita_decode as mdec
from repro.models import modules as nn
from repro.models.moe import moe_apply, moe_init

Params = dict[str, Any]


# ------------------------------------------------------------------ block ---

def block_init(rng, cfg: nn.ModelConfig) -> Params:
    ks = jax.random.split(rng, 3)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "attn": nn.attention_init(ks[0], cfg),
    }
    if cfg.n_experts:
        p["moe"] = moe_init(ks[1], cfg)
    else:
        p["ffn"] = nn.swiglu_init(ks[1], cfg)
    return p


def block_apply(params: Params, x: jax.Array, cfg: nn.ModelConfig,
                positions: jax.Array, bidir: bool = False):
    h = nn.attention_apply(params["attn"], nn.rms_norm(x, params["ln1"]),
                           cfg, positions, bidir=bidir)
    x = x + h
    if cfg.n_experts:
        f, aux = moe_apply(params["moe"], nn.rms_norm(x, params["ln2"]), cfg)
    else:
        f, aux = nn.swiglu_apply(params["ffn"],
                                 nn.rms_norm(x, params["ln2"]), cfg), 0.0
    return x + f, jnp.asarray(aux, jnp.float32)


# ------------------------------------------------------------------ model ---

def lm_init(rng, cfg: nn.ModelConfig) -> Params:
    k_emb, k_blocks, k_ln = jax.random.split(rng, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: block_init(k, cfg))(block_keys)
    return {
        "emb": nn.embedding_init(k_emb, cfg),
        "blocks": blocks,
        "ln_f": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }


def lm_backbone(params: Params, x: jax.Array, cfg: nn.ModelConfig,
                positions: Optional[jax.Array] = None, bidir: bool = False):
    """Run the layer stack on embeddings x: [B, N, D] -> (x, aux_loss)."""
    if positions is None:
        positions = jnp.arange(x.shape[1])

    def body(carry, layer_params):
        h, aux = carry
        h, a = block_apply(layer_params, h, cfg, positions, bidir=bidir)
        return (h, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"], unroll=cfg.scan_unroll)
    return nn.rms_norm(x, params["ln_f"]), aux


def lm_forward(params: Params, tokens: jax.Array, cfg: nn.ModelConfig,
               extra_embeds: Optional[jax.Array] = None):
    """tokens: [B, N] -> (logits [B, N, V], aux).  ``extra_embeds`` (VLM):
    [B, P, D] multimodal embeddings overwriting the first P positions."""
    x = nn.embed(params["emb"], tokens, cfg)
    if extra_embeds is not None:
        p = extra_embeds.shape[1]
        x = jnp.concatenate(
            [extra_embeds.astype(x.dtype), x[:, p:]], axis=1)
    x, aux = lm_backbone(params, x, cfg)
    return nn.unembed(params["emb"], x, cfg), aux


def lm_loss(params: Params, batch: dict[str, jax.Array], cfg: nn.ModelConfig,
            aux_weight: float = 0.01) -> jax.Array:
    logits, aux = lm_forward(params, batch["tokens"], cfg,
                             extra_embeds=batch.get("image_embeds"))
    loss = nn.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return loss + aux_weight * aux / cfg.n_layers


# ----------------------------------------------------------------- decode ---

def _decode_cfg(cfg: nn.ModelConfig) -> mdec.DecodeConfig:
    return mdec.DecodeConfig(window=cfg.attn.window, k=cfg.attn.k,
                             s=cfg.attn.s,
                             external_finalize=cfg.attn.external_finalize,
                             prefill_impl=cfg.attn.prefill_impl,
                             paged_impl=cfg.attn.paged_impl,
                             finalize_impl=cfg.attn.finalize_impl,
                             vmem_budget=cfg.attn.vmem_budget)


def lm_finalize_states(states, cfg: nn.ModelConfig):
    """Serving-loop landmark finalize for all layers (external mode) —
    call every ``cfg.attn.window`` decoded tokens."""
    dcfg = _decode_cfg(cfg)
    return jax.lax.map(lambda st: mdec.mita_finalize_if_due(st, dcfg), states)


def init_decode_states(cfg: nn.ModelConfig, batch: int, capacity: int):
    """Stacked per-layer decode states (scan axis 0)."""
    if cfg.attn.backend in ("mita", "mita_ref"):
        one = mdec.init_decode_state(batch, cfg.n_kv, cfg.dh, capacity,
                                     _decode_cfg(cfg), dtype=cfg.compute_dtype)
    else:
        one = mdec.init_full_state(batch, cfg.n_kv, cfg.dh, capacity,
                                   dtype=cfg.compute_dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)


def attention_decode(params: Params, x: jax.Array, state, cfg: nn.ModelConfig,
                     pos: jax.Array):
    """One-token attention. x: [B, D]; pos: scalar position."""
    b, _ = x.shape
    kv, g, dh = cfg.n_kv, cfg.group, cfg.dh
    ct = cfg.compute_dtype
    q = (x @ params["wq"].astype(ct)).reshape(b, kv, g, dh)
    k = (x @ params["wk"].astype(ct)).reshape(b, kv, dh)
    v = (x @ params["wv"].astype(ct)).reshape(b, kv, dh)
    if cfg.qk_norm:
        q = nn.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = nn.rms_norm(k, params["k_norm"], cfg.norm_eps)
    posv = pos[None] if pos.ndim == 0 else pos
    q = nn.rope(q[..., None, :], posv, cfg.rope_theta)[..., 0, :]
    k = nn.rope(k[..., None, :], posv, cfg.rope_theta)[..., 0, :]

    if cfg.attn.backend in ("mita", "mita_ref"):
        o, state = mdec.mita_decode_step(state, q, k, v, _decode_cfg(cfg))
    else:
        o, state = mdec.full_decode_step(state, q, k, v)
    o = o.reshape(b, cfg.n_heads * dh)
    return o @ params["wo"].astype(ct), state


def block_decode(params: Params, x: jax.Array, state, cfg: nn.ModelConfig,
                 pos: jax.Array):
    h, state = attention_decode(params["attn"], nn.rms_norm(x, params["ln1"]),
                                state, cfg, pos)
    x = x + h
    xn = nn.rms_norm(x, params["ln2"])
    if cfg.n_experts:
        f, _ = moe_apply(params["moe"], xn[:, None, :], cfg)
        f = f[:, 0]
    else:
        f = nn.swiglu_apply(params["ffn"], xn, cfg)
    return x + f, state


def init_slot_attn_state(cfg: nn.ModelConfig, n_slots: int, capacity: int):
    """ONE layer's per-slot monolithic attention decode state: leaves
    [S, 1, ...] with per-slot ``t`` of shape [S] — each slot is a B == 1
    monolithic cache, so slots advance at independent positions under
    `attention_decode_slots`' vmap.  The slot-addressed analogue of
    `mdec.init_paged_state` for models whose attention context is bounded
    per request (hybrid RG-LRU blocks) rather than pooled."""
    if cfg.attn.backend in ("mita", "mita_ref"):
        one = mdec.init_decode_state(1, cfg.n_kv, cfg.dh, capacity,
                                     _decode_cfg(cfg),
                                     dtype=cfg.compute_dtype)
    else:
        one = mdec.init_full_state(
            1, cfg.n_kv, cfg.dh, min(capacity, cfg.attn.local_window),
            dtype=cfg.compute_dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_slots,) + a.shape), one)


def attention_decode_slots(params: Params, x: jax.Array, state,
                           cfg: nn.ModelConfig, pos: jax.Array):
    """One-token attention with PER-SLOT positions over per-slot monolithic
    caches.  x: [S, D]; pos: [S]; state leaves [S, 1, ...] with per-slot
    ``t`` — `mita_decode_step` / `full_decode_step` vmapped over the slot
    axis, so one program serves slots at arbitrary, independent progress
    (the serving engine's recurrent backend decode path)."""
    s, _ = x.shape
    kv, g, dh = cfg.n_kv, cfg.group, cfg.dh
    ct = cfg.compute_dtype
    q = (x @ params["wq"].astype(ct)).reshape(s, kv, g, dh)
    k = (x @ params["wk"].astype(ct)).reshape(s, kv, dh)
    v = (x @ params["wv"].astype(ct)).reshape(s, kv, dh)
    if cfg.qk_norm:
        q = nn.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = nn.rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = nn.rope(q[..., None, :], pos[:, None, None, None],
                cfg.rope_theta)[..., 0, :]
    k = nn.rope(k[..., None, :], pos[:, None, None], cfg.rope_theta)[..., 0, :]

    dcfg = _decode_cfg(cfg)
    if cfg.attn.backend in ("mita", "mita_ref"):
        step = lambda st, qs, ks, vs: mdec.mita_decode_step(
            st, qs[None], ks[None], vs[None], dcfg)
    else:
        step = lambda st, qs, ks, vs: mdec.full_decode_step(
            st, qs[None], ks[None], vs[None])
    o, state = jax.vmap(step)(state, q, k, v)             # o: [S, 1, Hkv, G, d]
    o = o[:, 0].reshape(s, cfg.n_heads * dh)
    return o @ params["wo"].astype(ct), state


def block_decode_slots(params: Params, x: jax.Array, state,
                       cfg: nn.ModelConfig, pos: jax.Array):
    """`block_decode` with per-slot positions (`attention_decode_slots`)."""
    h, state = attention_decode_slots(
        params["attn"], nn.rms_norm(x, params["ln1"]), state, cfg, pos)
    x = x + h
    xn = nn.rms_norm(x, params["ln2"])
    if cfg.n_experts:
        f, _ = moe_apply(params["moe"], xn[:, None, :], cfg)
        f = f[:, 0]
    else:
        f = nn.swiglu_apply(params["ffn"], xn, cfg)
    return x + f, state


def lm_decode_step(params: Params, states, token: jax.Array,
                   pos: jax.Array, cfg: nn.ModelConfig):
    """token: [B] int32; pos: scalar. Returns (logits [B, V], states)."""
    x = nn.embed(params["emb"], token, cfg)

    def body(h, layer):
        lp, st = layer
        h, st = block_decode(lp, h, st, cfg, pos)
        return h, st

    x, new_states = jax.lax.scan(body, x, (params["blocks"], states),
                                 unroll=cfg.scan_unroll)
    logits = nn.unembed(params["emb"], nn.rms_norm(x, params["ln_f"]), cfg)
    return logits, new_states


# ---------------------------------------------------------- paged decode ---
#
# Serving-engine entry points (repro.serve): one shared KV/landmark/expert
# pool per layer, request slots advance independently (per-slot positions).
# The fused step is jitted ONCE for the slot batch; which request occupies a
# slot, how far it has decoded, and which pages it owns are all data.

def init_paged_states(cfg: nn.ModelConfig, n_slots: int, n_pages: int,
                      pages_per_slot: int):
    """Stacked per-layer paged decode pools (scan axis 0)."""
    if cfg.attn.backend not in ("mita", "mita_ref"):
        raise ValueError("paged decode states require a MiTA attention "
                         "backend (the pool layout is landmark/expert aware)")
    one = mdec.init_paged_state(cfg.n_kv, cfg.dh, n_pages, n_slots,
                                pages_per_slot, _decode_cfg(cfg),
                                dtype=cfg.compute_dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)


def attention_decode_paged(params: Params, x: jax.Array, state,
                           cfg: nn.ModelConfig, pos: jax.Array,
                           page_table: jax.Array, active: jax.Array):
    """One-token attention over the paged pool. x: [S, D]; pos: [S]."""
    b, _ = x.shape
    kv, g, dh = cfg.n_kv, cfg.group, cfg.dh
    ct = cfg.compute_dtype
    q = (x @ params["wq"].astype(ct)).reshape(b, kv, g, dh)
    k = (x @ params["wk"].astype(ct)).reshape(b, kv, dh)
    v = (x @ params["wv"].astype(ct)).reshape(b, kv, dh)
    if cfg.qk_norm:
        q = nn.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = nn.rms_norm(k, params["k_norm"], cfg.norm_eps)
    # per-slot rotary positions
    q = nn.rope(q[..., None, :], pos[:, None, None, None],
                cfg.rope_theta)[..., 0, :]
    k = nn.rope(k[..., None, :], pos[:, None, None],
                cfg.rope_theta)[..., 0, :]
    o, state = mdec.mita_paged_decode_step(state, q, k, v, page_table, pos,
                                           active, _decode_cfg(cfg))
    o = o.reshape(b, cfg.n_heads * dh)
    return o @ params["wo"].astype(ct), state


def block_decode_paged(params: Params, x: jax.Array, state,
                       cfg: nn.ModelConfig, pos: jax.Array,
                       page_table: jax.Array, active: jax.Array):
    h, state = attention_decode_paged(
        params["attn"], nn.rms_norm(x, params["ln1"]), state, cfg, pos,
        page_table, active)
    x = x + h
    xn = nn.rms_norm(x, params["ln2"])
    if cfg.n_experts:
        f, _ = moe_apply(params["moe"], xn[:, None, :], cfg)
        f = f[:, 0]
    else:
        f = nn.swiglu_apply(params["ffn"], xn, cfg)
    return x + f, state


def sample_tokens(logits: jax.Array, rid: jax.Array, index: jax.Array,
                  temperature: jax.Array, key: jax.Array) -> jax.Array:
    """Per-slot on-device sampling: greedy argmax, or temperature
    categorical with a threefry key derived from ``(rid, index)`` — the
    same derivation the host sampler uses, so tokens are independent of
    batching, slot placement, and preemption schedule.

    logits: [S, V]; rid/index: [S] int32; temperature: [S] f32 (<= 0 means
    greedy); key: threefry PRNG key.  Returns [S] int32 token ids.
    """

    def first_argmax(x):
        # first-index-of-max via two plain reduces instead of jnp.argmax:
        # the XLA variadic argmax reduction does not vectorize on CPU
        # (~1.3ms for [8, 32k] — more than the rest of the decode step);
        # the tie rule (first occurrence) matches np/jnp.argmax exactly.
        # NaNs map to +inf first: np.argmax returns the first NaN index
        # (NaN compares false against the running max), and without the
        # guard `x == mx` would be all-false and return the out-of-range
        # index V
        v = x.shape[-1]
        x = jnp.where(jnp.isnan(x), jnp.inf, x)
        mx = jnp.max(x, axis=-1, keepdims=True)
        return jnp.min(jnp.where(x == mx, jnp.arange(v, dtype=jnp.int32),
                                 v), axis=-1).astype(jnp.int32)

    greedy = first_argmax(logits)

    def categorical(_):
        # `jax.random.categorical(k, lg)` is exactly
        # argmax(gumbel(k, lg.shape, lg.dtype) + lg) — replicated here so
        # the argmax can use the fast reduce while staying bit-identical
        # to the host sampler (same keys, same gumbel draw, same tie rule)
        def gumbel_logits(lg, r, i, tmp):
            k = jax.random.fold_in(jax.random.fold_in(key, r), i)
            return (jax.random.gumbel(k, lg.shape, lg.dtype)
                    + lg / jnp.maximum(tmp, 1e-6))

        return first_argmax(
            jax.vmap(gumbel_logits)(logits, rid, index, temperature))

    # all-greedy batches skip the [S, V] threefry work behind a scalar cond
    sampled = jax.lax.cond(jnp.any(temperature > 0.0), categorical,
                           lambda _: greedy, None)
    return jnp.where(temperature > 0.0, sampled, greedy)


def lm_paged_decode_step(params: Params, states, token: jax.Array,
                         pos: jax.Array, page_table: jax.Array,
                         active: jax.Array, cfg: nn.ModelConfig,
                         due: Optional[jax.Array] = None,
                         sample: Optional[tuple] = None):
    """token: [S] int32; pos: [S] per-slot positions; page_table: [S, M];
    active: [S] bool.  Returns (logits [S, V], states) — or, with
    ``sample`` set, (tokens [S] int32, states): sampling then runs inside
    the fused program (`sample_tokens`) and the serving loop downloads S
    int32 tokens per step instead of the [S, V] logits.

    ``due`` (external-finalize mode): [S] bool — slots whose last completed
    window still needs its landmark.  The finalize is fused into this
    program behind a scalar `lax.cond`, so steps where no slot crossed a
    window boundary pay one dispatch and no O(context) work.

    ``sample``: optional (rid [S] i32, index [S] i32, temperature [S] f32,
    key) — per-slot request ids, token indices, and temperatures for
    on-device sampling."""
    x = nn.embed(params["emb"], token, cfg)
    dcfg = _decode_cfg(cfg)
    any_due = jnp.any(due) if due is not None else None

    def body(h, layer):
        lp, st = layer
        if due is not None:
            st = jax.lax.cond(
                any_due,
                lambda s: mdec.mita_paged_finalize(s, page_table, pos, due,
                                                   dcfg),
                lambda s: s, st)
        h, st = block_decode_paged(lp, h, st, cfg, pos, page_table, active)
        return h, st

    x, new_states = jax.lax.scan(body, x, (params["blocks"], states),
                                 unroll=cfg.scan_unroll)
    logits = nn.unembed(params["emb"], nn.rms_norm(x, params["ln_f"]), cfg)
    if sample is None:
        return logits, new_states
    rid, index, temperature, key = sample
    return sample_tokens(logits, rid, index, temperature, key), new_states


def attention_decode_landmark(params: Params, x: jax.Array, state,
                              cfg: nn.ModelConfig, pos: jax.Array,
                              m_cnt: jax.Array) -> jax.Array:
    """Landmark-branch-only attention for the speculative drafter: the q
    projection alone (no k/v — nothing is appended), RoPE'd at the
    per-slot draft position, attending the slot's finalized landmark tiles
    (`mdec.mita_paged_landmark_attend`).  Read-only w.r.t. ``state``."""
    b, _ = x.shape
    kv, g, dh = cfg.n_kv, cfg.group, cfg.dh
    ct = cfg.compute_dtype
    q = (x @ params["wq"].astype(ct)).reshape(b, kv, g, dh)
    if cfg.qk_norm:
        q = nn.rms_norm(q, params["q_norm"], cfg.norm_eps)
    q = nn.rope(q[..., None, :], pos[:, None, None, None],
                cfg.rope_theta)[..., 0, :]
    o = mdec.mita_paged_landmark_attend(state, q, m_cnt, _decode_cfg(cfg))
    o = o.reshape(b, cfg.n_heads * dh)
    return o @ params["wo"].astype(ct)


def block_decode_landmark(params: Params, x: jax.Array, state,
                          cfg: nn.ModelConfig, pos: jax.Array,
                          m_cnt: jax.Array) -> jax.Array:
    h = attention_decode_landmark(
        params["attn"], nn.rms_norm(x, params["ln1"]), state, cfg, pos,
        m_cnt)
    x = x + h
    xn = nn.rms_norm(x, params["ln2"])
    if cfg.n_experts:
        f, _ = moe_apply(params["moe"], xn[:, None, :], cfg)
        f = f[:, 0]
    else:
        f = nn.swiglu_apply(params["ffn"], xn, cfg)
    return x + f


def lm_landmark_draft(params: Params, states, tokens: jax.Array,
                      t: jax.Array, active: jax.Array, m_cnt: jax.Array,
                      cfg: nn.ModelConfig, n_pos: int, rid: jax.Array,
                      sample_idx: jax.Array, temperature: jax.Array,
                      key: jax.Array) -> jax.Array:
    """Self-drafting forward: propose ``n_pos`` tokens per slot against
    the compressed branch only, feeding each draft to the next position.

    tokens: [S] last committed token per slot; t: [S] positions of the
    first draft; active: [S] bool (per-position masks come from the
    caller's spec-length rule folded into ``active`` — here a slot either
    drafts all ``n_pos`` positions or its carry passes through untouched
    via the masks below); m_cnt: [S] finalized landmark count (constant
    across the draft: nothing finalizes until the verify step commits).

    Sampling uses the same (rid, sample_idx + i) keys the verify step will
    use at the same output indices, so a tempered draft can actually match
    its verification.  Returns drafts [n_pos, S] int32.  Entirely
    read-only: no KV append, no q_sum accumulation, no landmark change —
    a rejected draft needs NO state rollback from this program.
    """
    def pos_body(carry, i):
        tok, si = carry
        x = nn.embed(params["emb"], tok, cfg)

        def body(h, layer):
            lp, st = layer
            return block_decode_landmark(lp, h, st, cfg, t + i, m_cnt), None

        x, _ = jax.lax.scan(body, x, (params["blocks"], states),
                            unroll=cfg.scan_unroll)
        logits = nn.unembed(params["emb"], nn.rms_norm(x, params["ln_f"]),
                            cfg)
        tok2 = jnp.where(active, sample_tokens(logits, rid, si, temperature,
                                               key), tok)
        return (tok2, si + active.astype(si.dtype)), tok2

    (_, _), drafts = jax.lax.scan(pos_body, (tokens, sample_idx),
                                  jnp.arange(n_pos))
    return drafts


def pack_prefill_into_states(states, prefill_states, slot: jax.Array,
                             pages: jax.Array, cfg: nn.ModelConfig):
    """Copy per-layer single-request prefill states into a slot's pages."""
    dcfg = _decode_cfg(cfg)
    return jax.vmap(
        lambda st, pre: mdec.pack_prefill_into_pages(st, pre, slot, pages,
                                                     dcfg),
        in_axes=(0, 0))(states, prefill_states)


def _chunk_block_body(lp, h, st, cfg: nn.ModelConfig, positions, attn):
    """Shared per-layer body of the chunk-prefill forwards: norm -> qkv ->
    paged chunk attention (``attn`` closure returns o [B, Hkv, G, nc, d])
    -> output projection -> FFN residual."""
    b, nc, _ = h.shape
    ct = cfg.compute_dtype
    xin = nn.rms_norm(h, lp["ln1"])
    q, k, v = nn._qkv(lp["attn"], xin, cfg, positions)
    o, st = attn(st, q, k[:, :, 0], v[:, :, 0])
    o = jnp.moveaxis(o, 3, 1).reshape(b, nc, cfg.n_heads * cfg.dh)
    h = h + o @ lp["attn"]["wo"].astype(ct)
    xn = nn.rms_norm(h, lp["ln2"])
    if cfg.n_experts:
        f, _ = moe_apply(lp["moe"], xn, cfg)
    else:
        f = nn.swiglu_apply(lp["ffn"], xn, cfg)
    return h + f, st


def lm_prefill_chunk(params: Params, states, tokens: jax.Array,
                     slot: jax.Array, page_table_row: jax.Array,
                     t0: jax.Array, n_valid: jax.Array, n_train: jax.Array,
                     cfg: nn.ModelConfig):
    """Prefill one chunk of one slot's prompt directly into the paged pools.

    Args:
      tokens:         [nc] int32 chunk tokens, zero-padded past ``n_valid``.
      slot:           scalar int32 destination slot.
      page_table_row: [M] int32 — the slot's page-table row; pages covering
                      positions < t0 + n_valid must be allocated.
      t0:             scalar int32 resume point (tokens already packed).
      n_valid:        scalar int32 valid tokens in this chunk.
      n_train:        scalar int32 — original prompt length; recomputed
                      generated positions (>= n_train) replicate decode-time
                      landmark availability (see `mita_chunk_prefill`).

    Returns (logits [V] at position ``t0 + n_valid - 1``, updated states).
    One compiled program per chunk length serves every chunk of every
    request — chunk index, resume point, and validity are data, so the
    engine's set of prefill program shapes stays O(1).
    """
    (nc,) = tokens.shape
    pos = t0 + jnp.arange(nc)
    x = nn.embed(params["emb"], tokens[None], cfg)
    dcfg = _decode_cfg(cfg)

    def attn(st, q, k, v):
        o, st = mdec.mita_chunk_prefill(
            st, q[0], k[0], v[0], page_table_row, slot, t0, n_valid,
            n_train, dcfg)
        return o[None], st

    def body(h, layer):
        lp, st = layer
        return _chunk_block_body(lp, h, st, cfg, pos, attn)

    x, new_states = jax.lax.scan(body, x, (params["blocks"], states),
                                 unroll=cfg.scan_unroll)
    x = nn.rms_norm(x, params["ln_f"])
    last = jnp.take(x[0], n_valid - 1, axis=0)
    return nn.unembed(params["emb"], last, cfg), new_states


def lm_prefill_chunks(params: Params, states, tokens: jax.Array,
                      job_active: jax.Array, page_table: jax.Array,
                      slots: jax.Array, t0: jax.Array, n_valid: jax.Array,
                      n_train: jax.Array, cfg: nn.ModelConfig):
    """Prefill one chunk for EVERY active prefilling slot in one program.

    Rows are jobs: the engine packs the currently-prefilling slots into a
    fixed width P (padded with distinct idle slots, ``job_active`` False),
    so one dispatch advances them all and compute scales with P, not the
    slot-batch width.

    Args:
      tokens:     [P, nc] int32 chunk tokens per row (zero-padded past
                  each row's ``n_valid``; garbage for inactive rows).
      job_active: [P] bool — which rows advance a chunk this dispatch.
      page_table: [P, M] int32 — each row's slot's page-table row.
      slots:      [P] int32 UNIQUE slot ids; t0/n_valid/n_train: [P] int32
                  (see `core.mita_decode.mita_batched_chunk_prefill`).

    Returns (logits [P, V] at each row's position ``t0 + n_valid - 1``,
    updated states).  ONE compiled shape per (chunk length, row width,
    pages-per-slot) serves every engine step — the serving engine's
    prefill work per step is one dispatch, not one per job.  Inside, the
    attention dispatches between the fused Pallas chunk-prefill kernel and
    the XLA path (`kernels.ops.use_prefill_kernel` via
    ``cfg.attn.prefill_impl``).
    """
    nc = tokens.shape[1]
    pos = t0[:, None] + jnp.arange(nc)                  # [P, nc]
    x = nn.embed(params["emb"], tokens, cfg)
    dcfg = _decode_cfg(cfg)

    def attn(st, q, k, v):
        return mdec.mita_batched_chunk_prefill(
            st, q, k, v, page_table, slots, t0, n_valid, n_train,
            job_active, dcfg)

    def body(h, layer):
        lp, st = layer
        return _chunk_block_body(lp, h, st, cfg, pos[:, None, None, :],
                                 attn)

    x, new_states = jax.lax.scan(body, x, (params["blocks"], states),
                                 unroll=cfg.scan_unroll)
    x = nn.rms_norm(x, params["ln_f"])
    last = jnp.take_along_axis(
        x, jnp.maximum(n_valid - 1, 0)[:, None, None], axis=1)[:, 0]
    return nn.unembed(params["emb"], last, cfg), new_states


def lm_prefill(params: Params, tokens: jax.Array, cfg: nn.ModelConfig,
               capacity: int,
               extra_embeds: Optional[jax.Array] = None):
    """Forward over the prompt, building per-layer decode states.

    Returns (last_logits [B, V], states).
    """
    b, n = tokens.shape
    positions = jnp.arange(n)
    x = nn.embed(params["emb"], tokens, cfg)
    if extra_embeds is not None:
        p = extra_embeds.shape[1]
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x[:, p:]], axis=1)

    def body(h, layer_params):
        xin = nn.rms_norm(h, layer_params["ln1"])
        # recompute q/k/v to build the cache (cheap relative to attention)
        q, k, v = nn._qkv(layer_params["attn"], xin, cfg, positions)
        if cfg.attn.backend in ("mita", "mita_ref"):
            st = mdec.mita_prefill_state(q, k, v, _decode_cfg(cfg), capacity)
        else:
            st = mdec.full_prefill_state(k, v, capacity)
        h, _ = block_apply(layer_params, h, cfg, positions)
        return h, st

    x, states = jax.lax.scan(body, x, params["blocks"],
                             unroll=cfg.scan_unroll)
    x = nn.rms_norm(x, params["ln_f"])
    logits = nn.unembed(params["emb"], x[:, -1], cfg)
    return logits, states
