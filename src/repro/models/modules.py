"""Pure-JAX neural-net primitives (no flax/optax in this environment).

Conventions:
  * a "module" is a pair of functions `<name>_init(rng, ...) -> params`
    (nested dict of jnp arrays) and `<name>_apply(params, x, ...)`;
  * activations default to ``cfg.compute_dtype`` (bf16 on TPU), parameters to
    ``cfg.param_dtype`` (f32 master copies); norms/softmax accumulate in f32;
  * attention tensors are [B, Hkv, G, N, dh] (G = query heads per KV group)
    so GQA broadcasting works throughout `repro.core`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.baselines import (full_attention, linear_attention,
                                  local_attention, moba_attention)
from repro.core.mita import MiTAConfig, mita_attention
from repro.core.mita_sparse import mita_attention_sparse

Params = dict[str, Any]


# ---------------------------------------------------------------- config ---

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    """Attention backend selection + MiTA hyper-parameters."""
    backend: str = "mita"     # mita | mita_ref | full | moba | agent | linear | local
    window: int = 128         # landmark window w  (m = N // w)
    k: int = 128              # expert width
    s: int = 1                # routed experts per query
    causal: bool = True
    impl: str = "sorted"      # sorted | capacity   (mita_sparse strategy)
    block_q: int = 128        # 0 = kernels.ops.default_block_q (REPRO_BLOCK_Q)
    expert_span: int = 4
    capacity_factor: float = 1.25
    landmark: str = "pool1d"          # landmark extractor (Tab. 6 ablation)
    landmark_per_group: bool = True   # share landmarks per KV-head group
    route_per_group: bool = False     # share ROUTING per KV-head group (opt)
    # "grouped": [B, Hkv, G, N, dh] (KV broadcast; landmark/expert sharing
    #            possible) — but Hkv and G are each < TP width for most
    #            GQA configs, so GSPMD cannot shard the attention math and
    #            REPLICATES routing/sort/top-k (§Perf iteration 2).
    # "repeat":  [B, H, N, dh] with KV repeated per head — H divides the
    #            TP axis, the whole MiTA pipeline shards 16-way.
    gqa_layout: str = "grouped"
    local_window: int = 2048  # for backend == "local" (recurrentgemma)
    enc_window: int = 0       # enc-dec: encoder-side window (0 = same)
    external_finalize: bool = False  # serve-loop landmark finalize (opt)
    # Chunk-prefill backend: "auto" (fused Pallas kernel on TPU when its
    # working set fits the VMEM budget; XLA elsewhere), "kernel", "xla".
    # Overridable per-process via REPRO_PREFILL_IMPL (kernels.ops).
    prefill_impl: str = "auto"
    # Paged decode-step / landmark-finalize backends, same tri-state
    # (kernels.ops.use_paged_kernel / use_finalize_kernel), and the VMEM
    # working-set budget all dispatchers honour (0 =
    # REPRO_VMEM_BUDGET_BYTES / the built-in default) — threaded into
    # DecodeConfig so the serving path can force a dispatch.
    paged_impl: str = "auto"
    finalize_impl: str = "auto"
    vmem_budget: int = 0

    def mita_cfg(self, n: int, bidir: bool = False) -> MiTAConfig:
        m = max(1, n // self.window)
        return MiTAConfig(
            m=m, k=min(self.k, n), s=min(self.s, m),
            causal=self.causal and not bidir,
            landmark=self.landmark,
            compress_only=self.backend == "agent",
            route_only=self.backend == "mita_route",
            route_per_group=self.route_per_group)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: int = 0          # 0 -> d_model // n_heads
    rope_theta: float = 1e6
    qk_norm: bool = False
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn: AttnConfig = dataclasses.field(default_factory=AttnConfig)
    # MoE (n_experts == 0 -> dense FFN)
    n_experts: int = 0
    moe_top_k: int = 2
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    # hybrid / ssm / enc-dec extras live in their model files
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: bool = False
    # Unroll layer scans (dry-run FLOP calibration: XLA cost_analysis counts
    # a while-loop body once, so calibration compiles unroll at small depth).
    scan_unroll: bool = False

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv


# ------------------------------------------------------------ primitives ---

def _normal(rng, shape, scale, dtype):
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


def dense_init(rng, d_in: int, d_out: int, dtype) -> jax.Array:
    return _normal(rng, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., N, dh]; positions: [N] or broadcastable."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., N, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention ---

def attention_init(rng, cfg: ModelConfig) -> Params:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.dh
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d, h * dh, cfg.param_dtype),
        "wk": dense_init(ks[1], d, kv * dh, cfg.param_dtype),
        "wv": dense_init(ks[2], d, kv * dh, cfg.param_dtype),
        "wo": dense_init(ks[3], h * dh, d, cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), cfg.param_dtype)
        p["k_norm"] = jnp.zeros((dh,), cfg.param_dtype)
    return p


def _qkv(params: Params, x: jax.Array, cfg: ModelConfig,
         positions: jax.Array):
    """Project to [B,Hkv,G,N,dh] query and [B,Hkv,1,N,dh] key/value."""
    b, n, _ = x.shape
    kv, g, dh = cfg.n_kv, cfg.group, cfg.dh
    ct = cfg.compute_dtype
    q = (x @ params["wq"].astype(ct)).reshape(b, n, kv, g, dh)
    k = (x @ params["wk"].astype(ct)).reshape(b, n, kv, 1, dh)
    v = (x @ params["wv"].astype(ct)).reshape(b, n, kv, 1, dh)
    q = jnp.moveaxis(q, 1, 3)   # [B,kv,G,N,dh]
    k = jnp.moveaxis(k, 1, 3)
    v = jnp.moveaxis(v, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_apply(params: Params, x: jax.Array, cfg: ModelConfig,
                    positions: Optional[jax.Array] = None,
                    bidir: bool = False) -> jax.Array:
    """Full-sequence attention (training / prefill).  x: [B, N, D]."""
    b, n, _ = x.shape
    a = cfg.attn
    if positions is None:
        positions = jnp.arange(n)
    q, k, v = _qkv(params, x, cfg, positions)

    causal = a.causal and not bidir
    if a.gqa_layout == "repeat":
        # single head dim (divisible by the TP axis): KV repeated per head
        h = cfg.n_heads
        q = q.reshape(b, h, n, cfg.dh)
        k = jnp.broadcast_to(k, (b, cfg.n_kv, cfg.group, n, cfg.dh)
                             ).reshape(b, h, n, cfg.dh)
        v = jnp.broadcast_to(v, (b, cfg.n_kv, cfg.group, n, cfg.dh)
                             ).reshape(b, h, n, cfg.dh)
    if a.backend in ("mita", "mita_ref", "agent", "mita_route"):
        mcfg = a.mita_cfg(n, bidir=bidir)
        q_lm = jnp.mean(q, axis=2, keepdims=True) if (
            a.landmark_per_group and cfg.group > 1
            and a.gqa_layout != "repeat") else None
        if a.backend == "mita_ref" or mcfg.compress_only:
            o = mita_attention(q, k, v, mcfg, q_landmarks=q_lm)
        else:
            # block_q ~ expected tokens-per-expert so a sorted block spans
            # ~2 experts on average; span-4 then drops almost nothing.
            # block_q = 0 defers to the REPRO_BLOCK_Q env default.
            from repro.kernels.ops import default_block_q
            bq = min(a.block_q or default_block_q(),
                     a.window * mcfg.s, n * mcfg.s)
            o = mita_attention_sparse(
                q, k, v, mcfg, impl=a.impl, block_q=bq,
                expert_span=min(a.expert_span, mcfg.m),
                capacity_factor=a.capacity_factor, q_landmarks=q_lm)
    elif a.backend == "full":
        o = full_attention(q, k, v, causal=causal)
    elif a.backend == "local":
        o = local_attention(q, k, v, window=min(a.local_window, n),
                            causal=causal)
    elif a.backend == "moba":
        o = moba_attention(q, k, v, block_size=a.window,
                           top_blocks=max(1, a.k // a.window), causal=causal)
    elif a.backend == "linear":
        o = linear_attention(q, k, v, causal=causal)
    else:
        raise ValueError(f"unknown attention backend {a.backend!r}")

    if a.gqa_layout == "repeat":
        o = jnp.moveaxis(o, 2, 1).reshape(b, n, cfg.n_heads * cfg.dh)
    else:
        o = jnp.moveaxis(o, 3, 1).reshape(b, n, cfg.n_heads * cfg.dh)
    return o @ params["wo"].astype(cfg.compute_dtype)


# -------------------------------------------------------------------- ffn ---

def swiglu_init(rng, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "wi": dense_init(ks[0], cfg.d_model, d_ff, cfg.param_dtype),
        "wg": dense_init(ks[1], cfg.d_model, d_ff, cfg.param_dtype),
        "wo": dense_init(ks[2], d_ff, cfg.d_model, cfg.param_dtype),
    }


def swiglu_apply(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    ct = cfg.compute_dtype
    h = jax.nn.silu(x @ params["wg"].astype(ct)) * (x @ params["wi"].astype(ct))
    return h @ params["wo"].astype(ct)


def gelu_mlp_init(rng, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 2)
    return {
        "wi": dense_init(ks[0], cfg.d_model, d_ff, cfg.param_dtype),
        "bi": jnp.zeros((d_ff,), cfg.param_dtype),
        "wo": dense_init(ks[1], d_ff, cfg.d_model, cfg.param_dtype),
        "bo": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }


def gelu_mlp_apply(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    ct = cfg.compute_dtype
    h = jax.nn.gelu(x @ params["wi"].astype(ct) + params["bi"].astype(ct))
    return h @ params["wo"].astype(ct) + params["bo"].astype(ct)


# ------------------------------------------------------------- embeddings ---

def embedding_init(rng, cfg: ModelConfig) -> Params:
    ks = jax.random.split(rng, 2)
    p = {"tok": _normal(ks[0], (cfg.vocab, cfg.d_model), 0.02, cfg.param_dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], cfg.d_model, cfg.vocab, cfg.param_dtype)
    return p


def embed(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return jnp.take(params["tok"], tokens, axis=0).astype(cfg.compute_dtype)


def unembed(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    ct = cfg.compute_dtype
    if cfg.tie_embeddings:
        return x @ params["tok"].astype(ct).T
    return x @ params["head"].astype(ct)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy, f32 accumulation. logits: [..., V]."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
