"""Vision transformer (encoder) for the paper's own experiment suite
(ImageNet-proxy classification, Tab. 2/3/6; ADE20K FLOPs, Tab. 4).

Patchification is a fixed linear projection of raw patches (the paper keeps
the standard ViT frontend; the interesting part — the attention mechanism —
comes from `repro.core` via the attention backend registry).  Bidirectional
MiTA with 2-D average-pooled landmarks (the paper's default)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import modules as nn

Params = dict[str, Any]


def vit_init(rng, cfg: nn.ModelConfig, patch_dim: int, n_classes: int) -> Params:
    ks = jax.random.split(rng, 4)
    from repro.models.transformer import block_init
    keys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "patch": nn.dense_init(ks[1], patch_dim, cfg.d_model, cfg.param_dtype),
        "pos": (jax.random.normal(ks[2], (1024, cfg.d_model)) * 0.02
                ).astype(cfg.param_dtype),
        "blocks": jax.vmap(lambda k: block_init(k, cfg))(keys),
        "ln_f": jnp.zeros((cfg.d_model,), cfg.param_dtype),
        "head": nn.dense_init(ks[3], cfg.d_model, n_classes, cfg.param_dtype),
    }


def vit_forward(params: Params, patches: jax.Array, cfg: nn.ModelConfig):
    """patches: [B, N, patch_dim] -> logits [B, n_classes]."""
    from repro.models.transformer import block_apply
    b, n, _ = patches.shape
    ct = cfg.compute_dtype
    x = patches.astype(ct) @ params["patch"].astype(ct)
    x = x + params["pos"][:n].astype(ct)
    positions = jnp.arange(n)

    def body(h, bp):
        h, _ = block_apply(bp, h, cfg, positions, bidir=True)
        return h, None

    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
    x = nn.rms_norm(jnp.mean(x, axis=1), params["ln_f"])
    return x @ params["head"].astype(ct)


def vit_loss(params: Params, batch: dict, cfg: nn.ModelConfig):
    logits = vit_forward(params, batch["patches"], cfg)
    return nn.cross_entropy(logits, batch["label"])


def vit_accuracy(params: Params, batch: dict, cfg: nn.ModelConfig):
    logits = vit_forward(params, batch["patches"], cfg)
    return jnp.mean(jnp.argmax(logits, -1) == batch["label"])


def synthetic_vision_batch(rng: jax.Array, b: int, n_patches: int,
                           patch_dim: int, n_classes: int,
                           n_signal: int = 6, noise: float = 1.0):
    """Sparse-signal synthetic 'images': only ``n_signal`` patches (at random
    positions per sample) carry the class prototype; the rest is noise.
    This is the regime the paper's mechanism targets — compression-only
    attention dilutes sparse evidence across landmark averages, while top-k
    retrieval picks the signal patches exactly."""
    kp, kn, kl = jax.random.split(rng, 3)
    protos = jax.random.normal(jax.random.PRNGKey(17),
                               (n_classes, patch_dim)) * 1.2
    labels = jax.random.randint(kl, (b,), 0, n_classes)
    x = jax.random.normal(kn, (b, n_patches, patch_dim)) * noise
    # n_signal distinct random positions per sample
    scores = jax.random.uniform(kp, (b, n_patches))
    _, pos = jax.lax.top_k(scores, n_signal)                  # [b, n_signal]
    sig = protos[labels][:, None, :] + 0.3 * jax.random.normal(
        jax.random.fold_in(kn, 1), (b, n_signal, patch_dim))
    x = jax.vmap(lambda xi, pi, si: xi.at[pi].set(si))(x, pos, sig)
    return {"patches": x, "label": labels}
