"""MiTA reproduction package.

Global numerical policy set at import so it is independent of which
subpackage is imported first:

Partitionable threefry — random bits are a pure function of (key,
position), independent of how GSPMD partitions the generating
computation.  With the legacy implementation, `jax.random.normal` inside
a jit whose out_shardings shard the result produces DIFFERENT values on
different meshes — observed as wq/wo/tok init leaves drifting between a
1-device and a (2,4) mesh, making the same train step report loss 5.8555
vs 6.0465 (test_sharded_result_matches_single_device).  Flipping it here
(not in a leaf module) keeps RNG streams identical across entry points
regardless of import order.
"""

import jax

jax.config.update("jax_threefry_partitionable", True)
