from repro.data.pipeline import (DataConfig, SyntheticLMStream,
                                 synthetic_batch, synthetic_image_embeds,
                                 synthetic_audio_embeds)
