"""Deterministic, host-sharded, stateless-resumable data pipeline.

Design points that matter at 1000+ nodes:

  * **Stateless resumability** — a batch is a pure function of
    (seed, step, host_index); restart-from-checkpoint needs only the step
    counter, no iterator state, so elastic restarts (different host count)
    re-slice the same global stream deterministically.
  * **Host sharding** — each host materializes only its slice of the global
    batch (`host_index / host_count`).
  * **Structured synthetic text** — a Zipfian Markov stream (not iid noise)
    so optimizer/benchmark loss curves have realistic token statistics and
    are actually learnable (used by the examples and benchmarks; a real
    deployment would swap in a tokenized corpus behind the same interface).
  * **Prefetch** — a background thread keeps `prefetch` batches ready.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 1024
    seq_len: int = 256
    global_batch: int = 8
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    prefetch: int = 2


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(
        key=cfg.seed, counter=np.array([0, 0, 0, step], dtype=np.uint64)))


def synthetic_batch(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """Markov-Zipf token stream; deterministic in (seed, step, host)."""
    rng = _rng_for(cfg, step)
    if cfg.global_batch % cfg.host_count:
        raise ValueError("global_batch must divide by host_count")
    local_b = cfg.global_batch // cfg.host_count
    # skip to this host's slice, keeping the global stream identical
    # regardless of host_count (elastic-restart invariance).
    all_tokens = _markov_zipf(rng, cfg.global_batch, cfg.seq_len + 1, cfg.vocab)
    lo = cfg.host_index * local_b
    tokens = all_tokens[lo: lo + local_b]
    return {"tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32)}


def _markov_zipf(rng, b: int, n: int, vocab: int) -> np.ndarray:
    """Cheap structured stream: next token = f(prev) with Zipf-ish mixing."""
    base = rng.zipf(1.5, size=(b, n)).astype(np.int64)
    drift = np.cumsum(rng.integers(0, 7, size=(b, n)), axis=1)
    return ((base + drift) % vocab).astype(np.int64)


def synthetic_image_embeds(cfg: DataConfig, step: int, n_patches: int,
                           d_model: int) -> np.ndarray:
    rng = _rng_for(cfg, step + 1_000_003)
    local_b = cfg.global_batch // cfg.host_count
    return rng.standard_normal((local_b, n_patches, d_model), dtype=np.float32)


def synthetic_audio_embeds(cfg: DataConfig, step: int, t_enc: int,
                           d_model: int) -> np.ndarray:
    rng = _rng_for(cfg, step + 2_000_003)
    local_b = cfg.global_batch // cfg.host_count
    # smooth "spectrogram-like" frames
    x = rng.standard_normal((local_b, t_enc, d_model), dtype=np.float32)
    kernel = np.ones(5, dtype=np.float32) / 5.0
    return np.apply_along_axis(
        lambda r: np.convolve(r, kernel, mode="same"), 1, x)


class SyntheticLMStream:
    """Prefetching iterator over `synthetic_batch`, resumable at any step."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(1, cfg.prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = synthetic_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
