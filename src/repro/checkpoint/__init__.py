from repro.checkpoint.manager import (CheckpointManager, restore_checkpoint,
                                      save_checkpoint)
