"""Checkpointing: async, atomic, elastic-restorable.

Fault-tolerance contract (DESIGN.md):
  * **Atomicity** — writes go to ``step_<n>.tmp/`` then ``os.rename`` to
    ``step_<n>/``; a crash mid-write never corrupts the latest checkpoint.
  * **Async** — `save` serializes device arrays to host (blocking only for
    the device->host copy), then hands file I/O to a background thread so
    the train loop resumes immediately.
  * **Elastic restore** — arrays are stored unsharded (per-host shard files
    + a manifest would be the multi-host extension; single-host here).  On
    restore, arrays are `jax.device_put` with the *current* mesh's sharding,
    so a job restarted on a different topology (e.g. 256 -> 192 chips after
    a node failure) resumes from the same state.
  * **Retention** — `CheckpointManager(keep=k)` prunes old steps.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":  # ml_dtypes (bf16, fp8): np.savez
            arr = arr.astype(np.float32)  # can't round-trip them — widen
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None,
                    async_: bool = False) -> threading.Thread | None:
    """Save pytree. Returns the writer thread if async_."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")

    def write():
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        meta = {"step": step, "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, target_tree: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> tuple[int, Any]:
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional pytree of NamedSharding matching target_tree —
    this is the *elastic* path: the stored arrays are placed onto whatever
    mesh the restarted job runs with.
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step}")
    data = np.load(os.path.join(path, "arrays.npz"))

    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    new_leaves = []
    flat_shardings = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(leaves_p))
    for (kpath, leaf), shd in zip(leaves_p, flat_shardings):
        key = "/".join(_path_str(p) for p in kpath)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        new_leaves.append(jax.device_put(arr, shd) if shd is not None
                          else jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(treedef, [l for l in new_leaves])


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; async save with join-on-exit."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        # prune BEFORE the async write starts: keep (keep-1) existing steps,
        # the in-flight step becomes the keep-th.
        self._prune(margin=1)
        self._pending = save_checkpoint(self.directory, step, tree,
                                        extra=extra, async_=True)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore(self, target_tree, step=None, shardings=None):
        return restore_checkpoint(self.directory, target_tree, step=step,
                                  shardings=shardings)

    def latest_step(self):
        return latest_step(self.directory)

    def _prune(self, margin: int = 0):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: max(0, len(steps) - (self.keep - margin))]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
