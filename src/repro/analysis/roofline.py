"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step:

  compute    = HLO_FLOPs_per_chip / peak_FLOPs          (197 TF/s bf16, v5e)
  memory     = HLO_bytes_per_chip / HBM_bw              (819 GB/s)
  collective = collective_bytes_per_chip / ICI link bw  (50 GB/s/link)

``compiled.cost_analysis()`` reports per-chip (post-SPMD-partitioning)
flops/bytes with the standard 2·M·N·K dot convention (calibrated in
EXPERIMENTS.md §Dry-run).  Collective bytes are not in cost_analysis —
we parse the optimized HLO and cost each collective with ring-algorithm
byte counts over its replica-group size n:

  all-reduce      2·(n-1)/n · payload     (reduce-scatter + all-gather phases)
  all-gather        (n-1)/n · full_result
  reduce-scatter    (n-1)/n · full_input
  all-to-all        (n-1)/n · payload
  collective-permute          payload
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from collections import defaultdict
from typing import Any, Optional

PEAK_FLOPS = 197e12       # bf16 per chip (TPU v5e-class)
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].split(",")
        return max(1, len([x for x in first if x.strip() != ""]))
    return 2


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-chip collective traffic (bytes) by op kind, ring-costed."""
    out: dict[str, float] = defaultdict(float)
    seen_start = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        # avoid double counting async -start/-done pairs
        opname = line.split("=")[0].strip()
        if opname.endswith("-done)") or ("-done(" in line):
            continue
        key = re.sub(r"\.(\d+)$", "", opname)
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        n = _group_size(line)
        if n <= 1:
            continue
        eff = (n - 1) / n
        if kind == "all-reduce":
            out[kind] += 2 * eff * size
        elif kind == "all-gather":
            out[kind] += eff * size          # result is the full buffer
        elif kind == "reduce-scatter":
            out[kind] += eff * size * n      # result is 1/n of the input
        elif kind == "all-to-all":
            out[kind] += eff * size
        else:  # collective-permute
            out[kind] += size
    return dict(out)


def top_collectives(hlo_text: str, n: int = 15) -> list[dict]:
    """The n largest collective ops with byte cost and jax source op_name —
    the hillclimb profiler (maps HLO collectives back to model code)."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        grp = _group_size(line)
        if grp <= 1:
            continue
        eff = (grp - 1) / grp
        cost = {"all-reduce": 2 * eff * size, "all-gather": eff * size,
                "reduce-scatter": eff * size * grp,
                "all-to-all": eff * size,
                "collective-permute": size}[kind]
        op_name = ""
        mm = re.search(r'op_name="([^"]*)"', line)
        if mm:
            op_name = mm.group(1)
        out.append({"kind": kind, "bytes": cost, "shape": shape_str[:60],
                    "groups": grp, "op_name": op_name[:160]})
    out.sort(key=lambda d: -d["bytes"])
    return out[:n]


@dataclasses.dataclass
class Roofline:
    name: str
    mesh: str
    n_devices: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict[str, float]
    model_flops: float = 0.0           # 6·N_active·D analytic, whole step
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time (no overlap assumption: max of terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / total HLO flops — catches remat/redundancy waste."""
        total = self.flops_per_chip * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the bound:
        (useful flop time) / (roofline step time)."""
        t_useful = self.model_flops / self.n_devices / self.peak_flops
        return t_useful / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> dict:
        return {
            "name": self.name, "mesh": self.mesh, "n_devices": self.n_devices,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(name: str, mesh_name: str, n_devices: int, compiled,
                  model_flops: float = 0.0) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = collective_bytes(compiled.as_text())
    return Roofline(
        name=name, mesh=mesh_name, n_devices=n_devices,
        flops_per_chip=float(ca.get("flops", 0.0)),
        bytes_per_chip=float(ca.get("bytes accessed", 0.0)),
        coll_bytes_per_chip=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops,
    )


# --------------------------------------------------- analytic MODEL_FLOPS ---

def model_flops_for(arch, shape) -> float:
    """6·N_params_active·D_tokens for train; 2·N_active·tokens for inference.

    enc-dec counts encoder and decoder stacks against their own token
    streams (t_enc frames vs dec_len tokens) separately."""
    cfg = arch.model
    if arch.family == "encdec":
        enc, dec, emb = _encdec_params(arch)
        if shape.kind == "train":
            return 6.0 * shape.batch * (enc * arch.t_enc
                                        + (dec + emb) * arch.dec_len)
        if shape.kind == "prefill":
            return 2.0 * shape.batch * enc * arch.t_enc
        return 2.0 * shape.batch * (dec + emb)
    n_active = active_params(arch)
    if shape.kind == "train":
        return 6.0 * n_active * shape.batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.batch * shape.seq
    return 2.0 * n_active * shape.batch        # decode: one token per seq


def _encdec_params(arch):
    cfg = arch.model
    d, dh = cfg.d_model, cfg.dh
    attn = d * dh * (cfg.n_heads * 2 + cfg.n_kv * 2)
    ffn = 2 * d * cfg.d_ff
    enc = cfg.n_layers * (attn + ffn)
    dec = cfg.n_layers * (2 * attn + ffn)  # self + cross
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return enc, dec, emb


def active_params(arch) -> float:
    """Parameters touched per token (MoE counts shared + top-k experts)."""
    cfg = arch.model
    d, dh = cfg.d_model, cfg.dh
    attn = d * dh * (cfg.n_heads * 2 + cfg.n_kv * 2)
    if cfg.n_experts:
        ffn = 3 * d * cfg.d_ff * (cfg.moe_top_k + cfg.n_shared_experts)
        ffn += d * cfg.n_experts  # router
    else:
        ffn = 3 * d * cfg.d_ff
    if arch.family == "ssm":
        d_in, s = 2 * d, 128
        per_layer = d * (2 * d_in + 2 * s + d_in // 64) + d_in * d
    elif arch.family == "hybrid":
        # super-block = 2 RG-LRU (5 Dr·Dr maps each) + 1 FFN + 1 attn block
        rec = 5 * d * d
        per_layer = (2 * rec + attn + 2 * (3 * d * cfg.d_ff)) / 3.0
    elif arch.family == "encdec":
        enc, dec, emb = _encdec_params(arch)
        return enc + dec + emb
    else:
        per_layer = attn + ffn
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return cfg.n_layers * per_layer + emb
