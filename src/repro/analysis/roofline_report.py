"""Render the roofline table from results/dryrun/*.json.

Usage:  PYTHONPATH=src python -m repro.analysis.roofline_report [--mesh 16x16]
Emits a markdown table (stdout) used verbatim in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str, mesh: str, tag: str = "") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        base = os.path.basename(path)[:-5]
        want_tag = bool(tag) and base.endswith(tag)
        has_tag = base.endswith(tag) if tag else not any(
            base.endswith(t) for t in ("_opt", "_full"))
        if r.get("mesh") == mesh and has_tag:
            recs.append(r)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    return recs


def fmt_row(r: dict) -> str:
    if r.get("status") == "skipped":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — | — | "
                f"{r.get('reason', '')[:60]} |")
    if r.get("status") != "ok":
        return f"| {r['arch']} | {r['shape']} | FAILED: {r.get('error','')[:60]} |"
    ro = r["roofline"]
    mem = r["memory"]["peak_per_device"] / 2**30
    return ("| {arch} | {shape} | {tc:.2e} | {tm:.2e} | {tcl:.2e} | {mem:.1f} "
            "| **{bn}** | {uf:.2f} | {rf:.3f} | {note} |").format(
        arch=r["arch"], shape=r["shape"],
        tc=ro["t_compute"], tm=ro["t_memory"], tcl=ro["t_collective"],
        mem=mem, bn=ro["bottleneck"],
        uf=ro["useful_flops_fraction"], rf=ro["roofline_fraction"],
        note=r.get("note", "")[:40])


HEADER = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
          "GiB/dev | bottleneck | useful-FLOP frac | roofline frac | note |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--dir", default=DEFAULT_DIR)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh, args.tag)
    print(f"### Roofline — mesh {args.mesh}"
          + (f" (tag={args.tag})" if args.tag else "") + "\n")
    print(HEADER)
    for r in recs:
        print(fmt_row(r))
    ok = [r for r in recs if r.get("status") == "ok"]
    if ok:
        worst = sorted(ok, key=lambda r: r["roofline"]["roofline_fraction"])[:5]
        print("\nworst roofline fractions:",
              ", ".join(f"{r['arch']}:{r['shape']}"
                        f"={r['roofline']['roofline_fraction']:.3f}"
                        for r in worst))


if __name__ == "__main__":
    main()
