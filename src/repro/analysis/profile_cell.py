import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hillclimb profiler: compile one cell (optionally at reduced unrolled
depth so per-layer costs are visible) and dump the top collectives with
their jax source op_names, plus the biggest fusion outputs — the 'profile'
available without hardware (DESIGN.md roofline method).

Usage:
  PYTHONPATH=src python -m repro.analysis.profile_cell \
      --arch qwen3-32b --shape train_4k [--depth 2] \
      [--state-policy dh] [--attn impl=capacity,route_per_group=true]
"""

import argparse
import dataclasses

import jax

from repro.analysis import roofline as rl
from repro.configs.registry import SHAPES, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--depth", type=int, default=0,
                    help="reduced unrolled depth (0 = full scanned)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--state-policy", default="seq")
    ap.add_argument("--attn", default="")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.attn:
        overrides = {}
        for kv in args.attn.split(","):
            key, val = kv.split("=")
            overrides[key] = (val.lower() == "true" if val.lower() in
                              ("true", "false") else
                              (float(val) if "." in val else int(val))
                              if val.replace(".", "").isdigit() else val)
        arch = dataclasses.replace(arch, model=dataclasses.replace(
            arch.model, attn=dataclasses.replace(arch.model.attn,
                                                 **overrides)))
    if args.depth:
        arch = dataclasses.replace(arch, model=dataclasses.replace(
            arch.model, n_layers=args.depth, scan_unroll=True))
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cell = build_cell(arch, shape, mesh, state_policy=args.state_policy)
    with mesh:
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings,
                           donate_argnums=cell.donate_argnums
                           ).lower(*cell.args).compile()

    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    mem = compiled.memory_analysis()
    print(f"== {args.arch} {args.shape} depth={args.depth or 'full'} "
          f"policy={args.state_policy} attn=[{args.attn}] ==")
    print(f"flops/chip={ca.get('flops', 0):.3e}  "
          f"bytes/chip={ca.get('bytes accessed', 0):.3e}  "
          f"temp_mem={mem.temp_size_in_bytes/2**30:.2f}GiB")
    text = compiled.as_text()
    coll = rl.collective_bytes(text)
    print("collective bytes by kind:",
          {k: f"{v:.3e}" for k, v in sorted(coll.items(),
                                            key=lambda kv: -kv[1])})
    print(f"\ntop {args.top} collectives (per appearance in HLO; ops inside "
          "a scan body execute once per layer):")
    for c in rl.top_collectives(text, args.top):
        print(f"  {c['bytes']:.3e}B  {c['kind']:18s} {c['shape']:34s} "
              f"g={c['groups']:4d}  {c['op_name']}")


if __name__ == "__main__":
    main()
