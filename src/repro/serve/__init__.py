"""Continuous-batching MiTA serving engine (paged decode cache).

Public surface:
  * `Request` / `FinishedRequest` — one generation job (with a priority
    class) and its result.
  * `EngineConfig` — slot/page budget and scheduling knobs, including
    chunked prefill (`prefill_chunk`) and the append-only page reserve.
  * `ServingEngine` — admits requests into a paged, fused decode batch;
    with chunking enabled it also preempts low-priority requests under
    page pressure and rebuilds them by recompute-from-prompt.

docs/serving.md documents the request lifecycle, the page-pool layout, and
every compiled program shape the engine can dispatch.
"""

from repro.serve.engine import (EngineConfig, FinishedRequest, Request,
                                ServingEngine)

__all__ = ["EngineConfig", "FinishedRequest", "Request", "ServingEngine"]
