"""Continuous-batching serving engine (backend-agnostic scheduler).

Public surface:
  * `Request` / `FinishedRequest` — one generation job (with a priority
    class) and its result.
  * `EngineConfig` — slot/page budget and scheduling knobs, including
    chunked prefill (`prefill_chunk`) and the append-only page reserve.
  * `ServingEngine` — admits requests into a fused decode batch; with
    chunking enabled it also preempts low-priority requests under page
    pressure and rebuilds them by recompute-from-prompt.
  * `backends` — the `DecodeBackend` protocol plus the paged MiTA backend
    and the constant-state recurrent backends (Mamba2, RG-LRU); the same
    scheduler serves the whole fast-weight spectrum
    (`backends.for_arch(arch, params, ecfg)` builds one from a registry
    `ArchConfig`).

docs/serving.md documents the request lifecycle, the backend protocol, the
page-pool layout, and every compiled program shape the engine can dispatch.
"""

from repro.serve import backends
from repro.serve.engine import (EngineConfig, FinishedRequest, Request,
                                ServingEngine)

__all__ = ["EngineConfig", "FinishedRequest", "Request", "ServingEngine",
           "backends"]
