"""Continuous-batching MiTA serving engine (paged decode cache).

Public surface:
  * `Request` / `FinishedRequest` — one generation job and its result.
  * `EngineConfig` — slot/page budget and scheduling knobs.
  * `ServingEngine` — admits requests into a paged, fused decode batch.
"""

from repro.serve.engine import (EngineConfig, FinishedRequest, Request,
                                ServingEngine)

__all__ = ["EngineConfig", "FinishedRequest", "Request", "ServingEngine"]
