"""Continuous-batching serving engine (backend-agnostic scheduler).

Public surface:
  * `Request` / `FinishedRequest` — one generation job (with a priority
    class) and its result.
  * `EngineConfig` — slot/page budget and scheduling knobs, including
    chunked prefill (`prefill_chunk`) and the append-only page reserve.
  * `ServingEngine` — admits requests into a fused decode batch; with
    chunking enabled it also preempts low-priority requests under page
    pressure and rebuilds them by recompute-from-prompt.
  * `backends` — the `DecodeBackend` protocol plus the paged MiTA backend
    and the constant-state recurrent backends (Mamba2, RG-LRU); the same
    scheduler serves the whole fast-weight spectrum
    (`backends.for_arch(arch, params, ecfg)` builds one from a registry
    `ArchConfig`).
  * `Supervisor` / `SupervisorConfig` — fault isolation around the
    engine: retry with backoff, per-slot quarantine, the degradation
    ladder, straggler detection, and bit-exact snapshot/restore crash
    recovery (`serve/supervisor.py`).
  * `ChaosBackend` / `ChaosConfig` / `InjectedFault` — the seeded fault
    injector that makes every one of those paths exercisable in CI
    (`serve/chaos.py`).
  * `AllocatorInvariantError` — page-accounting corruption; never
    retried, never shed.

docs/serving.md documents the request lifecycle, the backend protocol, the
page-pool layout, every compiled program shape the engine can dispatch,
and the failure-domain taxonomy.
"""

from repro.serve import backends
from repro.serve.chaos import ChaosBackend, ChaosConfig, InjectedFault
from repro.serve.engine import (AllocatorInvariantError, EngineConfig,
                                FinishedRequest, Request, ServingEngine)
from repro.serve.supervisor import (DEGRADATION_RUNGS, Supervisor,
                                    SupervisorConfig, SupervisionExhausted)

__all__ = ["AllocatorInvariantError", "ChaosBackend", "ChaosConfig",
           "DEGRADATION_RUNGS", "EngineConfig", "FinishedRequest",
           "InjectedFault", "Request", "ServingEngine", "Supervisor",
           "SupervisorConfig", "SupervisionExhausted", "backends"]
