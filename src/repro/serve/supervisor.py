"""Supervised serving: fault isolation, degradation, and crash recovery.

`Supervisor` wraps `ServingEngine.step()` with the failure policy the
bare engine deliberately does not have (docs/serving.md §Failure
domains):

  * **Retry with exponential backoff** — a raising step is re-executed up
    to ``max_retries`` times.  Safe because every backend dispatch either
    completes or never starts (fault injection fires before dispatch, and
    the engine's per-step mutations up to a dispatch are idempotent
    across re-execution: admission, page growth, and chunk bookkeeping
    all advance only on dispatch success).
  * **Quarantine** — when retries are exhausted and the fault implicates
    a strict subset of slots (`InjectedFault.batchwide` False, or any
    exception carrying a ``slots`` attribute), ONLY those slots are
    evicted through the engine's recompute-from-prompt preemption path —
    the victims re-admit and emit bit-identical tokens; the rest of the
    batch never stops.  A fault signature that survives its own
    quarantine escalates to the ladder instead of thrashing.
  * **Degradation ladder** — batch-wide persistent faults walk
    ``nominal → spec_off → prefix_cache_off → xla_forced`` one rung per
    escalation, each rung surfaced as ``stats()["degradation_level"]``.
    Every rung preserves bit-parity: speculation is lossless by
    construction, cache hits are bit-exact vs cold prefill, and the XLA
    fallback is the kernels' parity oracle.  A spent ladder is NOT fatal
    by itself (a storm of distinct transient faults can spend it and
    still heal); ``max_consecutive_failures`` failed attempts without
    one good step raises `SupervisionExhausted`.
  * **Straggler detection** — every step is timed through the
    `distributed.fault_tolerance.StepTimer` EWMA detector (the training
    harness's, reused); trips are counted, not acted on (CPU smoke has no
    host to exclude).
  * **Stall relief** — ``stall_steps`` consecutive no-progress steps fire
    the backend's `on_stall` hook (a chaos wrapper releases held
    allocator spikes there), so injected resource pressure can never
    livelock the scheduler.

Crash recovery: `snapshot()` journals every in-flight request (prompt +
tokens committed so far) plus the finished list; `restore()` rebuilds
them on a FRESH, identically-configured engine as resume entries — the
same recompute-from-prompt machinery preemption uses, so a killed and
restarted engine continues every stream bit-identically.  Snapshots
write atomically (tmp + rename, the `checkpoint/manager.py` idiom).

`AllocatorInvariantError` is never retried: page-accounting corruption
is a scheduler bug, and replaying it would turn an error into state
corruption.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Optional

import numpy as np

from repro.distributed.fault_tolerance import StepTimer
from repro.serve.engine import (AllocatorInvariantError, FinishedRequest,
                                Request, ServingEngine, _WaitEntry)

#: the degradation ladder, rung per index (stats()["degradation_level"])
DEGRADATION_RUNGS = ("nominal", "spec_off", "prefix_cache_off",
                     "xla_forced")


class SupervisionExhausted(RuntimeError):
    """Too many consecutive failed step attempts with retries,
    quarantine, and every ladder rung already spent — the supervisor
    gives up loudly rather than spin."""


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    """Failure policy knobs.  ``backoff_base_s`` = 0 (default) keeps
    tests and CPU benches fast; production would set a real base.
    ``max_degradation`` caps how far down `DEGRADATION_RUNGS` the ladder
    may walk.  ``max_consecutive_failures`` is the hard give-up bound:
    a storm of DISTINCT transient faults can legitimately spend the
    quarantine/ladder budget (each one heals, the next fires), so a
    spent ladder alone is not fatal — only this many failed attempts
    without a single good step in between is."""
    max_retries: int = 3
    backoff_base_s: float = 0.0
    backoff_cap_s: float = 0.05
    max_degradation: int = len(DEGRADATION_RUNGS) - 1
    max_consecutive_failures: int = 20
    straggler_alpha: float = 0.3
    straggler_threshold: float = 4.0
    stall_steps: int = 8


class Supervisor:
    """Drives a `ServingEngine` under the failure policy above.  The
    engine keeps owning requests/slots/pages; the supervisor owns fault
    handling and increments the engine's robustness counters
    (``retries``/``quarantined``/``degradation_level``) so `stats()`
    stays the one observability surface."""

    def __init__(self, engine: ServingEngine,
                 cfg: SupervisorConfig = SupervisorConfig()):
        self.engine = engine
        self.cfg = cfg
        self.timer = StepTimer(alpha=cfg.straggler_alpha,
                               threshold=cfg.straggler_threshold)
        self.n_faults = 0               # exceptions caught (incl. retried)
        self.degradations: list[str] = []   # rung names, in order taken
        self.last_fault: Optional[str] = None
        self._consecutive = 0           # failures in the current cycle
        self._streak = 0                # failures since last good step
        self._stalled = 0               # no-progress steps in a row
        self._last_quarantine: Optional[tuple] = None  # fault signature
        self._env_prev: dict[str, Optional[str]] = {}
        # give a chaos wrapper real pool pressure to play with
        self._notify("bind_allocator", engine.alloc)

    # ----------------------------------------------------------- plumbing --

    def _notify(self, hook: str, *args: Any) -> None:
        fn = getattr(self.engine.backend, hook, None)
        if fn is not None:
            fn(*args)

    def submit(self, req: Request) -> bool:
        return self.engine.submit(req)

    def cancel(self, rid: int, reason: str = "cancelled") -> bool:
        return self.engine.cancel(rid, reason=reason)

    def stats(self) -> dict:
        s = self.engine.stats()
        s["stragglers"] = self.timer.n_stragglers
        return s

    def close(self) -> None:
        """Restore process environment touched by ladder rungs."""
        for var, prev in self._env_prev.items():
            if prev is None:
                os.environ.pop(var, None)
            else:
                os.environ[var] = prev
        self._env_prev = {}

    # ----------------------------------------------------------- stepping --

    def step(self) -> bool:
        """One SUPERVISED engine iteration: retries, quarantines, and
        degrades until the underlying `engine.step()` completes, then
        returns its result.  Raises `AllocatorInvariantError` immediately
        and `SupervisionExhausted` when the whole policy is spent."""
        eng = self.engine
        while True:
            marker = (eng.steps, eng.prefill_dispatches, len(eng.finished),
                      len(eng.waiting), len(eng.prefilling))
            t0 = time.perf_counter()
            try:
                ok = eng.step()
            except AllocatorInvariantError:
                raise
            except Exception as e:      # noqa: BLE001 — supervised domain
                self._handle_fault(e)
                continue
            self.timer.observe(time.perf_counter() - t0)
            self._consecutive = 0
            self._streak = 0
            self._last_quarantine = None
            progressed = marker != (eng.steps, eng.prefill_dispatches,
                                    len(eng.finished), len(eng.waiting),
                                    len(eng.prefilling))
            if ok and not progressed:
                self._stalled += 1
                if self._stalled >= self.cfg.stall_steps:
                    self._notify("on_stall")
                    self._stalled = 0
            else:
                self._stalled = 0
            if not ok:
                # drained: release anything a fault injector still holds
                self._notify("on_stall")
            return ok

    def _handle_fault(self, e: Exception) -> None:
        eng = self.engine
        self.n_faults += 1
        self.last_fault = repr(e)
        self._consecutive += 1
        self._streak += 1
        if self._streak >= self.cfg.max_consecutive_failures:
            raise SupervisionExhausted(
                f"{self._streak} consecutive failed step attempts with "
                f"quarantine and the degradation ladder "
                f"{self.degradations} already spent: {e!r}") from e
        if self._consecutive <= self.cfg.max_retries:
            eng.n_retries += 1
            delay = min(
                self.cfg.backoff_base_s * (2 ** (self._consecutive - 1)),
                self.cfg.backoff_cap_s)
            if delay > 0:
                time.sleep(delay)
            return
        self._consecutive = 0
        slots = sorted({int(s) for s in getattr(e, "slots", []) or []})
        occupied = [s for s in slots
                    if s in eng.slot_req or s in eng.prefilling]
        batchwide = bool(getattr(e, "batchwide", True))
        sig = (type(e).__name__, getattr(e, "op", None), tuple(slots))
        if occupied and not batchwide and sig != self._last_quarantine:
            # fault domain is a strict slot subset: evict ONLY those
            # slots through the preemption path (decoding victims carry
            # their emitted tokens and resurrect bit-identically; mid-
            # prefill victims restart having emitted nothing)
            for s in occupied:
                eng._preempt(s)
            eng.n_quarantined += len(occupied)
            self._last_quarantine = sig
            self._notify("on_quarantine", occupied)
            return
        if not self._degrade():
            # ladder spent: keep retrying — a storm of distinct transient
            # faults heals on its own, and `max_consecutive_failures`
            # bounds a genuinely stuck fault (checked above)
            return

    def _degrade(self) -> bool:
        """Climb one ladder rung; False when already at the cap.  Every
        rung narrows capability, never correctness — each mode is pinned
        bit-identical to the mode above it by the tier-1 suites."""
        eng = self.engine
        level = eng.degradation_level
        cap = min(self.cfg.max_degradation, len(DEGRADATION_RUNGS) - 1)
        if level >= cap:
            return False
        level += 1
        eng.degradation_level = level
        rung = DEGRADATION_RUNGS[level]
        if rung == "spec_off":
            eng.ecfg = dataclasses.replace(eng.ecfg, spec_k=0)
        elif rung == "prefix_cache_off":
            if eng.cache is not None:
                while eng.cache.evict_one():
                    pass
                eng.cache = None
        elif rung == "xla_forced":
            # the chunk-prefill dispatch reads this at trace time; the
            # XLA path is the kernels' bit-exact oracle, so forcing it is
            # a perf rung, not a correctness one.  close() restores.
            var = "REPRO_PREFILL_IMPL"
            self._env_prev.setdefault(var, os.environ.get(var))
            os.environ[var] = "xla"
        self.degradations.append(rung)
        self._notify("on_degrade", level)
        return True

    def run(self, requests: list[Request],
            realtime: bool = False) -> list[FinishedRequest]:
        """Supervised version of `ServingEngine.run`: same drive loop,
        every step supervised, injector holdings drained at the end."""
        eng = self.engine
        pending = sorted(requests, key=lambda r: r.arrival)
        start = time.perf_counter()
        already_done = len(eng.finished)
        idx = 0
        while (idx < len(pending) or eng.waiting or eng.prefilling
               or eng.active.any()):
            now = time.perf_counter() - start
            while idx < len(pending) and (
                    not realtime or pending[idx].arrival <= now):
                self.submit(pending[idx])
                idx += 1
            progressed = self.step()
            if not progressed and idx < len(pending):
                if realtime:
                    time.sleep(max(0.0,
                                   pending[idx].arrival
                                   - (time.perf_counter() - start)))
        self._notify("on_stall")
        return sorted(eng.finished[already_done:], key=lambda f: f.rid)

    # ------------------------------------------------------ crash recovery --

    def snapshot(self) -> dict:
        """Journal of everything needed to resume this engine's streams
        bit-identically on a fresh process: per in-flight request its
        prompt, scheduling fields, and the tokens committed so far (in
        admission order — decoding slots, then prefilling, then waiting),
        plus the finished list and the shed/robustness counters.  Device
        state is deliberately absent: recompute-from-prompt rebuilds it
        bit-exactly, which is the whole premise of the engine's
        preemption machinery."""
        eng = self.engine

        def req_row(req: Request, tokens: list) -> dict:
            return {"rid": int(req.rid),
                    "prompt": np.asarray(req.prompt).tolist(),
                    "max_new_tokens": int(req.max_new_tokens),
                    "temperature": float(req.temperature),
                    "arrival": float(req.arrival),
                    "priority": int(req.priority),
                    "deadline_ms": (None if req.deadline_ms is None
                                    else float(req.deadline_ms)),
                    "tokens": [int(x) for x in tokens]}

        rows = []
        for slot in sorted(eng.slot_req, key=lambda s: eng.slot_seq[s]):
            rows.append(req_row(eng.slot_req[slot], eng.slot_out[slot]))
        for slot in sorted(eng.prefilling, key=lambda s: eng.slot_seq[s]):
            entry = eng.prefilling[slot].entry
            rows.append(req_row(entry.req,
                                entry.resume[0] if entry.resume else []))
        for entry in eng.waiting:
            rows.append(req_row(entry.req,
                                entry.resume[0] if entry.resume else []))
        fins = [{"rid": int(f.rid), "tokens": f.tokens.tolist(),
                 "arrival": float(f.arrival), "cancelled": bool(f.cancelled),
                 "reason": f.reason, "preemptions": int(f.preemptions)}
                for f in eng.finished]
        return {"version": 1, "backend": eng.backend.name,
                "requests": rows, "finished": fins,
                "counters": {"rejected": eng.n_rejected,
                             "deadline_expired": eng.n_deadline_expired,
                             "retries": eng.n_retries,
                             "quarantined": eng.n_quarantined,
                             "degradation_level": eng.degradation_level}}

    def save_snapshot(self, path: str) -> None:
        """Atomic journal write — tmp then rename, so a crash mid-save
        leaves the previous snapshot intact (`checkpoint/manager.py`)."""
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.snapshot(), f)
        os.replace(tmp, path)

    @staticmethod
    def load_snapshot(path: str) -> dict:
        with open(path) as f:
            return json.load(f)

    def restore(self, snap: dict) -> None:
        """Rebuild a snapshot's streams on THIS supervisor's engine —
        which must be fresh (nothing in flight) and configured
        identically to the snapshotted one (same params / model config /
        EngineConfig / sample key): resumed tokens re-enter through the
        recompute-from-prompt path, whose bit-exactness is only defined
        against the same compiled programs and sampling keys.  Requests
        with committed tokens need chunked mode (``prefill_chunk`` > 0),
        exactly like preemption resume.  Deadlines restart their clock
        at restore time."""
        eng = self.engine
        if eng.waiting or eng.prefilling or eng.slot_req or eng.finished:
            raise ValueError("restore() needs a fresh engine: this one "
                             "already has requests in flight or finished")
        if snap.get("backend") != eng.backend.name:
            raise ValueError(
                f"snapshot was taken on the {snap.get('backend')!r} "
                f"backend; this engine runs {eng.backend.name!r}")
        now = time.perf_counter()
        for row in snap["requests"]:
            tokens = row["tokens"]
            if tokens and not eng.ecfg.prefill_chunk:
                raise ValueError(
                    "snapshot holds mid-decode requests; restoring them "
                    "needs chunked prefill (prefill_chunk > 0) — the "
                    "recompute-from-prompt resume path")
            req = Request(rid=row["rid"],
                          prompt=np.asarray(row["prompt"], np.int32),
                          max_new_tokens=row["max_new_tokens"],
                          temperature=row["temperature"],
                          arrival=row["arrival"],
                          priority=row["priority"],
                          deadline_ms=row["deadline_ms"])
            eng._inflight.add(req.rid)
            eng._seq += 1
            entry = _WaitEntry(req=req, seq=eng._seq)
            if tokens:
                entry.resume = (list(tokens), [0.0] * len(tokens),
                                (0.0, 0.0))
            eng._enqueue(entry)
            if req.deadline_ms is not None:
                eng._deadline[req.rid] = now + req.deadline_ms / 1e3
        for f in snap["finished"]:
            eng.finished.append(FinishedRequest(
                rid=f["rid"], tokens=np.asarray(f["tokens"], np.int32),
                arrival=f["arrival"], admitted=0.0, first_token=0.0,
                finished=0.0, preemptions=f["preemptions"],
                cancelled=f["cancelled"], reason=f["reason"]))
        c = snap.get("counters", {})
        eng.n_rejected = c.get("rejected", 0)
        eng.n_deadline_expired = c.get("deadline_expired", 0)
        eng.n_retries = c.get("retries", 0)
        eng.n_quarantined = c.get("quarantined", 0)
        eng.degradation_level = c.get("degradation_level", 0)


__all__ = ["DEGRADATION_RUNGS", "Supervisor", "SupervisorConfig",
           "SupervisionExhausted"]
