"""Serving backends: the `DecodeBackend` protocol behind the scheduler.

`repro.serve.engine.ServingEngine` is a GENERIC continuous-batching
scheduler: admission, the priority queue, preemption, chunked-prefill
pacing, page accounting, sampling bookkeeping, and stats never mention a
model family — every device-side operation goes through a `DecodeBackend`.
A backend owns the model parameters, the per-slot decode state, its device
mirrors, and every compiled program; the engine owns requests, slots,
pages, and time.

Page semantics are backend-defined: the MiTA backend's pages are real pool
rows (a page = ``window`` KV/landmark rows, named by per-slot page tables);
the recurrent backends' states are constant-size per slot, so pages are
pure admission-control currency — ``pages_needed`` still meters context
budget, which keeps priority preemption and the allocator's fairness
ordering meaningful across the whole fast-weight spectrum (the paper's
framing: routing → compression; docs/serving.md §Backend protocol).

Protocol (duck-typed; `BackendBase` supplies the defaults):

  * ``fresh()``                 — new instance, zeroed state (warmup scratch).
  * ``pages_needed(n)``         — pages covering an ``n``-token context.
  * ``chunkable(n, batched)``   — can the chunk program serve a fresh
                                  ``n``-token prompt (False → the engine
                                  routes it through ``prefill_group``)?
  * ``validate_prompt(n, path)``— raise at SUBMIT time if the path
                                  ("monolithic" | "chunked") cannot lower
                                  this length; nothing may be mutated.
  * ``alloc_slot(slot)``        — a slot was assigned: prepare its state
                                  (recurrent backends zero the accumulator).
  * ``prefill_group(...)``      — monolithic prefill+pack of an admission
                                  group, one dispatch.
  * ``prefill_chunk(...)``      — advance ONE job one chunk (per-job mode).
  * ``prefill_chunks(...)``     — advance EVERY packed job row one chunk in
                                  one dispatch (batched mode).
  * ``slot_filled(slot, n, snapshot)`` — the slot enters the decode batch
                                  with ``n`` tokens of context.
  * ``decode_step(...)``        — one fused step for the whole slot batch;
                                  returns [S, V] logits (host sampling) or
                                  [S] sampled tokens (fused sampling).
  * ``retire(slot)``            — the slot left the decode batch.
  * ``preempt_snapshot(slot)``  — capture what re-admission needs beyond
                                  recompute-from-prompt (None for both
                                  current families: recompute is exact).
  * ``invalidate()``            — host copies of scheduler tensors changed;
                                  re-upload device mirrors next step.
  * ``stats()``                 — per-backend counters (dispatches,
                                  kernel fallbacks) merged into
                                  ``ServingEngine.stats()``.
  * ``static_reference(...)``   — the backend's static/full-forward oracle;
                                  engine greedy tokens must be bit-identical.
  * ``supports_prefix_cache``   — True if pages hold real per-token context
                                  a prefix cache can share by reference
                                  (False → the engine silently runs
                                  cache-off; recurrent states fold the
                                  whole prefix into one accumulator, so
                                  there is nothing page-resident to reuse).
  * ``prefix_snapshot(slot, m)``— host copies of the slot's first ``m``
                                  per-window summary payloads, stored in
                                  the radix cache next to the page ids.
  * ``attach_prefix(slot, payloads)`` — install cached payloads so the
                                  slot's state is exactly what prefilling
                                  those windows itself would have produced
                                  (the pages attach via the page table).
  * ``supports_speculation``    — True if the backend implements the
                                  draft/verify/rollback triple below
                                  (``EngineConfig.spec_k > 0`` requires it).
  * ``draft_horizon(t)``        — per-slot cap on how many tokens may be
                                  drafted past position ``t`` before a
                                  backend-internal boundary (the MiTA
                                  backend stops short of the next landmark
                                  finalize so a rejected draft never needs
                                  a landmark/expert rollback).
  * ``draft_steps(...)``        — cheaply propose up to ``spec_len[s]``
                                  tokens per slot ([k, S]); MUST NOT change
                                  any state a rejected draft would need
                                  undone beyond what ``rollback`` restores.
  * ``verify_step(...)``        — run the EXACT decode rule over the k+1
                                  positions [input, drafts...] and return
                                  the tokens it samples ([k+1, S]); the
                                  engine commits the longest exact-match
                                  prefix + one correction.
  * ``rollback(commits, active)``— rewind per-slot state to exactly
                                  ``commits[s]`` tokens past the round's
                                  start — bit-identical to having decoded
                                  those tokens one step at a time.
  * ``on_quarantine(slots)`` / ``on_degrade(level)`` / ``on_stall()`` —
                                  supervision notifications (no-op
                                  defaults); `serve/supervisor.py` fires
                                  them on fault isolation, a degradation-
                                  ladder rung, and scheduler stalls, and
                                  fault-injection wrappers
                                  (`serve/chaos.py`) key fault lifecycles
                                  off them.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mita_decode import window_aligned

# THE stats schema: every `ServingEngine.stats()` dict holds exactly these
# keys — the engine's scheduler counters plus the backend counters every
# `BackendBase.stats()` reports.  Bench JSON rows and the conformance suite
# pin against these sets instead of three ad-hoc copies drifting apart.
ENGINE_STAT_KEYS = frozenset({
    "backend", "steps", "chunks", "prefill_dispatches", "preemptions",
    "pages_high_water", "reserve_dips", "prefix_cache_hits",
    "prefix_cache_misses", "pages_shared", "prefix_tokens_reused",
    "prefix_cache_pages", "prefix_cache_evictions",
    "spec_drafted", "spec_accepted", "spec_rollbacks",
    "rejected", "deadline_expired", "retries", "quarantined",
    "degradation_level",
})
BACKEND_STAT_KEYS = frozenset({
    "decode_dispatches", "prefill_kernel_fallbacks",
    "paged_kernel_fallbacks", "finalize_kernel_fallbacks",
})
STATS_SCHEMA = ENGINE_STAT_KEYS | BACKEND_STAT_KEYS


def sample_host(logits, rid: int, index: int, temperature: float,
                key) -> int:
    """THE host-side sampling rule, shared by the engine's hot loop and
    every backend's `static_reference` so the engine==reference parity
    gates compare one recipe, not three copies: greedy first-index argmax,
    or a categorical keyed by fold_in(fold_in(key, rid), index) with the
    same 1e-6 temperature floor as the fused on-device sampler
    (`models.transformer.sample_tokens`) — tokens are therefore identical
    across host/fused sampling and invariant to batching, slot placement,
    and preemption schedule."""
    if temperature <= 0.0:
        return int(np.argmax(logits))
    k = jax.random.fold_in(jax.random.fold_in(key, rid), index)
    return int(jax.random.categorical(
        k, jnp.asarray(logits) / max(temperature, 1e-6)))


class BackendBase:
    """Shared defaults: window-quantized page math, no-op lifecycle hooks.

    Subclasses must set ``name``, ``window``, and implement the prefill /
    decode entry points; ``model_cfg``/``params``/``ecfg`` are kept so
    ``fresh()`` can rebuild an identically-configured instance (compiled
    programs are cached module-wide, so a fresh instance recompiles
    nothing)."""

    name = "backend"
    supports_prefix_cache = False
    supports_speculation = False

    def __init__(self, params: Any, cfg: Any, ecfg: Any):
        self.params = params
        self.model_cfg = cfg
        self.ecfg = ecfg
        self.decode_dispatches = 0
        self._dirty = True

    def fresh(self) -> "BackendBase":
        return type(self)(self.params, self.model_cfg, self.ecfg)

    def pages_needed(self, n_tokens: int) -> int:
        return window_aligned(n_tokens, self.window) // self.window

    def chunkable(self, n_train: int, batched: bool) -> bool:
        return True

    def validate_prompt(self, n: int, path: str) -> None:
        pass

    def alloc_slot(self, slot: int) -> None:
        pass

    def slot_filled(self, slot: int, n_tokens: int,
                    snapshot: Any = None) -> None:
        pass

    def retire(self, slot: int) -> None:
        pass

    def preempt_snapshot(self, slot: int) -> Any:
        return None

    def prefix_snapshot(self, slot: int, n_windows: int) -> list:
        raise NotImplementedError(
            f"{self.name} backend does not support the prefix cache")

    def attach_prefix(self, slot: int, payloads: list) -> None:
        raise NotImplementedError(
            f"{self.name} backend does not support the prefix cache")

    # --- speculative decoding (EngineConfig.spec_k > 0) ------------------
    # A backend advertises `supports_speculation = True` and implements the
    # triple; the engine owns accept/reject bookkeeping and never calls
    # these on a backend that does not advertise them.

    def draft_horizon(self, t: np.ndarray) -> np.ndarray:
        """Per-slot cap on draftable tokens past position ``t`` ([S] ->
        [S]).  Default: no backend-internal boundary, draft freely."""
        return np.full_like(np.asarray(t), np.iinfo(np.int32).max)

    def draft_steps(self, tokens_in, t, active, page_table, rid,
                    temperature, sample_idx, key, spec_len) -> np.ndarray:
        raise NotImplementedError(
            f"{self.name} backend does not support speculative decoding")

    def verify_step(self, tokens_in, t, active, page_table, rid,
                    temperature, sample_idx, key, spec_len,
                    drafts) -> np.ndarray:
        raise NotImplementedError(
            f"{self.name} backend does not support speculative decoding")

    def rollback(self, commits: np.ndarray, active: np.ndarray) -> None:
        raise NotImplementedError(
            f"{self.name} backend does not support speculative decoding")

    def invalidate(self) -> None:
        self._dirty = True

    # --- supervision hooks (serve/supervisor.py) -------------------------
    # No-op by default: the supervisor notifies the backend of fault-
    # isolation events so wrappers (serve/chaos.py) can key fault
    # lifecycles off them — quarantine clears slot-bound faults, a ladder
    # rung clears persistent ones, a stall drains held resources.

    def on_quarantine(self, slots: list) -> None:
        pass

    def on_degrade(self, level: int) -> None:
        pass

    def on_stall(self) -> None:
        pass

    def stats(self) -> dict:
        # the fallback counters are process-global and MiTA-kernel-
        # specific; backends that never dispatch those kernels report 0
        # rather than inheriting another engine's trace-time fallbacks
        # (keys must cover BACKEND_STAT_KEYS exactly)
        return {"decode_dispatches": self.decode_dispatches,
                "prefill_kernel_fallbacks": 0,
                "paged_kernel_fallbacks": 0,
                "finalize_kernel_fallbacks": 0}


def resolve(params: Any, cfg: Any, ecfg: Any) -> BackendBase:
    """Default backend for a bare `ModelConfig` (the engine's ctor path
    when no backend is passed): the paged MiTA backend.  Recurrent
    architectures carry no marker on `ModelConfig` alone — build them via
    `for_arch` (the registry's family field decides)."""
    attn = getattr(getattr(cfg, "attn", None), "backend", None)
    if attn in ("mita", "mita_ref"):
        from repro.serve.backends.mita import MiTABackend
        return MiTABackend(params, cfg, ecfg)
    raise ValueError(
        f"no default serving backend for attention backend {attn!r}: "
        "ServingEngine drives MiTA paged decode caches unless a backend is "
        "passed — ssm/hybrid architectures serve through "
        "serve.backends.for_arch (constant-size recurrent slot states)")


def for_arch(arch: Any, params: Any, ecfg: Any) -> BackendBase:
    """Backend for a registry `ArchConfig` — any architecture with a decode
    state is servable through the same scheduler."""
    if arch.family in ("dense", "moe", "vlm"):
        from repro.serve.backends.mita import MiTABackend
        return MiTABackend(params, arch.model, ecfg)
    if arch.family == "ssm":
        from repro.serve.backends.recurrent import Mamba2Backend
        return Mamba2Backend(params, arch.model, ecfg)
    if arch.family == "hybrid":
        from repro.serve.backends.recurrent import RGLRUBackend
        return RGLRUBackend(params, arch.model, ecfg)
    raise ValueError(f"family {arch.family!r} has no serving backend "
                     "(encdec decode is capacity-448 native; see registry)")


__all__ = ["BackendBase", "resolve", "for_arch", "sample_host",
           "ENGINE_STAT_KEYS", "BACKEND_STAT_KEYS", "STATS_SCHEMA"]
