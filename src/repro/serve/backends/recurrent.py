"""Recurrent serving backends: Mamba2 (SSD) and RecurrentGemma (RG-LRU).

These are the compression end of the paper's fast-weight spectrum: the
decode state is a CONSTANT-size module per request (SSD state + conv tail;
RG-LRU state + conv tail + a bounded per-slot attention cache for the
hybrid's attention layers), so a "slot" is an index into the state's batch
axis and no paging indirection exists.  The scheduler's pages become pure
admission-control currency — `pages_needed` still meters context budget,
which keeps priority preemption, the reserve, and the allocator fairness
order meaningful across backends.

Program inventory (mirroring the paged backend's three-program shape):

  * ``decode``  — one fused step for the whole slot batch; per-slot
    positions, activity, and sampling inputs are data.  State updates are
    masked by activity (`core.slotted.where_slots`), so an idle slot's
    state is bit-frozen.
  * ``chunk``   — `*_prefill_chunk`: a sequential scan of the EXACT
    decode-step update over one fixed-shape chunk for a row-packed subset
    of slots (`core.slotted.gather_slots` / `scatter_slots`; inactive rows
    pass through bit-identically).  ONE compiled shape per (chunk length,
    row width) serves every chunk at any resume point — which is what
    makes recompute-from-prompt preemption exact: re-scanning prompt +
    emitted tokens rebuilds the state the victim had when evicted.
  * ``monolithic`` — the same chunk program at the window-aligned prompt
    capacity (one dispatch per admission group), used when the engine runs
    unchunked.

The static reference (`static_reference`) is a STRUCTURALLY different
program — a time-major `lax.scan` of the full decode step over the prompt,
then single-token decode — so engine==reference greedy parity checks the
slot scatter/gather, masking, and chunking machinery, not a program
against itself.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import slotted
from repro.core.mita_decode import window_aligned
from repro.models import mamba2 as m2
from repro.models import rglru as rg
from repro.models import transformer as tfm
from repro.serve.backends import BackendBase, sample_host

# family -> (init_states(cfg, n_slots, capacity), decode(p, st, tok, pos,
# cfg), chunk(p, st, toks, t0, n_valid, cfg)); states are stacked pytrees
# with the slot axis second (leaves [L, S, ...])
_OPS: dict[str, tuple[Callable, Callable, Callable]] = {
    "mamba2": (lambda cfg, s, cap: m2.mamba_slot_states(cfg, s),
               m2.mamba_decode_step, m2.mamba_prefill_chunk),
    "rglru": (rg.rg_slot_states, rg.rg_slot_decode_step, rg.rg_prefill_chunk),
}


_zero_slot = jax.jit(slotted.zero_slot, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _decode_fn(family: str, cfg, fused_sampling: bool) -> Callable:
    """Fused whole-slot-batch decode step: model step + activity-masked
    state commit + on-device position/sample-index advance (+ fused
    sampling).  Cached module-wide so engines sharing a config share
    compiled code."""
    _, decode_raw, _ = _OPS[family]

    def step(p, st, tok, t, ac, rid, si, temp, key):
        logits, st_new = decode_raw(p, st, tok, t, cfg)
        st = slotted.where_slots(ac, st_new, st, axis=1)
        adv = ac.astype(t.dtype)
        if fused_sampling:
            out = tfm.sample_tokens(logits, rid, si, temp, key)
        else:
            out = logits
        return out, st, t + adv, si + adv

    return jax.jit(step, donate_argnums=(1, 3, 6))


@functools.lru_cache(maxsize=None)
def _chunk_fn(family: str, cfg) -> Callable:
    """Row-packed chunk scan: gather the rows' slot states, scan the chunk,
    scatter back (rows with n_valid == 0 scatter their gathered values —
    bit-identical).  Jit caches one program per (chunk length, row width)."""
    _, _, chunk_raw = _OPS[family]

    def run(p, st, slot_ids, toks, t0, n_valid):
        sub = slotted.gather_slots(st, slot_ids)
        logits, sub = chunk_raw(p, sub, toks, t0, n_valid, cfg)
        return logits, slotted.scatter_slots(st, slot_ids, sub)

    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _spec_draft_fn(family: str, cfg, n_pos: int) -> Callable:
    """Free-running speculative scan (``spec_mode="self"``): the EXACT
    decode body over ``n_pos`` positions in ONE dispatch, each sampled
    token fed to the next, per-slot length as data (positions past
    ``spec_len[s]`` pass the carry through bit-frozen).  The state commits
    through the scan — self-drafted tokens ARE the decode rule's output,
    so every draft verifies and no rollback exists on this path; the win
    is dispatch collapse: one program commits up to ``n_pos`` tokens."""
    _, decode_raw, _ = _OPS[family]

    def run(p, st, tok, t, ac, rid, si, temp, key, spec_len):
        def body(carry, i):
            st, tok, t, si = carry
            ac_i = ac & (i < spec_len)
            logits, st_new = decode_raw(p, st, tok, t, cfg)
            st = slotted.where_slots(ac_i, st_new, st, axis=1)
            tok2 = tfm.sample_tokens(logits, rid, si, temp, key)
            tok2 = jnp.where(ac_i, tok2, tok)
            adv = ac_i.astype(t.dtype)
            return (st, tok2, t + adv, si + adv), tok2

        (st, _, _, _), drafts = jax.lax.scan(body, (st, tok, t, si),
                                             jnp.arange(n_pos))
        return drafts, st

    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _spec_tf_fn(family: str, cfg, n_pos: int) -> Callable:
    """Teacher-forced speculative scan (``spec_mode="stress"`` verify AND
    rollback replay): consume a fixed [n_pos, S] token stream through the
    exact decode body, per-slot step count as data, collecting the sampled
    tokens.  The same compiled program serves both calls — verify runs it
    over [input, drafts...] with ``n_steps = spec_len + 1``; rollback
    restores the pre-verify snapshot and re-runs it over the COMMITTED
    stream with ``n_steps = commits``, which is bit-identical to having
    decoded those tokens one step at a time (the committed prefix of the
    verify scan consumed exactly these inputs from the same state)."""
    _, decode_raw, _ = _OPS[family]

    def run(p, st, toks, t, ac, rid, si, temp, key, n_steps):
        def body(carry, inp):
            st, t, si = carry
            i, tok = inp
            ac_i = ac & (i < n_steps)
            logits, st_new = decode_raw(p, st, tok, t, cfg)
            st = slotted.where_slots(ac_i, st_new, st, axis=1)
            out = tfm.sample_tokens(logits, rid, si, temp, key)
            adv = ac_i.astype(t.dtype)
            return (st, t + adv, si + adv), out

        (st, _, _), outs = jax.lax.scan(body, (st, t, si),
                                        (jnp.arange(n_pos), toks))
        return outs, st

    return jax.jit(run, donate_argnums=(1,))


# snapshot for the stress path's rollback; scans donate their state input,
# so the copy must NOT (fresh buffers, original untouched)
_tree_copy = jax.jit(lambda st: jax.tree.map(jnp.copy, st))


@functools.lru_cache(maxsize=None)
def _ref_prefill_fn(family: str, cfg, n: int) -> Callable:
    """Reference prefill: time-major scan of the FULL decode step over the
    prompt — a different program structure from the serving chunk scan, so
    parity gates test the machinery, not a program against itself."""
    _, decode_raw, _ = _OPS[family]

    def run(p, st, toks):                       # toks: [B, n]
        b = toks.shape[0]

        def step(st, inp):
            tok, pos = inp
            logits, st = decode_raw(p, st, tok, jnp.full((b,), pos), cfg)
            return st, logits

        st, logits = jax.lax.scan(step, st, (toks.T, jnp.arange(n)))
        return logits[-1], st

    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _ref_step_fn(family: str, cfg) -> Callable:
    _, decode_raw, _ = _OPS[family]
    return jax.jit(lambda p, st, tok, pos: decode_raw(p, st, tok, pos, cfg),
                   donate_argnums=(1,))


class _RecurrentBackend(BackendBase):
    """Shared `DecodeBackend` implementation over `_OPS[family]`."""

    family = ""
    supports_speculation = True

    def __init__(self, params: Any, cfg: Any, ecfg: Any):
        super().__init__(params, cfg, ecfg)
        mode = getattr(ecfg, "spec_mode", "auto")
        self.spec_mode = "self" if mode == "auto" else mode
        if getattr(ecfg, "spec_k", 0) and self.spec_mode not in ("self",
                                                                 "stress"):
            raise ValueError(
                f"recurrent backends speculate by self-drafting through "
                f"the decode scan (spec_mode='self') or via the synthetic "
                f"rollback-exercising 'stress' mode (got {mode!r})")
        # inline landmark finalize for the hybrid's attention caches: the
        # slot-wise vmap evaluates both cond branches anyway, and inline
        # semantics make the chunk-scan prefill and the decode step the
        # same per-token function — the exactness recompute rests on
        self.cfg = dataclasses.replace(
            cfg, attn=dataclasses.replace(cfg.attn, external_finalize=False))
        self.window = cfg.attn.window
        self.capacity = ecfg.pages_per_slot * self.window
        init, _, _ = _OPS[self.family]
        self.states = init(self.cfg, ecfg.n_slots, self.capacity)
        self._decode = _decode_fn(self.family, self.cfg,
                                  ecfg.sample_device == "fused")
        self._t_dev = self._ac_dev = self._rid_dev = None
        self._tp_dev = self._si_dev = None
        self._snap = None                  # stress verify→rollback handoff
        self._verify_toks = self._stress = None

    # ------------------------------------------------------ slot lifecycle --

    def alloc_slot(self, slot: int) -> None:
        # the chunk scan accumulates into the slot's state from zero — a
        # retired occupant's state must not leak into the new request
        self.states = _zero_slot(self.states, np.int32(slot))

    # ----------------------------------------------------------- prefill --

    def prefill_group(self, prompts: np.ndarray, slots: list[int],
                      pages_list: list[list[int]]) -> np.ndarray:
        del pages_list                  # constant-size states: no pages
        k, n = prompts.shape
        nc = window_aligned(n, self.window)
        toks = np.zeros((k, nc), np.int32)
        toks[:, :n] = prompts
        logits, self.states = _chunk_fn(self.family, self.cfg)(
            self.params, self.states, jnp.asarray(slots, jnp.int32),
            jnp.asarray(toks), jnp.zeros(k, jnp.int32),
            jnp.full(k, n, jnp.int32))
        return np.asarray(logits)

    def prefill_chunk(self, slot: int, pt_row: np.ndarray, toks: np.ndarray,
                      t0: int, n_valid: int, n_train: int) -> np.ndarray:
        return self.prefill_chunks(
            [slot], toks[None], np.ones(1, bool), pt_row[None],
            np.array([t0], np.int32), np.array([n_valid], np.int32),
            np.array([n_train], np.int32))[0]

    def prefill_chunks(self, slot_ids: list[int], toks: np.ndarray,
                       job_active: np.ndarray, page_table: np.ndarray,
                       t0: np.ndarray, n_valid: np.ndarray,
                       n_train: np.ndarray) -> np.ndarray:
        del page_table                  # constant-size states: no pages
        del n_train                     # no train/decode semantics boundary:
        #                                 the chunk IS the decode update, so
        #                                 recomputed generated positions are
        #                                 exact by construction
        nv = np.where(job_active, n_valid, 0).astype(np.int32)
        logits, self.states = _chunk_fn(self.family, self.cfg)(
            self.params, self.states, jnp.asarray(slot_ids, jnp.int32),
            jnp.asarray(toks), jnp.asarray(t0, dtype=jnp.int32),
            jnp.asarray(nv))
        return np.asarray(logits)

    # ------------------------------------------------------------- decode --

    def decode_step(self, tokens_in: np.ndarray, t: np.ndarray,
                    active: np.ndarray, page_table: np.ndarray,
                    rid: np.ndarray, temperature: np.ndarray,
                    sample_idx: np.ndarray, key: jax.Array) -> np.ndarray:
        del page_table                  # constant-size states: no pages
        if self._dirty:
            self._t_dev = jnp.asarray(t)
            self._ac_dev = jnp.asarray(active)
            self._rid_dev = jnp.asarray(rid)
            self._tp_dev = jnp.asarray(temperature)
            self._si_dev = jnp.asarray(sample_idx)
            self._dirty = False
        out, self.states, self._t_dev, self._si_dev = self._decode(
            self.params, self.states, jnp.asarray(tokens_in), self._t_dev,
            self._ac_dev, self._rid_dev, self._si_dev, self._tp_dev, key)
        self.decode_dispatches += 1
        return np.asarray(out)

    # -------------------------------------------------------- speculation --

    def draft_steps(self, tokens_in: np.ndarray, t: np.ndarray,
                    active: np.ndarray, page_table: np.ndarray,
                    rid: np.ndarray, temperature: np.ndarray,
                    sample_idx: np.ndarray, key: jax.Array,
                    spec_len: np.ndarray) -> np.ndarray:
        del page_table                  # constant-size states: no pages
        k = self.ecfg.spec_k
        if self.spec_mode == "stress":
            # synthetic host-side proposals, deliberately (mostly) wrong:
            # zero dispatches here, and the verify/rollback pair below gets
            # exercised with real mismatches — the conformance suite's way
            # of pinning rollback bit-exactness on a backend whose natural
            # speculation never rejects
            off = np.arange(1, k + 1, dtype=np.int32)[:, None]
            return ((np.asarray(tokens_in, np.int32)[None] + off)
                    % self.cfg.vocab)
        drafts, self.states = _spec_draft_fn(self.family, self.cfg, k)(
            self.params, self.states, jnp.asarray(tokens_in, jnp.int32),
            jnp.asarray(t), jnp.asarray(active), jnp.asarray(rid),
            jnp.asarray(sample_idx), jnp.asarray(temperature), key,
            jnp.asarray(spec_len))
        self.decode_dispatches += 1
        return np.asarray(drafts)

    def verify_step(self, tokens_in: np.ndarray, t: np.ndarray,
                    active: np.ndarray, page_table: np.ndarray,
                    rid: np.ndarray, temperature: np.ndarray,
                    sample_idx: np.ndarray, key: jax.Array,
                    spec_len: np.ndarray,
                    drafts: np.ndarray) -> np.ndarray:
        del page_table                  # constant-size states: no pages
        k = self.ecfg.spec_k
        tokens_in = np.asarray(tokens_in, np.int32)
        t = np.asarray(t)
        active = np.asarray(active)
        spec_len = np.asarray(spec_len)
        sample_idx = np.asarray(sample_idx)
        if self.spec_mode == "stress":
            # snapshot (the scan donates the LIVE state, not the copy),
            # then teacher-force [input, drafts...] through the decode
            # scan; rollback restores + replays the committed prefix with
            # the same inputs, stashed here
            self._snap = _tree_copy(self.states)
            self._stress = (tokens_in, t, np.asarray(rid),
                            np.asarray(temperature), sample_idx, key)
            toks = np.concatenate([tokens_in[None], np.asarray(drafts)], 0)
            outs, self.states = _spec_tf_fn(self.family, self.cfg, k + 1)(
                self.params, self.states, jnp.asarray(toks, jnp.int32),
                jnp.asarray(t), jnp.asarray(active), jnp.asarray(rid),
                jnp.asarray(sample_idx), jnp.asarray(temperature), key,
                jnp.asarray(spec_len + 1))
            self.decode_dispatches += 1
            self._verify_toks = np.asarray(outs)
            return self._verify_toks
        # self mode: the draft scan already ran the exact decode rule and
        # committed its state, so the drafts verify themselves; one more
        # masked decode step at t0 + spec_len samples the correction token
        s = len(tokens_in)
        rows = np.maximum(spec_len - 1, 0)
        tok_v = np.where(spec_len > 0,
                         np.asarray(drafts)[rows, np.arange(s)], tokens_in)
        self._dirty = True              # mirrors must see spec'd t/si
        corr = self.decode_step(
            tok_v.astype(np.int32), t + spec_len, active, None, rid,
            temperature, sample_idx + spec_len, key)
        self._dirty = True              # ...and forget them afterwards
        verify = np.concatenate(
            [np.asarray(drafts), np.zeros((1, s), np.int32)], 0)
        verify[spec_len, np.arange(s)] = corr
        return verify

    def rollback(self, commits: np.ndarray, active: np.ndarray) -> None:
        if self.spec_mode == "self":
            return                      # drafted state IS the decode state
        tokens_in, t, rid, temp, sample_idx, key = self._stress
        n = np.where(np.asarray(active), np.asarray(commits), 0)
        # the committed prefix of the verify scan consumed exactly
        # [input, verify[0..c-2]] — replaying that stream from the
        # snapshot is bit-identical to having decoded it step by step
        toks = np.concatenate([tokens_in[None], self._verify_toks[:-1]], 0)
        _, self.states = _spec_tf_fn(self.family, self.cfg,
                                     self.ecfg.spec_k + 1)(
            self.params, self._snap, jnp.asarray(toks, jnp.int32),
            jnp.asarray(t), jnp.asarray(active), jnp.asarray(rid),
            jnp.asarray(sample_idx), jnp.asarray(temp), key,
            jnp.asarray(n, jnp.int32))
        self.decode_dispatches += 1
        self._snap = self._verify_toks = self._stress = None

    # ------------------------------------------------------------- oracle --

    def static_reference(self, prompts: np.ndarray, max_new: int,
                         temperature: float = 0.0,
                         rids: Optional[list[int]] = None,
                         sample_key: Optional[jax.Array] = None
                         ) -> np.ndarray:
        """Full-forward reference: time-major prompt scan + single-token
        decode, batch-independent per lane.  Greedy by default; with
        ``temperature`` > 0, keys derive from (rid, token index) exactly
        like the engine's sampler, so tokens stay schedule-invariant."""
        b, n = prompts.shape
        if sample_key is None:
            sample_key = jax.random.PRNGKey(0)
        rids = list(rids) if rids is not None else list(range(b))
        init, _, _ = _OPS[self.family]
        states = init(self.cfg, b, self.capacity)
        logits, states = _ref_prefill_fn(self.family, self.cfg, n)(
            self.params, states, jnp.asarray(prompts, jnp.int32))
        step = _ref_step_fn(self.family, self.cfg)

        def sample(lg, row, index):
            return sample_host(lg, rids[row], index, temperature,
                               sample_key)

        logits = np.asarray(logits)
        out = [[sample(logits[row], row, 0)] for row in range(b)]
        for i in range(1, max_new):
            tok = jnp.asarray([o[-1] for o in out], jnp.int32)
            logits, states = step(self.params, states, tok,
                                  jnp.full((b,), n + i - 1, jnp.int32))
            logits = np.asarray(logits)
            for row in range(b):
                out[row].append(sample(logits[row], row, i))
        return np.asarray(out, np.int32)


class Mamba2Backend(_RecurrentBackend):
    """SSD decode state per slot: h [H, P, S] + conv tail — the paper
    taxonomy's compressed fast-weight module as a servable backend."""

    name = family = "mamba2"


class RGLRUBackend(_RecurrentBackend):
    """RecurrentGemma hybrid: RG-LRU recurrences + a bounded per-slot
    attention cache advanced at per-slot positions
    (`models.transformer.attention_decode_slots`)."""

    name = family = "rglru"
