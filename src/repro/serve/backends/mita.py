"""Paged MiTA serving backend — the engine's original device-side path.

Everything the PR-1..4 engine knew about MiTA lives here now, behavior-
unchanged and pinned by the existing greedy-bit-parity tests: the paged
KV/landmark/expert pools (`core.mita_decode.PagedMiTAState`), the fused
whole-batch decode step (window-boundary landmark finalize behind a scalar
`lax.cond`, optional fused sampling), the monolithic prefill+pack program,
the per-job and batched chunk-prefill programs (fused Pallas kernel vs XLA
dispatch inside, `kernels.ops.use_prefill_kernel`), and the per-slot
``m_done`` finalize bookkeeping with its device mirrors.

The scheduler sees none of it: it talks the `DecodeBackend` protocol
(`serve.backends`), and this module translates protocol calls into the
compiled programs documented in docs/serving.md.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mita_decode as mdec
from repro.models import transformer as tfm
from repro.models.modules import ModelConfig
from repro.serve.backends import BackendBase


@functools.lru_cache(maxsize=None)
def _decode_fn(cfg: ModelConfig, fused_finalize: bool,
               fused_sampling: bool) -> Callable:
    """Fused whole-batch decode step, cached at module level so every
    backend instance with the same model config shares compiled code.

    Scheduler tensors (t, m_done, sample index) advance ON DEVICE: the hot
    loop uploads only the fed-back tokens — page tables, activity,
    positions, and per-request (rid, temperature) are re-uploaded solely
    when admission/retire changes them.  With ``fused_sampling`` the step
    also samples inside the program (`tfm.sample_tokens`) and returns [S]
    int32 tokens; otherwise it returns the [S, V] logits for the host
    sampler."""
    w = cfg.attn.window

    def step(p, st, tok, t, m_done, pt, ac, rid, si, temp, key):
        due = None
        if fused_finalize:
            due = ac & (t % w == 0) & (t // w > m_done)
            m_done = jnp.where(due, t // w, m_done)
        sample = (rid, si, temp, key) if fused_sampling else None
        out, st = tfm.lm_paged_decode_step(p, st, tok, t, pt, ac, cfg,
                                           due=due, sample=sample)
        adv = ac.astype(t.dtype)
        return out, st, t + adv, m_done, si + adv

    return jax.jit(step, donate_argnums=(1, 3, 4, 8))


@functools.lru_cache(maxsize=None)
def _prefill_pack_fn(cfg: ModelConfig, cap: int, k: int) -> Callable:
    """Fused batched prefill + pack-into-slots: one dispatch admits ``k``
    same-length requests (compiled per window-aligned capacity and group
    size).  Prefill rows are independent, so batching admissions does not
    change any request's tokens."""

    def prefill_pack(p, st, toks, slots, pages):
        logits, pre = tfm.lm_prefill(p, toks, cfg, cap)
        for i in range(k):
            pre_i = jax.tree.map(
                lambda a: a[:, i:i + 1] if a.ndim >= 2 else a, pre)
            st = tfm.pack_prefill_into_states(st, pre_i, slots[i], pages[i],
                                              cfg)
        return logits, st

    return jax.jit(prefill_pack, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _chunk_prefill_fn(cfg: ModelConfig, chunk: int, m_slot: int) -> Callable:
    """Per-job chunked prefill program (``prefill_mode="per-job"``): ONE
    compiled shape per (chunk length, pages-per-slot) serves every chunk of
    every request — resume point, validity, and the training/decode
    semantics boundary are data."""

    def run(p, st, toks, slot, pt_row, t0, n_valid, n_train):
        return tfm.lm_prefill_chunk(p, st, toks, slot, pt_row, t0, n_valid,
                                    n_train, cfg)

    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _batched_chunk_prefill_fn(cfg: ModelConfig, chunk: int,
                              m_slot: int) -> Callable:
    """Batched chunked prefill program (``prefill_mode="batched"``, the
    default): EVERY currently-prefilling slot advances one chunk in ONE
    dispatch — which slots advance, their resume points, and validity are
    data, so the engine issues exactly one prefill dispatch per step no
    matter how many requests are mid-prefill.  Rows are packed to power-
    of-two widths; non-aligned prompts ride the same program (the n//m
    landmark quirk is per-slot data;
    `core.mita_decode.mita_batched_chunk_prefill`)."""

    def run(p, st, toks, job_active, pt, slots, t0, n_valid, n_train):
        return tfm.lm_prefill_chunks(p, st, toks, job_active, pt, slots,
                                     t0, n_valid, n_train, cfg)

    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _draft_fn(cfg: ModelConfig, n_pos: int) -> Callable:
    """Self-drafting program: ``n_pos`` landmark-branch-only forward
    passes, each feeding its sampled token to the next (``lm_landmark_
    draft``).  Read-only — no donation, no state output: a rejected draft
    has nothing to undo."""

    def run(p, st, tok, t, ac, m_cnt, rid, si, temp, key):
        return tfm.lm_landmark_draft(p, st, tok, t, ac, m_cnt, cfg, n_pos,
                                     rid, si, temp, key)

    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _verify_fn(cfg: ModelConfig, fused_finalize: bool,
               n_pos: int) -> Callable:
    """Teacher-forced verify: ONE program scans the EXACT fused decode body
    (`_decode_fn`'s step, finalize cond and all) over the ``n_pos`` =
    spec_k + 1 positions [input, drafts...], sampling at every position.
    Collects the sampled tokens [n_pos, S] plus a per-position q_sum
    snapshot stack for `rollback` — the draft horizon guarantees the
    landmark finalize can only fire at position 0 (always committed), so
    the running query sum is the ONLY state a rejected suffix perturbs
    (appended KV rows past the commit point are masked by ``t`` and
    overwritten by future appends; no page churn)."""
    w = cfg.attn.window

    def run(p, st, toks, t, m_done, pt, ac, rid, si, temp, key, spec_len):
        def body(carry, inp):
            st, t, m_done, si = carry
            i, tok = inp
            ac_i = ac & (i <= spec_len)
            due = None
            if fused_finalize:
                due = ac_i & (t % w == 0) & (t // w > m_done)
                m_done = jnp.where(due, t // w, m_done)
            out, st = tfm.lm_paged_decode_step(
                p, st, tok, t, pt, ac_i, cfg, due=due,
                sample=(rid, si, temp, key))
            adv = ac_i.astype(t.dtype)
            return (st, t + adv, m_done, si + adv), (out, st.q_sum)

        (st, _, _, _), (toks_out, q_stack) = jax.lax.scan(
            body, (st, t, m_done, si), (jnp.arange(n_pos), toks))
        return toks_out, q_stack, st

    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _rollback_fn(cfg: ModelConfig) -> Callable:
    """Rewind the running query sums to the snapshot taken after the last
    committed verify position: per-slot gather of ``q_stack[commits - 1]``
    (commits >= 1 always — position 0 commits unconditionally; inactive
    slots pass commits=1, whose stack row equals their untouched sums
    because the verify scan's accumulate and finalize are active-masked)."""

    def run(st, q_stack, commits):
        sel = jnp.moveaxis(q_stack, 2, 0)            # [S, k+1, L, Hkv, d]
        idx = (commits - 1)[:, None, None, None, None]
        picked = jnp.take_along_axis(sel, idx, axis=1)[:, 0]
        return st._replace(q_sum=jnp.moveaxis(picked, 0, 1))

    return jax.jit(run, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _attach_prefix_fn(cfg: ModelConfig) -> Callable:
    """Install cached prefix summary rows into one slot: landmark
    queries/values, global expert rows and their validity, with both
    running query sums zeroed (a window-aligned resume point closes every
    window, so the cold engine's sums are exactly zero there too) and the
    prompt-side landmark queries mirroring the committed ones (for
    window-aligned prompts the two landmark systems share one grid —
    which is precisely why only aligned prefixes are cached).  One
    compiled shape per model config: the slot is data and rows beyond the
    attached prefix are zeros, masked by landmark availability exactly
    like a retired slot's stale rows."""

    def attach(st, slot, lm_q, lm_v, ei, ev):
        zero = jnp.zeros(st.q_sum.shape[:1] + st.q_sum.shape[2:],
                         st.q_sum.dtype)
        return st._replace(
            lm_q=st.lm_q.at[:, slot].set(lm_q),
            lm_v=st.lm_v.at[:, slot].set(lm_v),
            expert_idx=st.expert_idx.at[:, slot].set(ei),
            expert_valid=st.expert_valid.at[:, slot].set(ev),
            pre_lm_q=st.pre_lm_q.at[:, slot].set(lm_q),
            q_sum=st.q_sum.at[:, slot].set(zero),
            pre_q_sum=st.pre_q_sum.at[:, slot].set(zero))

    return jax.jit(attach, donate_argnums=(0,))


class MiTABackend(BackendBase):
    """Paged MiTA decode caches behind the `DecodeBackend` protocol."""

    name = "mita"
    supports_prefix_cache = True
    supports_speculation = True

    def __init__(self, params: Any, cfg: ModelConfig, ecfg: Any):
        from repro.kernels import ops
        super().__init__(params, cfg, ecfg)
        if cfg.attn.backend not in ("mita", "mita_ref"):
            raise ValueError("MiTABackend drives MiTA decode caches "
                             f"(got attention backend {cfg.attn.backend!r})")
        mode = getattr(ecfg, "spec_mode", "auto")
        if getattr(ecfg, "spec_k", 0) and mode not in ("auto", "landmark"):
            raise ValueError(
                f"MiTABackend speculates by self-drafting against the "
                f"compressed landmark branch (spec_mode='landmark'; got "
                f"{mode!r})")
        self.spec_mode = "landmark"
        # kernel→XLA VMEM fallbacks are counted process-wide at trace
        # time; this backend reports the deltas since it was built
        self._fallback_base = ops.fallback_counters()
        self._q_stack = None                  # verify→rollback handoff
        self.cfg = dataclasses.replace(
            cfg, attn=dataclasses.replace(
                cfg.attn, external_finalize=ecfg.finalize == "external"))
        self.window = cfg.attn.window
        s = ecfg.n_slots
        self.states = tfm.init_paged_states(self.cfg, s, ecfg.n_pages,
                                            ecfg.pages_per_slot)
        self.m_done = np.zeros(s, np.int32)   # finalized landmarks per slot
        # window-boundary landmark finalize fused behind a lax.cond —
        # off-boundary steps skip the O(context) work inside ONE program
        self._decode = _decode_fn(self.cfg, ecfg.finalize == "external",
                                  ecfg.sample_device == "fused")
        # device mirrors of the scheduler tensors (uploaded on change)
        self._t_dev = self._md_dev = self._pt_dev = self._ac_dev = None
        self._rid_dev = self._tp_dev = self._si_dev = None
        self._traceable: set[int] = set()     # validated prompt lengths

    # ------------------------------------------------------------ sizing --

    def chunkable(self, n_train: int, batched: bool) -> bool:
        """The batched chunk program serves any prompt (the n//m landmark
        quirk is per-slot data); the per-job program needs window-aligned
        prompts — the engine routes the rest through the monolithic head."""
        return batched or n_train % self.window == 0

    def validate_prompt(self, n: int, path: str) -> None:
        if path == "monolithic":
            self._check_prefill_traceable(n)
        elif n % self.window:
            # the chunk program replicates the training head's n//m
            # landmark pooling — representable only when m divides n
            # (pool1d's constraint, the same lengths the static path serves)
            if n % max(1, n // self.window):
                raise ValueError(
                    f"prompt length {n} is not servable by the chunked "
                    f"prefill path (window {self.window}): the training-"
                    "path landmark pooling needs n % (n // window) == 0")

    def _check_prefill_traceable(self, n: int) -> None:
        """Reject prompt lengths the prefill path cannot lower (e.g. the
        sorted-mita block_q divisibility constraint) at SUBMIT time, with
        abstract tracing only — a length that failed inside admission after
        scheduler state was mutated would leak the slot and its pages."""
        if n in self._traceable:
            return
        cap = mdec.window_aligned(n, self.window)
        mdl = self.cfg
        try:
            jax.eval_shape(
                lambda p, tok: tfm.lm_prefill(p, tok, mdl, cap),
                self.params,
                jax.ShapeDtypeStruct((1, n), jnp.int32))
        except Exception as e:
            raise ValueError(
                f"prompt length {n} is not servable by the "
                f"{mdl.attn.backend!r} prefill path (window {self.window}):"
                f" {e}") from e
        self._traceable.add(n)

    # ----------------------------------------------------------- prefill --

    def prefill_group(self, prompts: np.ndarray, slots: list[int],
                      pages_list: list[list[int]]) -> np.ndarray:
        k, n = prompts.shape
        cap = mdec.window_aligned(n, self.window)
        logits, self.states = _prefill_pack_fn(self.cfg, cap, k)(
            self.params, self.states, jnp.asarray(prompts, jnp.int32),
            jnp.asarray(slots, jnp.int32),
            jnp.asarray(np.stack(
                [pg[: cap // self.window] for pg in pages_list]), jnp.int32))
        return np.asarray(logits)

    def prefill_chunk(self, slot: int, pt_row: np.ndarray, toks: np.ndarray,
                      t0: int, n_valid: int, n_train: int) -> np.ndarray:
        fn = _chunk_prefill_fn(self.cfg, self.ecfg.prefill_chunk,
                               self.ecfg.pages_per_slot)
        logits, self.states = fn(
            self.params, self.states, jnp.asarray(toks), np.int32(slot),
            jnp.asarray(pt_row), np.int32(t0), np.int32(n_valid),
            np.int32(n_train))
        return np.asarray(logits)

    def prefill_chunks(self, slot_ids: list[int], toks: np.ndarray,
                       job_active: np.ndarray, page_table: np.ndarray,
                       t0: np.ndarray, n_valid: np.ndarray,
                       n_train: np.ndarray) -> np.ndarray:
        fn = _batched_chunk_prefill_fn(self.cfg, self.ecfg.prefill_chunk,
                                       self.ecfg.pages_per_slot)
        logits, self.states = fn(
            self.params, self.states, jnp.asarray(toks),
            jnp.asarray(job_active), jnp.asarray(page_table),
            jnp.asarray(slot_ids, jnp.int32).reshape(len(slot_ids)),
            jnp.asarray(t0), jnp.asarray(n_valid), jnp.asarray(n_train))
        return np.asarray(logits)

    # ------------------------------------------------------ slot lifecycle --

    def slot_filled(self, slot: int, n_tokens: int,
                    snapshot: Any = None) -> None:
        self.m_done[slot] = n_tokens // self.window
        self._dirty = True

    def preempt_snapshot(self, slot: int) -> Any:
        # recompute-from-prompt rebuilds the paged state bit-exactly
        # (`mita_chunk_prefill` replicates decode-time landmark
        # availability past the original prompt) — nothing to save
        return None

    # --------------------------------------------------------- prefix cache --

    def prefix_snapshot(self, slot: int, n_windows: int) -> list:
        """Host copies of the slot's first ``n_windows`` per-window summary
        rows — one (lm_q, lm_v, expert_idx, expert_valid) tuple per window,
        each [L, Hkv, ...] (the per-layer stack).  The expert rows are
        GLOBAL pool rows into the prefix's own pages, so they stay valid
        for every future holder of those pages — the radix cache's path
        invariant guarantees a node's pages outlive the node."""
        st = self.states
        lm_q, lm_v, ei, ev = jax.device_get(
            (st.lm_q[:, slot], st.lm_v[:, slot],
             st.expert_idx[:, slot], st.expert_valid[:, slot]))
        return [(lm_q[:, :, i].copy(), lm_v[:, :, i].copy(),
                 ei[:, :, i].copy(), ev[:, :, i].copy())
                for i in range(n_windows)]

    def attach_prefix(self, slot: int, payloads: list) -> None:
        """Make ``slot`` look exactly as if it had chunk-prefilled the
        cached windows itself: summary rows installed, query sums zeroed
        (window-aligned resume), pages arrive via the page table.  Padded
        to the full per-slot landmark capacity on the host so one jitted
        program (slot and rows are data) serves every hit."""
        st = self.states
        _, _, hkv, m_cap, d = st.lm_q.shape
        n_layers = st.lm_q.shape[0]
        k_w = st.expert_idx.shape[-1]
        lm_q = np.zeros((n_layers, hkv, m_cap, d), st.lm_q.dtype)
        lm_v = np.zeros((n_layers, hkv, m_cap, d), st.lm_v.dtype)
        ei = np.zeros((n_layers, hkv, m_cap, k_w), st.expert_idx.dtype)
        ev = np.zeros((n_layers, hkv, m_cap, k_w), bool)
        for i, (q_i, v_i, ei_i, ev_i) in enumerate(payloads):
            lm_q[:, :, i] = q_i
            lm_v[:, :, i] = v_i
            ei[:, :, i] = ei_i
            ev[:, :, i] = ev_i
        self.states = _attach_prefix_fn(self.cfg)(
            self.states, np.int32(slot), jnp.asarray(lm_q),
            jnp.asarray(lm_v), jnp.asarray(ei), jnp.asarray(ev))

    # ------------------------------------------------------------- decode --

    def decode_step(self, tokens_in: np.ndarray, t: np.ndarray,
                    active: np.ndarray, page_table: np.ndarray,
                    rid: np.ndarray, temperature: np.ndarray,
                    sample_idx: np.ndarray, key: jax.Array) -> np.ndarray:
        if self._dirty:
            self._t_dev = jnp.asarray(t)
            self._md_dev = jnp.asarray(self.m_done)
            self._pt_dev = jnp.asarray(page_table)
            self._ac_dev = jnp.asarray(active)
            self._rid_dev = jnp.asarray(rid)
            self._tp_dev = jnp.asarray(temperature)
            self._si_dev = jnp.asarray(sample_idx)
            self._dirty = False
        # host mirror of the device-side due/m_done transition
        w = self.window
        due = active & (t % w == 0) & (t // w > self.m_done)
        self.m_done = np.where(due, t // w, self.m_done)

        out, self.states, self._t_dev, self._md_dev, self._si_dev = \
            self._decode(self.params, self.states, jnp.asarray(tokens_in),
                         self._t_dev, self._md_dev, self._pt_dev,
                         self._ac_dev, self._rid_dev, self._si_dev,
                         self._tp_dev, key)
        self.decode_dispatches += 1
        # fused sampling downloads [S] int32 tokens; the host path the
        # whole [S, V] logits (docs/serving.md, host-transfer budget)
        return np.asarray(out)

    # -------------------------------------------------------- speculation --

    def draft_horizon(self, t: np.ndarray) -> np.ndarray:
        """Stop drafting short of the next landmark finalize so it can only
        fire at verify position 0 (which always commits): a rejected draft
        then never needs a landmark/expert/m_done rollback, and every
        speculative append stays inside the slot's current page — the one
        `_ensure_append_pages` guarantees.  With ``r = t % window``:
        external finalize fires when a position hits a window boundary;
        inline finalize fires one position earlier (it closes window
        ``(t+1) // w`` after the append), so at ``r == w - 1`` the round
        degenerates to plain decode — position ``t`` is the page's last
        row and drafting past it would append into an unowned page."""
        r = np.asarray(t) % self.window
        if self.cfg.attn.external_finalize:
            return np.where(r != 0, self.window - r - 1, self.window - 1)
        return np.where(r < self.window - 1, self.window - 2 - r, 0)

    def draft_steps(self, tokens_in: np.ndarray, t: np.ndarray,
                    active: np.ndarray, page_table: np.ndarray,
                    rid: np.ndarray, temperature: np.ndarray,
                    sample_idx: np.ndarray, key: jax.Array,
                    spec_len: np.ndarray) -> np.ndarray:
        # drafts attend ONLY the already-finalized landmark tiles — no
        # expert gather, no page-walk: page_table is unused, and the
        # landmark count is frozen at the round's start (external mode
        # drafts against the host m_done mirror; the position-0 finalize
        # lands in the verify step)
        ac = np.asarray(active) & (np.asarray(spec_len) > 0)
        m_cnt = (self.m_done.copy() if self.cfg.attn.external_finalize
                 else np.asarray(t) // self.window)
        drafts = _draft_fn(self.cfg, self.ecfg.spec_k)(
            self.params, self.states, jnp.asarray(tokens_in, jnp.int32),
            jnp.asarray(t), jnp.asarray(ac), jnp.asarray(m_cnt),
            jnp.asarray(rid), jnp.asarray(sample_idx),
            jnp.asarray(temperature), key)
        self.decode_dispatches += 1
        return np.asarray(drafts)

    def verify_step(self, tokens_in: np.ndarray, t: np.ndarray,
                    active: np.ndarray, page_table: np.ndarray,
                    rid: np.ndarray, temperature: np.ndarray,
                    sample_idx: np.ndarray, key: jax.Array,
                    spec_len: np.ndarray,
                    drafts: np.ndarray) -> np.ndarray:
        t = np.asarray(t)
        active = np.asarray(active)
        md_old = self.m_done.copy()
        if self.cfg.attn.external_finalize:
            # host mirror of the device transition: the draft horizon
            # guarantees finalize can only fire at position 0
            w = self.window
            due0 = active & (t % w == 0) & (t // w > self.m_done)
            self.m_done = np.where(due0, t // w, self.m_done)
        toks = np.concatenate(
            [np.asarray(tokens_in, np.int32)[None], np.asarray(drafts)], 0)
        fn = _verify_fn(self.cfg, self.cfg.attn.external_finalize,
                        self.ecfg.spec_k + 1)
        toks_out, self._q_stack, self.states = fn(
            self.params, self.states, jnp.asarray(toks, jnp.int32),
            jnp.asarray(t), jnp.asarray(md_old), jnp.asarray(page_table),
            jnp.asarray(active), jnp.asarray(rid),
            jnp.asarray(sample_idx), jnp.asarray(temperature), key,
            jnp.asarray(spec_len))
        self.decode_dispatches += 1
        return np.asarray(toks_out)

    def rollback(self, commits: np.ndarray, active: np.ndarray) -> None:
        commits = np.where(np.asarray(active), np.asarray(commits), 1)
        self.states = _rollback_fn(self.cfg)(
            self.states, self._q_stack, jnp.asarray(commits, jnp.int32))
        self._q_stack = None

    def stats(self) -> dict:
        from repro.kernels import ops
        s = super().stats()
        now = ops.fallback_counters()
        s["prefill_kernel_fallbacks"] = (now["prefill"]
                                         - self._fallback_base["prefill"])
        s["paged_kernel_fallbacks"] = now["paged"] - self._fallback_base["paged"]
        s["finalize_kernel_fallbacks"] = (now["finalize"]
                                          - self._fallback_base["finalize"])
        return s

    # ------------------------------------------------------------- oracle --

    def static_reference(self, prompts: np.ndarray, max_new: int,
                         temperature: float = 0.0,
                         rids: Optional[list[int]] = None,
                         sample_key: Optional[jax.Array] = None
                         ) -> np.ndarray:
        """Static fixed-batch baseline at the slot capacity — the oracle
        the engine's greedy tokens are pinned against.  Greedy delegates
        to `launch.serve.static_generate` (the historical pin);
        ``temperature`` > 0 drives the same static programs step-by-step
        but samples with the engine's (rid, index)-keyed rule
        (`serve.backends.sample_host`), so tempered parity checks mean the
        same thing on every backend."""
        from repro.launch.serve import _static_fns, static_generate
        capacity = self.ecfg.pages_per_slot * self.window
        if temperature <= 0.0:
            gen, _ = static_generate(
                self.params, self.cfg, jnp.asarray(prompts, jnp.int32),
                max_new, capacity=capacity)
            return gen
        from repro.serve.backends import sample_host
        if sample_key is None:
            sample_key = jax.random.PRNGKey(0)
        b, n = prompts.shape
        rids = list(rids) if rids is not None else list(range(b))
        w = self.window
        prefill, decode, finalize = _static_fns(
            self.cfg, mdec.window_aligned(capacity, w))
        logits, states = prefill(self.params,
                                 jnp.asarray(prompts, jnp.int32))
        logits = np.asarray(logits)
        out = [[sample_host(logits[row], rids[row], 0, temperature,
                            sample_key)] for row in range(b)]
        m_done = n // w
        for i in range(1, max_new):
            pos = n + i - 1
            if self.cfg.attn.external_finalize and pos % w == 0 \
                    and pos // w > m_done:
                states = finalize(states)
                m_done = pos // w
            tok = jnp.asarray([o[-1] for o in out], jnp.int32)
            logits, states = decode(self.params, states, tok,
                                    jnp.asarray(pos))
            logits = np.asarray(logits)
            for row in range(b):
                out[row].append(sample_host(logits[row], rids[row], i,
                                            temperature, sample_key))
        return np.asarray(out, np.int32)
