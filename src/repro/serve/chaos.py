"""Deterministic fault injection for the serving stack.

`ChaosBackend` wraps ANY `DecodeBackend` and fires a seeded, scripted
fault schedule at the protocol boundary so every failure path the
supervisor claims to handle (`serve/supervisor.py`) is exercisable in CI
— the same idea as the training harness's restart tests
(`distributed/fault_tolerance.py`), applied to serving.

Fault taxonomy (docs/serving.md §Failure domains):

  * **transient** — an intercepted dispatch raises `InjectedFault` for
    ``transient_len`` consecutive calls of that op, then heals; the
    supervisor's retry loop absorbs it.
  * **slot-bound** — one active slot is implicated; the fault persists
    until the supervisor quarantines that slot (`on_quarantine`), which
    models a poisoned request / corrupt slot state.  The victim is
    resurrected through recompute-from-prompt, bit-identically.
  * **persistent** — the op keeps raising until the supervisor climbs the
    degradation ladder to ``persistent_clears_at`` (`on_degrade`), which
    models a feature-specific failure a fallback path avoids.
  * **allocator spike** — every ``alloc_spike_every``-th intercepted call
    grabs up to ``alloc_spike_pages`` pages from the engine's pool
    (`bind_allocator`) and holds them for ``alloc_spike_len`` calls,
    creating real page pressure (preemptions, reserve dips) without any
    fake accounting; `on_stall` / `release_spikes` return them, so a
    drained trace always ends at zero pages in use.
  * **straggler** — a dispatch sleeps ``slow_s`` with probability
    ``p_slow`` before running; the supervisor's `StepTimer` EWMA must
    flag it (the `distributed.fault_tolerance` detector, reused).

Faults fire BEFORE delegating to the wrapped backend, so a faulted
dispatch never starts on device: retrying the engine step re-issues the
identical dispatch against unchanged backend state, which is what makes
supervised streams bit-identical to fault-free ones.  The schedule is a
pure function of `ChaosConfig` (seeded `numpy` Generator) and the call
sequence — same config, same trace, same faults.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np

#: ops the injector may intercept (a ChaosConfig.ops subset selects)
CHAOS_OPS = ("prefill_group", "prefill_chunk", "prefill_chunks",
             "decode_step", "draft_steps", "verify_step")


class InjectedFault(RuntimeError):
    """A scripted backend failure.  ``slots`` are the implicated slots
    (what the supervisor may quarantine); ``batchwide``=False marks a
    slot-bound fault where quarantining ``slots`` clears it."""

    def __init__(self, op: str, slots: list, kind: str,
                 batchwide: bool = True):
        super().__init__(f"injected {kind} fault in {op} (slots={slots})")
        self.op = op
        self.slots = list(slots)
        self.kind = kind
        self.batchwide = batchwide


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Seeded fault schedule.  All probabilities are per intercepted
    dispatch; ``p_slot_fault`` + ``p_persistent`` <= 1 split new faults
    into kinds (the remainder is transient).  Only one raising fault is
    live at a time — its lifecycle must resolve (heal / quarantine /
    degrade) before the next can start, which keeps schedules readable
    and every fault's resolution observable."""
    seed: int = 0
    p_fault: float = 0.0            # new-fault probability per dispatch
    ops: tuple = ("decode_step", "prefill_chunks", "prefill_chunk",
                  "prefill_group", "verify_step")
    transient_len: int = 1          # raises per transient fault
    p_persistent: float = 0.0       # fraction of faults that persist
    persistent_clears_at: int = 1   # ladder rung that heals them
    p_slot_fault: float = 0.0       # fraction bound to one slot
    p_slow: float = 0.0             # straggler probability per dispatch
    slow_s: float = 0.0             # injected dispatch delay (seconds)
    alloc_spike_every: int = 0      # 0 = no allocator spikes
    alloc_spike_pages: int = 0      # pages grabbed per spike
    alloc_spike_len: int = 2        # dispatches a spike is held


class ChaosBackend:
    """Delegation wrapper: protocol calls pass through untouched except
    the intercepted ops, which consult the fault schedule first.  The
    supervision hooks (`on_quarantine`/`on_degrade`/`on_stall`) both
    clear matching faults and forward to the wrapped backend."""

    def __init__(self, inner: Any, chaos: ChaosConfig):
        self.inner = inner
        self.chaos = chaos
        self._rng = np.random.default_rng(chaos.seed)
        self._fault: Optional[dict] = None
        self._alloc = None              # engine page allocator, if bound
        self._spike_pages: list[int] = []
        self._spike_ttl = 0
        self._calls = 0
        self.n_injected = 0             # raises fired
        self.n_faults_started = 0       # distinct fault lifecycles
        self.n_spikes = 0
        self.n_slowed = 0

    # --------------------------------------------------------- delegation --

    def __getattr__(self, name: str) -> Any:
        return getattr(self.inner, name)

    def fresh(self) -> Any:
        # warmup scratch engines must compile, not crash: the fresh
        # instance is the bare inner backend, chaos-free
        return self.inner.fresh()

    # ---------------------------------------------------------- lifecycle --

    def bind_allocator(self, alloc: Any) -> None:
        """Give the injector the engine's page allocator so spikes apply
        REAL pool pressure (call after engine construction)."""
        self._alloc = alloc

    def inject(self, op: str, kind: str = "transient",
               slots: tuple = (), raises: Optional[int] = None) -> None:
        """Script ONE fault deterministically, bypassing the RNG draw —
        how tests and the chaos bench stage exact scenarios (e.g. a
        single persistent fault that walks the whole degradation ladder).
        ``raises`` bounds a transient fault's raise count (defaults to
        ``transient_len``); slot/persistent faults resolve through the
        supervision hooks as usual."""
        if op not in CHAOS_OPS:
            raise ValueError(f"unknown op {op!r}; one of {CHAOS_OPS}")
        if kind not in ("transient", "slot", "persistent"):
            raise ValueError(f"unknown fault kind {kind!r}")
        f: dict = {"op": op, "kind": kind, "slots": [int(s) for s in slots]}
        if kind == "transient":
            f["remaining"] = (self.chaos.transient_len if raises is None
                              else int(raises))
        self.n_faults_started += 1
        self._fault = f

    def release_spikes(self) -> None:
        if self._spike_pages and self._alloc is not None:
            self._alloc.release(self._spike_pages)
        self._spike_pages = []
        self._spike_ttl = 0

    def on_quarantine(self, slots: list) -> None:
        f = self._fault
        if (f is not None and f["kind"] == "slot"
                and set(f["slots"]) <= set(int(s) for s in slots)):
            self._fault = None
        self.inner.on_quarantine(slots)

    def on_degrade(self, level: int) -> None:
        f = self._fault
        if (f is not None and f["kind"] == "persistent"
                and level >= self.chaos.persistent_clears_at):
            self._fault = None
        self.inner.on_degrade(level)

    def on_stall(self) -> None:
        self.release_spikes()
        self.inner.on_stall()

    # ----------------------------------------------------------- schedule --

    def _gate(self, op: str, slots: list[int]) -> None:
        """Consult the schedule before dispatching ``op`` over ``slots``;
        raises `InjectedFault` instead of dispatching when a fault is due.
        Runs straggler and allocator-spike side effects either way."""
        cfg = self.chaos
        self._calls += 1
        if cfg.p_slow > 0.0 and self._rng.random() < cfg.p_slow:
            self.n_slowed += 1
            if cfg.slow_s > 0.0:
                time.sleep(cfg.slow_s)
        if self._spike_pages:
            self._spike_ttl -= 1
            if self._spike_ttl <= 0:
                self.release_spikes()
        elif (cfg.alloc_spike_every and self._alloc is not None
              and self._calls % cfg.alloc_spike_every == 0):
            n = cfg.alloc_spike_pages
            while n > 0 and not self._alloc.can_alloc(n):
                n -= 1
            if n > 0:
                self._spike_pages = self._alloc.alloc(n)
                self._spike_ttl = cfg.alloc_spike_len
                self.n_spikes += 1

        f = self._fault
        if f is not None and f["op"] == op:
            if f["kind"] == "transient":
                if f["remaining"] > 0:
                    f["remaining"] -= 1
                    self.n_injected += 1
                    raise InjectedFault(op, f["slots"], "transient")
                self._fault = None          # healed: dispatch proceeds
            elif f["kind"] == "slot":
                # only raises while its slot is in the dispatch — after a
                # quarantine+readmission races, the hook has cleared it
                if set(f["slots"]) & set(slots):
                    self.n_injected += 1
                    raise InjectedFault(op, f["slots"], "slot",
                                        batchwide=False)
            else:                           # persistent
                self.n_injected += 1
                raise InjectedFault(op, f["slots"], "persistent")
        if (self._fault is None and cfg.p_fault > 0.0 and op in cfg.ops
                and self._rng.random() < cfg.p_fault):
            kind_draw = self._rng.random()
            self.n_faults_started += 1
            if slots and kind_draw < cfg.p_slot_fault:
                target = [slots[int(self._rng.integers(len(slots)))]]
                self._fault = {"op": op, "kind": "slot", "slots": target}
                self.n_injected += 1
                raise InjectedFault(op, target, "slot", batchwide=False)
            if kind_draw < cfg.p_slot_fault + cfg.p_persistent:
                self._fault = {"op": op, "kind": "persistent",
                               "slots": slots}
                self.n_injected += 1
                raise InjectedFault(op, slots, "persistent")
            self._fault = {"op": op, "kind": "transient", "slots": slots,
                           "remaining": cfg.transient_len - 1}
            self.n_injected += 1
            raise InjectedFault(op, slots, "transient")

    # --------------------------------------------------- intercepted ops --

    def prefill_group(self, prompts, slots, pages_list):
        self._gate("prefill_group", [int(s) for s in slots])
        return self.inner.prefill_group(prompts, slots, pages_list)

    def prefill_chunk(self, slot, pt_row, toks, t0, n_valid, n_train):
        self._gate("prefill_chunk", [int(slot)])
        return self.inner.prefill_chunk(slot, pt_row, toks, t0, n_valid,
                                        n_train)

    def prefill_chunks(self, slot_ids, toks, job_active, page_table, t0,
                       n_valid, n_train):
        live = [int(s) for s, a in zip(slot_ids, job_active) if a]
        self._gate("prefill_chunks", live)
        return self.inner.prefill_chunks(slot_ids, toks, job_active,
                                         page_table, t0, n_valid, n_train)

    def decode_step(self, tokens_in, t, active, page_table, rid,
                    temperature, sample_idx, key):
        self._gate("decode_step",
                   [int(s) for s in np.nonzero(np.asarray(active))[0]])
        return self.inner.decode_step(tokens_in, t, active, page_table,
                                      rid, temperature, sample_idx, key)

    def draft_steps(self, tokens_in, t, active, page_table, rid,
                    temperature, sample_idx, key, spec_len):
        self._gate("draft_steps",
                   [int(s) for s in np.nonzero(np.asarray(active))[0]])
        return self.inner.draft_steps(tokens_in, t, active, page_table,
                                      rid, temperature, sample_idx, key,
                                      spec_len)

    def verify_step(self, tokens_in, t, active, page_table, rid,
                    temperature, sample_idx, key, spec_len, drafts):
        self._gate("verify_step",
                   [int(s) for s in np.nonzero(np.asarray(active))[0]])
        return self.inner.verify_step(tokens_in, t, active, page_table,
                                      rid, temperature, sample_idx, key,
                                      spec_len, drafts)

    def stats(self) -> dict:
        # schema-transparent: chaos counters live on the wrapper (the
        # bench/tests read them directly), not in STATS_SCHEMA
        return self.inner.stats()


__all__ = ["CHAOS_OPS", "ChaosBackend", "ChaosConfig", "InjectedFault"]
