"""Continuous-batching serving engine — a backend-agnostic scheduler.

The scheduler is plain host Python and never touches a device tensor:
admission, the priority queue, preemption, chunked-prefill pacing, page
accounting, sampling bookkeeping, and stats are generic over the
`DecodeBackend` protocol (`repro.serve.backends`).  A backend owns the
model parameters, the per-slot decode state, its device mirrors, and every
compiled program; the engine owns requests, slots, pages, and time.
docs/serving.md documents the protocol, the request lifecycle, and each
backend's program inventory.

Per engine step the backend is asked for at most three dispatches:

  * ``prefill_group``   — monolithic prefill of an admission group packed
    straight into the group's slots (``prefill_chunk = 0``);
  * ``prefill_chunks``  — ONE program advancing EVERY currently-prefilling
    slot's chunk per step (batched mode; ``prefill_chunk`` > 0); long
    prompts then admit incrementally, interleaved with the decode batch,
    instead of stalling it.  ``prefill_mode = "per-job"`` keeps the legacy
    one-job-per-step dispatch (``prefill_chunk``);
  * ``decode_step``     — ONE program for the whole slot batch regardless
    of per-request progress (per-slot positions, page tables, and activity
    are data, not shape).  With ``sample_device == "fused"`` sampling runs
    inside the program and the hot loop downloads [S] int32 tokens instead
    of [S, V] logits.

Pages are the scheduler's admission-control currency; whether a page is a
real pool region (the paged-attention backend) or pure context-budget
accounting (constant-size recurrent states) is the backend's business.

Chunked mode also enables priority preemption: under page pressure the
scheduler evicts the lowest-priority victim (releasing its pages) and later
rebuilds it by chunk-prefilling prompt + generated-so-far — recompute-from-
prompt, vLLM-style.  A preempted request emits the same greedy tokens it
would have emitted unpreempted (`tests/test_serve_chunked.py` and
`tests/test_serve_backends.py` pin this per backend).

Greedy sampling is exact w.r.t. each backend's static reference: a request
decoded by the engine emits the same tokens it would emit in a fixed batch
(`tests/test_serve.py` pins this).  Temperature sampling derives its key
from (request id, token index) so results are batching-invariant too.
"""

from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.serve import backends as _backends


class AllocatorInvariantError(RuntimeError):
    """Page accounting corruption: double-free, duplicate release, retain
    of a free page, or an allocation the caller failed to guard with
    `can_alloc`.  These are scheduler bugs, not workload conditions — the
    supervisor re-raises them instead of retrying (`serve/supervisor.py`),
    and no admission-control path may convert them into a rejection."""


@dataclasses.dataclass(eq=False)
class Request:
    """One generation job.

    Shape contract: ``prompt`` is a [n] int32 token array with n >= 1;
    ``max_new_tokens`` >= 1 counts every emitted token INCLUDING the first
    one sampled from the prefill logits, so a request occupies
    ``ceil((n + max_new_tokens) / window)`` pages at full length.

    ``priority``: higher wins.  Admission order is (priority desc, submit
    order); in chunked mode a higher-priority arrival may preempt the
    lowest-priority running request under page pressure (the victim is
    rebuilt later, emitting identical tokens).

    ``eq=False``: requests compare by identity — the scheduler removes them
    from its queue by object, and a generated __eq__ would compare the
    ndarray prompt."""
    rid: int
    prompt: np.ndarray              # [n] int32 token ids
    max_new_tokens: int
    temperature: float = 0.0
    arrival: float = 0.0            # seconds since trace start
    priority: int = 0               # higher = more important
    deadline_ms: Optional[float] = None   # wall-clock SLO from submit


@dataclasses.dataclass
class FinishedRequest:
    """``arrival`` is trace-relative (copied from the Request); all other
    stamps are absolute `time.perf_counter` values.  ``preemptions`` counts
    how many times the request was evicted and rebuilt.

    ``cancelled``: the request was killed by `ServingEngine.cancel` —
    ``tokens`` holds whatever was emitted before the kill (possibly
    nothing), and a request cancelled while still waiting carries zeroed
    admission/TTFT stamps.

    ``reason`` is the structured finish taxonomy (`FINISH_REASONS`):
    ``"complete"`` ran to max_new_tokens; ``"cancelled"`` was killed by
    `cancel`; ``"deadline_expired"`` missed its ``deadline_ms`` SLO (a
    cancel with its own label — ``cancelled`` is True for both);
    ``"rejected"`` was shed at submit time (typed backpressure: the
    request can never fit a slot or no prefill path can serve it) and
    never entered the scheduler."""
    rid: int
    tokens: np.ndarray              # [max_new_tokens] generated ids
    arrival: float
    admitted: float                 # when prefill started
    first_token: float              # TTFT reference point
    finished: float
    token_times: list[float] = dataclasses.field(default_factory=list)
    preemptions: int = 0
    cancelled: bool = False
    reason: str = "complete"


FINISH_REASONS = ("complete", "cancelled", "deadline_expired", "rejected")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Slot/page budget and scheduling knobs.

    Invariants enforced at construction: the pool minus the reserve still
    fits one slot's maximum context (otherwise admission could deadlock),
    and ``prefill_chunk`` is a positive multiple of the backend's window
    (pages are window-quantized, so chunk boundaries must be too).

    ``prefill_chunk`` = 0 (default) keeps the monolithic prefill path:
    full page budget up front, no preemption — exactly the PR-1 engine.
    ``prefill_chunk`` > 0 enables chunked prefill AND priority preemption:
    requests admit with their first chunk's pages only, grow page-by-page,
    and may be evicted for higher-priority work.

    ``reserve_pages``: pages the admission/prefill path may not claim;
    only decode-time appends (one page per ``window`` tokens per slot) can
    dip into them, which is what keeps running requests running when a
    burst of admissions would otherwise drain the pool.

    ``finalize``: backend-interpreted decode-time bookkeeping mode.  For
    the paged-attention backend, "external" runs the window-boundary
    summary update as part of the fused step only when due (the default)
    and "inline" folds it into every step; constant-size recurrent
    backends have no deferred work and ignore it.

    ``sample_device``: where decode-time sampling runs.  ``"host"``
    downloads the [S, V] logits every step and samples in Python;
    ``"fused"`` samples inside the decode program and downloads [S] int32
    tokens — same greedy argmax, same (rid, index)-derived categorical
    keys, so tokens are bit-identical across the two modes.

    ``prefill_mode`` (chunked mode only): ``"batched"`` (default) advances
    EVERY prefilling slot one chunk per step in ONE fused dispatch (a slot
    mask, same compiled shape regardless of how many slots are prefilling);
    ``"per-job"`` is the legacy baseline — at most one job advances one
    chunk per step in its own dispatch, and prompts the backend's chunk
    program cannot start from scratch take the monolithic path.

    ``prefix_cache`` (chunked mode only): keep a radix cache of committed
    window-aligned prompt prefixes, keyed by token content.  An incoming
    prompt whose leading windows match a cached prefix attaches those
    pages by reference (ref-counted, read-only) plus the per-window
    summary rows the backend snapshotted when the prefix was first
    computed, and its chunked prefill skips straight to the first
    unshared chunk — TTFT collapses for shared-system-prompt traffic.
    Backends that do not store per-token context in pages have nothing to
    reuse and silently run cache-off.  Cached pages are reclaimed, LRU
    leaf first, before the scheduler resorts to preempting live work.

    ``spec_k`` > 0 enables LOSSLESS speculative decoding: each engine step
    becomes one draft/verify/commit round — the backend cheaply proposes up
    to ``spec_k`` tokens per slot (`draft_steps`), re-derives all of them
    plus one correction through its exact decode rule in one fused
    teacher-forced pass (`verify_step`), and the engine commits the longest
    draft prefix the verification reproduced plus the first corrected
    token, rewinding backend state past the commit point (`rollback`).
    Emitted streams are bit-identical to ``spec_k = 0`` at any temperature
    (verification samples with the same (rid, index)-derived keys), across
    preemption, cancellation, and the prefix cache.  Requires
    ``sample_device="fused"`` and a backend advertising
    ``supports_speculation``.  ``spec_mode`` selects the backend's drafting
    strategy ("auto" picks its native one: the paged MiTA backend drafts
    against the compressed landmark branch only; recurrent backends run
    their exact decode scan — also accepting "stress", the synthetic
    wrong-draft mode that exercises rollback)."""
    n_slots: int = 8                # decode batch width
    n_pages: int = 64               # shared pool size (pages of `window`)
    pages_per_slot: int = 8         # max context per request, in pages
    finalize: str = "external"      # external | inline (backend-specific)
    prefill_chunk: int = 0          # chunk length (0 = monolithic prefill)
    reserve_pages: int = 0          # appends-only page reserve
    sample_device: str = "host"     # host | fused (on-device sampling)
    prefill_mode: str = "batched"   # batched | per-job (chunk dispatch)
    prefix_cache: bool = False      # shared-prefix reuse (chunked only)
    spec_k: int = 0                 # speculative tokens/round (0 = off)
    spec_mode: str = "auto"         # backend drafting strategy


class _PageAllocator:
    """Ref-counted free-list over the shared pool.

    A page leaves the free list with one reference (`alloc`); additional
    holders `retain` it (prefix sharing: a cached prefix node and every
    slot reading it each hold one reference) and every holder `release`s
    it — the page returns to the free list only when the LAST reference
    drops.  Releasing a free or never-retained page, or the same page
    twice in one call, is a hard error: with shared pages a silent
    double-free would hand one holder's live page to a new owner, which is
    state corruption, not mis-accounting.

    ``reserve`` pages are invisible to ordinary allocations (admission,
    prefill chunks) and only served when ``reserved=True`` (decode appends)
    — the high-water mark and the dip counter quantify how close the pool
    came to starving the decode batch."""

    def __init__(self, n_pages: int, reserve: int = 0):
        self.n_pages = n_pages
        self.reserve = reserve
        self.free: list[int] = list(range(n_pages))
        self.refs: dict[int, int] = {}  # page id -> live reference count
        self.high_water = 0             # max pages ever in use
        self.reserve_dips = 0           # appends served from the reserve

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self.free)

    def refcount(self, page: int) -> int:
        return self.refs.get(page, 0)

    @property
    def shared_pages(self) -> int:
        """Pages currently held by more than one reference."""
        return sum(1 for c in self.refs.values() if c > 1)

    def can_alloc(self, n: int, reserved: bool = False) -> bool:
        avail = len(self.free) if reserved else len(self.free) - self.reserve
        return n <= avail

    def alloc(self, n: int, reserved: bool = False) -> list[int]:
        if not self.can_alloc(n, reserved):
            raise AllocatorInvariantError("page pool exhausted")
        if reserved and len(self.free) - n < self.reserve:
            self.reserve_dips += 1
        pages, self.free = self.free[:n], self.free[n:]
        for p in pages:
            self.refs[p] = 1
        self.high_water = max(self.high_water, self.in_use)
        return pages

    def retain(self, pages: list[int]) -> None:
        """Add one reference to each (already-allocated) page."""
        for p in pages:
            if self.refs.get(p, 0) < 1:
                raise AllocatorInvariantError(
                    f"retain of page {p} which is not allocated")
        for p in pages:
            self.refs[p] += 1

    def release(self, pages: list[int]) -> None:
        """Drop one reference per page; free pages whose count hits zero.

        Validates the whole batch before mutating anything, so a raising
        call never half-applies."""
        if len(set(pages)) != len(pages):
            raise AllocatorInvariantError(
                f"release with duplicate page ids: {sorted(pages)}")
        for p in pages:
            if self.refs.get(p, 0) < 1:
                raise AllocatorInvariantError(
                    f"double-free: page {p} has no live reference")
        for p in pages:
            self.refs[p] -= 1
            if self.refs[p] == 0:
                del self.refs[p]
                self.free.append(p)


@dataclasses.dataclass(eq=False)
class _WaitEntry:
    """Queue entry: (priority desc, submit order) defines admission order.
    ``resume`` holds (tokens, times, meta) for a preempted request awaiting
    its recompute-from-prompt re-admission; ``snapshot`` is the backend's
    opaque `preempt_snapshot` payload handed back at `slot_filled`;
    ``evictions`` counts every preemption the request has suffered
    (mid-prefill restarts included).  ``first_admit`` is the stamp of the
    FIRST admission — a preempted victim (mid-prefill ones included, which
    carry no ``resume``) must report its original admission time, not the
    re-admission's, or TTFT under-reports queueing delay for exactly the
    requests that suffered most."""
    req: Request
    seq: int
    resume: Optional[tuple] = None
    snapshot: Any = None
    evictions: int = 0
    first_admit: Optional[float] = None

    @property
    def key(self):
        return (-self.req.priority, self.seq)


@dataclasses.dataclass(eq=False)
class _PrefillJob:
    """A request mid-(chunked)-prefill: owns a slot and a growing page set,
    but is NOT in the decode batch until the last chunk lands."""
    entry: _WaitEntry
    toks: np.ndarray                # prompt [+ generated-so-far] to pack
    n_train: int                    # original prompt length (semantics)
    admit_time: float
    done: int = 0                   # tokens packed so far (next chunk's t0)


class ServingEngine:
    """Admit/evict requests each step; keep the fused decode batch full."""

    def __init__(self, params: Any, cfg: Any,
                 ecfg: EngineConfig = EngineConfig(),
                 sample_key: jax.Array | None = None,
                 backend: Optional[Any] = None):
        if ecfg.finalize not in ("external", "inline"):
            raise ValueError(f"unknown finalize mode {ecfg.finalize!r}")
        if ecfg.n_pages - ecfg.reserve_pages < ecfg.pages_per_slot:
            raise ValueError("pool minus reserve smaller than one slot's "
                             "max context — admission could deadlock")
        if ecfg.reserve_pages < 0:
            raise ValueError("reserve_pages must be >= 0")
        if ecfg.sample_device not in ("host", "fused"):
            raise ValueError(f"unknown sample_device {ecfg.sample_device!r}")
        if ecfg.prefill_mode not in ("batched", "per-job"):
            raise ValueError(f"unknown prefill_mode {ecfg.prefill_mode!r}")
        if ecfg.prefix_cache and not ecfg.prefill_chunk:
            raise ValueError("prefix_cache requires chunked prefill "
                             "(prefill_chunk > 0): cache hits resume the "
                             "chunk program at the first unshared chunk")
        if ecfg.spec_k < 0:
            raise ValueError("spec_k must be >= 0")
        self.backend = (backend if backend is not None
                        else _backends.resolve(params, cfg, ecfg))
        if ecfg.spec_k:
            if ecfg.sample_device != "fused":
                raise ValueError(
                    "speculative decoding samples inside the verify "
                    "program (spec_k > 0 requires sample_device='fused')")
            if not getattr(self.backend, "supports_speculation", False):
                raise ValueError(
                    f"the {self.backend.name!r} backend does not support "
                    "speculative decoding (spec_k > 0)")
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.w = self.backend.window
        if ecfg.prefill_chunk and (ecfg.prefill_chunk < 0
                                   or ecfg.prefill_chunk % self.w):
            raise ValueError("prefill_chunk must be a positive multiple of "
                             f"the backend window ({self.w})")
        self._key = (jax.random.PRNGKey(0) if sample_key is None
                     else sample_key)

        s, m = ecfg.n_slots, ecfg.pages_per_slot
        self.alloc = _PageAllocator(ecfg.n_pages, ecfg.reserve_pages)

        # host-owned scheduler state
        self.page_table = np.zeros((s, m), np.int32)
        self.t = np.zeros(s, np.int32)
        self.active = np.zeros(s, bool)
        self.tokens_in = np.zeros(s, np.int32)
        # per-slot sampling inputs for the fused on-device sampler
        self.slot_rid = np.zeros(s, np.int32)
        self.slot_temp = np.zeros(s, np.float32)
        self.sample_idx = np.zeros(s, np.int32)   # next token index per slot
        self.free_slots: list[int] = list(range(s))
        self.slot_req: dict[int, Request] = {}
        self.slot_entry: dict[int, _WaitEntry] = {}
        self.slot_pages: dict[int, list[int]] = {}
        self.slot_out: dict[int, list[int]] = {}
        self.slot_times: dict[int, list[float]] = {}
        self.slot_meta: dict[int, tuple[float, float]] = {}  # admitted, ttft
        self.slot_seq: dict[int, int] = {}    # admission recency (victims)
        self.slot_npre: dict[int, int] = {}   # preemptions suffered so far
        self.prefilling: dict[int, _PrefillJob] = {}
        self.waiting: list[_WaitEntry] = []   # sorted by _WaitEntry.key
        self.finished: list[FinishedRequest] = []
        self.steps = 0
        self.n_preemptions = 0
        self.n_chunks = 0
        self.prefill_dispatches = 0
        self.step_times: list[float] = []
        self._seq = 0
        self._inflight: set[int] = set()    # rids waiting or active

        # prefix cache (opt-in; silently off for backends with nothing
        # page-resident to reuse) + its counters, zero when disabled
        self.cache = None
        if ecfg.prefix_cache and getattr(self.backend,
                                         "supports_prefix_cache", False):
            from repro.serve.prefix_cache import RadixPrefixCache
            self.cache = RadixPrefixCache(self.alloc, self.w)
        self.n_prefix_hits = 0
        self.n_prefix_misses = 0
        self.n_pages_shared = 0           # pages attached by reference
        self.n_prefix_tokens_reused = 0   # prompt tokens never re-prefilled
        self.prefix_hits: dict[int, int] = {}  # rid -> tokens reused

        # speculative-decoding counters (zero when spec_k == 0)
        self.n_spec_drafted = 0           # draft tokens proposed
        self.n_spec_accepted = 0          # draft tokens verification kept
        self.n_spec_rollbacks = 0         # rounds that rejected a draft

        # robustness counters (serve/supervisor.py increments retries /
        # quarantined / degradation_level; rejections and deadline kills
        # are the engine's own admission-control outcomes)
        self.n_rejected = 0               # requests shed at submit
        self.n_deadline_expired = 0       # requests killed past their SLO
        self.n_retries = 0                # supervised step re-executions
        self.n_quarantined = 0            # slots evicted by fault isolation
        self.degradation_level = 0        # supervisor ladder rung (0 = full)
        self._deadline: dict[int, float] = {}  # rid -> absolute expiry
        self.reject_reasons: dict[int, str] = {}  # rid -> why it was shed

    # ------------------------------------------------------------ plumbing --

    def _sample(self, logits: np.ndarray, req: Request, index: int) -> int:
        # ONE host sampling rule shared with every backend's
        # static_reference (and bit-matched by the fused on-device
        # sampler) — the parity gates compare a single recipe
        return _backends.sample_host(logits, req.rid, index,
                                     req.temperature, self._key)

    def pages_needed(self, req: Request) -> int:
        return self.backend.pages_needed(len(req.prompt)
                                         + req.max_new_tokens)

    def warmup(self, prompt_lens: list[int]) -> None:
        """Compile every program the serving loop can hit for the given
        prompt lengths: the fused decode step, the chunk-prefill program
        variants (chunked mode: per-job has one; batched has one per
        power-of-two row width, exercised by submitting that many probes
        at once so they prefill concurrently), and each monolithic prefill
        variant.  Runs on one scratch engine so this engine's
        pool/scheduler state is untouched (compile caches are shared
        module-wide)."""
        scratch = ServingEngine(self.params, self.cfg, self.ecfg,
                                backend=self.backend.fresh())
        k_max = 1 if (self.ecfg.prefill_chunk
                      and self.ecfg.prefill_mode == "per-job") \
            else self.ecfg.n_slots
        if self.ecfg.prefill_chunk and self.ecfg.prefill_mode == "batched":
            # no compiled program depends on prompt length in batched
            # chunked mode (length and resume point are data) — one
            # representative length covers every width variant
            prompt_lens = [max(prompt_lens)] if prompt_lens else []
        for n in sorted(set(prompt_lens)):
            # probe requests claim the MINIMAL page budget a real request
            # of this length would (max_new=1), so warmup never rejects a
            # length the engine can actually serve
            gen = 2 if self.backend.pages_needed(n + 2) \
                <= self.ecfg.pages_per_slot else 1
            sizes = []
            k = 1
            while k <= k_max:
                sizes.append(k)
                k *= 2
            if sizes[-1] != k_max:
                # non-power-of-two slot counts cap the batched prefill row
                # width at k_max itself — compile that variant too
                sizes.append(k_max)
            for k in sizes:
                scratch.run([Request(rid=-1 - i, prompt=np.zeros(n, np.int32),
                                     max_new_tokens=gen) for i in range(k)])

    def stats(self) -> dict[str, Any]:
        """Scheduler counters: fused steps, prefill chunks run (per slot),
        prefill dispatches issued (batched mode: ≤ 1 per step regardless of
        how many slots are prefilling), preemptions, and the allocator's
        high-water / reserve accounting — merged with the backend's own
        counters (decode dispatches, kernel fallbacks)."""
        s = {"backend": self.backend.name,
             "steps": self.steps, "chunks": self.n_chunks,
             "prefill_dispatches": self.prefill_dispatches,
             "preemptions": self.n_preemptions,
             "pages_high_water": self.alloc.high_water,
             "reserve_dips": self.alloc.reserve_dips,
             "prefix_cache_hits": self.n_prefix_hits,
             "prefix_cache_misses": self.n_prefix_misses,
             "pages_shared": self.n_pages_shared,
             "prefix_tokens_reused": self.n_prefix_tokens_reused,
             "prefix_cache_pages": (self.cache.n_pages
                                    if self.cache is not None else 0),
             "prefix_cache_evictions": (self.cache.evictions
                                        if self.cache is not None else 0),
             "spec_drafted": self.n_spec_drafted,
             "spec_accepted": self.n_spec_accepted,
             "spec_rollbacks": self.n_spec_rollbacks,
             "rejected": self.n_rejected,
             "deadline_expired": self.n_deadline_expired,
             "retries": self.n_retries,
             "quarantined": self.n_quarantined,
             "degradation_level": self.degradation_level}
        s.update(self.backend.stats())
        return s

    # ----------------------------------------------------------- scheduler --

    def submit(self, req: Request) -> bool:
        """Queue a request, or shed it.  Returns True when queued.

        Malformed submissions (empty prompt, max_new < 1, a rid already in
        flight) are caller bugs and still raise ValueError.  Workload
        conditions the engine can never serve — prompt + max_new exceeding
        a slot's page budget, or a prompt length no prefill path can lower
        — are STRUCTURED BACKPRESSURE, not errors: the request is shed
        with a ``FinishedRequest(reason="rejected")`` (tokens empty, rid
        free for resubmission), ``n_rejected`` counts it, and False is
        returned.  Nothing downstream of a True return can reject: an
        admitted request can always finish (invariant 3)."""
        if len(req.prompt) < 1 or req.max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and ≥ 1 new token")
        if req.rid in self._inflight:
            raise ValueError(f"request id {req.rid} is already in flight")
        try:
            self._validate_servable(req)
        except ValueError as e:
            self._reject(req, str(e))
            return False
        self._inflight.add(req.rid)
        self._seq += 1
        self._enqueue(_WaitEntry(req=req, seq=self._seq))
        if req.deadline_ms is not None:
            self._deadline[req.rid] = (time.perf_counter()
                                       + req.deadline_ms / 1e3)
        return True

    def _validate_servable(self, req: Request) -> None:
        """Raise ValueError when no admission path can ever serve ``req``
        — before any scheduler state is touched."""
        if self.pages_needed(req) > self.ecfg.pages_per_slot:
            raise ValueError(
                f"request {req.rid} needs {self.pages_needed(req)} pages; a "
                f"slot owns {self.ecfg.pages_per_slot} "
                f"(max context {self.ecfg.pages_per_slot * self.w})")
        n = len(req.prompt)
        batched = self.ecfg.prefill_mode == "batched"
        if not self.ecfg.prefill_chunk:
            self.backend.validate_prompt(n, "monolithic")
        elif self.backend.chunkable(n, batched):
            self.backend.validate_prompt(n, "chunked")
        elif batched:
            # batched chunked mode has no monolithic route — shed now
            # rather than feed the chunk program a prompt the backend
            # said it cannot start (unreachable for the current backends,
            # which chunk everything in batched mode)
            raise ValueError(
                f"prompt length {n} is not servable: the "
                f"{self.backend.name} backend cannot start it through the "
                "batched chunk program (use prefill_mode='per-job' or "
                "monolithic prefill)")
        else:
            self.backend.validate_prompt(n, "monolithic")

    def _reject(self, req: Request, why: str) -> None:
        self.n_rejected += 1
        self.reject_reasons[req.rid] = why
        now = time.perf_counter()
        self.finished.append(FinishedRequest(
            rid=req.rid, tokens=np.zeros(0, np.int32), arrival=req.arrival,
            admitted=0.0, first_token=0.0, finished=now,
            reason="rejected"))

    def _enqueue(self, entry: _WaitEntry) -> None:
        bisect.insort(self.waiting, entry, key=lambda e: e.key)

    def _emit(self, slot: int, tok: int, now: float) -> None:
        self.slot_out[slot].append(tok)
        self.slot_times[slot].append(now)

    def _retire(self, slot: int, now: float, cancelled: bool = False,
                reason: Optional[str] = None) -> None:
        if reason is None:
            reason = "cancelled" if cancelled else "complete"
        req = self.slot_req.pop(slot)
        self.slot_entry.pop(slot)
        out = self.slot_out.pop(slot)
        times = self.slot_times.pop(slot)
        admitted, ttft = self.slot_meta.pop(slot)
        self.alloc.release(self.slot_pages.pop(slot))
        self.slot_seq.pop(slot)
        npre = self.slot_npre.pop(slot)
        self.active[slot] = False
        self.t[slot] = 0
        self.page_table[slot] = 0     # unused entries must stay in-bounds
        # a stale temperature would defeat the fused sampler's all-greedy
        # fast path (sample_tokens conds on "any slot tempered")
        self.slot_temp[slot] = 0.0
        self.free_slots.append(slot)
        self.backend.retire(slot)
        self.backend.invalidate()
        self._inflight.discard(req.rid)
        self.finished.append(FinishedRequest(
            rid=req.rid, tokens=np.asarray(out, np.int32),
            arrival=req.arrival, admitted=admitted, first_token=ttft,
            finished=now, token_times=times, preemptions=npre,
            cancelled=cancelled, reason=reason))

    def cancel(self, rid: int, reason: str = "cancelled") -> bool:
        """Kill an in-flight request in ANY state — waiting (fresh or
        preempted-awaiting-readmission), mid-chunked-prefill, or decoding —
        releasing its slot and page references immediately and emitting a
        ``cancelled`` FinishedRequest carrying whatever tokens were already
        out.  ``reason`` labels the kill ("cancelled", or
        "deadline_expired" when the engine's own SLO sweep fires it).
        Returns False if the rid is not in flight (already finished,
        never submitted, or cancelled twice)."""
        now = time.perf_counter()
        for entry in self.waiting:
            if entry.req.rid == rid:
                self.waiting.remove(entry)
                out, times, meta = entry.resume or \
                    ([], [], (entry.first_admit or 0.0, 0.0))
                self._inflight.discard(rid)
                self.finished.append(FinishedRequest(
                    rid=rid, tokens=np.asarray(out, np.int32),
                    arrival=entry.req.arrival, admitted=meta[0],
                    first_token=meta[1], finished=now,
                    token_times=list(times), preemptions=entry.evictions,
                    cancelled=True, reason=reason))
                return True
        for slot, job in self.prefilling.items():
            if job.entry.req.rid != rid:
                continue
            entry = job.entry
            del self.prefilling[slot]
            self.alloc.release(self.slot_pages.pop(slot))
            self.slot_seq.pop(slot)
            self.page_table[slot] = 0
            self.free_slots.append(slot)
            self.backend.retire(slot)
            self.backend.invalidate()
            self._inflight.discard(rid)
            out, times, meta = entry.resume or \
                ([], [], (job.admit_time, 0.0))
            self.finished.append(FinishedRequest(
                rid=rid, tokens=np.asarray(out, np.int32),
                arrival=entry.req.arrival, admitted=meta[0],
                first_token=meta[1], finished=now, token_times=list(times),
                preemptions=entry.evictions, cancelled=True, reason=reason))
            return True
        for slot, req in self.slot_req.items():
            if req.rid == rid:
                self._retire(slot, now, cancelled=True, reason=reason)
                return True
        return False

    def _expire_deadlines(self) -> None:
        """Cancel every in-flight request whose ``deadline_ms`` SLO has
        passed, with the ``deadline_expired`` finish reason — the kill
        rides the ordinary `cancel` path, so slot and page release follow
        the exact lifecycle cancellation already pins."""
        if not self._deadline:
            return
        now = time.perf_counter()
        for rid, expiry in list(self._deadline.items()):
            if rid not in self._inflight:
                del self._deadline[rid]
            elif now >= expiry:
                del self._deadline[rid]
                if self.cancel(rid, reason="deadline_expired"):
                    self.n_deadline_expired += 1

    # ---------------------------------------------------------- preemption --

    def _pick_victim(self, below: Optional[int] = None) -> Optional[int]:
        """Lowest-priority occupied slot; ties broken toward the most
        recently admitted (its recompute loses the least work).  ``below``
        restricts candidates to strictly lower priorities (admission-side
        preemption never thrashes equals)."""
        cands = [(job.entry.req.priority, self.slot_seq[s], s)
                 for s, job in self.prefilling.items()]
        cands += [(req.priority, self.slot_seq[s], s)
                  for s, req in self.slot_req.items()]
        if below is not None:
            cands = [c for c in cands if c[0] < below]
        if not cands:
            return None
        cands.sort(key=lambda c: (c[0], -c[1]))
        return cands[0][2]

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``: release its pages and requeue its request.  A
        decoding victim keeps its emitted tokens/stamps (plus the backend's
        snapshot) and is rebuilt by recompute-from-prompt; a prefilling
        victim simply restarts (it has emitted nothing)."""
        self.n_preemptions += 1
        self.alloc.release(self.slot_pages.pop(slot))
        self.page_table[slot] = 0
        self.slot_seq.pop(slot)
        job = self.prefilling.pop(slot, None)
        if job is not None:
            entry = job.entry      # mid-prefill: restart, nothing emitted
        else:
            entry = self.slot_entry.pop(slot)
            self.slot_req.pop(slot)
            out = self.slot_out.pop(slot)
            times = self.slot_times.pop(slot)
            meta = self.slot_meta.pop(slot)
            self.slot_npre.pop(slot)
            entry.resume = (out, times, meta)
            entry.snapshot = self.backend.preempt_snapshot(slot)
            self.active[slot] = False
            self.t[slot] = 0
            self.slot_temp[slot] = 0.0
            self.backend.invalidate()
        entry.evictions += 1
        self.free_slots.append(slot)
        self._enqueue(entry)

    def _reclaim_cache(self, pages: int, reserved: bool = False) -> None:
        """Drop cached prefix nodes (LRU leaf first) until ``pages`` are
        allocatable or the cache is empty.  Runs BEFORE any preemption
        path considers live victims: cached pages are spare capacity, and
        a cache-only reference is always cheaper to sacrifice than a
        running request's recompute."""
        if self.cache is None:
            return
        while (not self.alloc.can_alloc(pages, reserved)
               and self.cache.evict_one()):
            pass

    def _preempt_for(self, priority: int, pages: int,
                     need_slot: bool = False) -> None:
        """Evict strictly-lower-priority victims until ``pages`` are
        allocatable (and a slot is free, if requested) or none remain.
        Cached prefix pages are reclaimed before any victim is touched."""
        self._reclaim_cache(pages)
        while ((need_slot and not self.free_slots)
               or not self.alloc.can_alloc(pages)):
            victim = self._pick_victim(below=priority)
            if victim is None:
                return
            self._preempt(victim)
            self._reclaim_cache(pages)

    # ----------------------------------------------------------- admission --

    def _admit(self, now: float) -> None:
        if self.ecfg.prefill_chunk:
            self._admit_chunked(now)
        else:
            self._admit_grouped(now)

    def _entry_total(self, entry: _WaitEntry) -> int:
        """Tokens the prefill of this entry must pack: the prompt, plus
        (for a preempted victim's recompute) everything it had emitted
        short of the last token, which re-enters through decode."""
        n_train = len(entry.req.prompt)
        return n_train if entry.resume is None \
            else n_train + len(entry.resume[0]) - 1

    def _match_prefix(self, entry: _WaitEntry) -> list:
        """Radix-cache nodes whose pages this entry can attach: longest
        cached prefix of the prompt, quantized DOWN to a prefill-chunk
        boundary.  Chunk quantization is what makes cache hits bit-exact
        against a cold run: every remaining chunk then covers the same
        [t0, t0+nv) span the cold engine's schedule would, so the float
        reduction order of every summary-row sum and mixing output is
        identical.  Only fully window-aligned prompt prefixes are cached
        at all (see `_finish_prefill`), and at least one token is always
        left to prefill — the final chunk's logits seed sampling."""
        if self.cache is None:
            return []
        n_train = len(entry.req.prompt)
        if n_train % self.w:
            # only window-aligned prompts share summary rows: a prompt
            # whose length is not a multiple of the window trains its
            # summaries on a different (n//m-derived) grid, so cached
            # w-aligned rows would be wrong for it
            return []
        if self.ecfg.prefill_mode == "per-job" \
                and not self.backend.chunkable(n_train, batched=False):
            return []               # monolithic path packs from zero
        limit = min(n_train, self._entry_total(entry) - 1) // self.w
        if limit <= 0:
            return []
        nodes = self.cache.match(entry.req.prompt, limit)
        chunk_w = self.ecfg.prefill_chunk // self.w
        return nodes[: (len(nodes) // chunk_w) * chunk_w]

    def _first_chunk_pages(self, entry: _WaitEntry,
                           shared_pages: int = 0) -> int:
        """NEW pages the first prefill dispatch of this request needs
        beyond ``shared_pages`` attached from the prefix cache: one
        chunk's worth — or the whole (window-aligned) prompt when the
        backend's chunk program cannot start this prompt in per-job mode
        and it must go through the monolithic path."""
        n_train = len(entry.req.prompt)
        if self.ecfg.prefill_mode == "per-job" \
                and not self.backend.chunkable(n_train, batched=False):
            return self.backend.pages_needed(n_train)
        t0 = shared_pages * self.w
        first = min(self.ecfg.prefill_chunk, self._entry_total(entry) - t0)
        return self.backend.pages_needed(t0 + first) - shared_pages

    def _admit_chunked(self, now: float) -> None:
        """Chunked admission: one request at a time, first-chunk pages only.
        A higher-priority arrival preempts the lowest strictly-lower victim
        when slots or pages run short (invariant 2 becomes priority-ordered
        head-of-line blocking).  With the prefix cache on, the prompt is
        matched against the radix tree first: matched pages attach by
        reference (one retained ref per page), the backend installs the
        cached per-window summary rows, and the prefill job starts at the
        first unshared chunk instead of zero."""
        while self.waiting:
            entry = self.waiting[0]
            nodes = self._match_prefix(entry)
            first = self._first_chunk_pages(entry, len(nodes))
            if not self.free_slots or not self.alloc.can_alloc(first):
                self._preempt_for(entry.req.priority, first, need_slot=True)
                # pressure relief may have evicted matched cache nodes —
                # re-match before attaching anything
                nodes = self._match_prefix(entry)
                first = self._first_chunk_pages(entry, len(nodes))
                if not self.free_slots or not self.alloc.can_alloc(first):
                    return
            self.waiting.pop(0)
            slot = self.free_slots.pop()
            if entry.resume is None:
                toks = np.asarray(entry.req.prompt, np.int32)
            else:
                out = entry.resume[0]
                toks = np.concatenate([
                    np.asarray(entry.req.prompt, np.int32),
                    np.asarray(out[:-1], np.int32)])
            if entry.first_admit is None:
                entry.first_admit = now
            shared = len(nodes) * self.w
            self.prefilling[slot] = _PrefillJob(
                entry=entry, toks=toks, n_train=len(entry.req.prompt),
                admit_time=entry.first_admit, done=shared)
            self.backend.alloc_slot(slot)
            shared_pages = [nd.page for nd in nodes]
            if shared_pages:
                # attach by reference: the slot becomes one more holder of
                # each page; the cached summary rows make the backend's
                # state look exactly as if it had prefilled those windows
                self.alloc.retain(shared_pages)
                self.backend.attach_prefix(
                    slot, [nd.payload for nd in nodes])
                self.n_prefix_hits += 1
                self.n_pages_shared += len(shared_pages)
                self.n_prefix_tokens_reused += shared
                self.prefix_hits[entry.req.rid] = shared
            elif self.cache is not None:
                self.n_prefix_misses += 1
                self.prefix_hits.setdefault(entry.req.rid, 0)
            # claim the first dispatch's pages NOW so concurrent admissions
            # never overcommit the same free pages
            pages = shared_pages + self.alloc.alloc(first)
            self.slot_pages[slot] = pages
            self.page_table[slot] = 0
            self.page_table[slot, : len(pages)] = pages
            self.backend.invalidate()
            self._seq += 1
            self.slot_seq[slot] = self._seq

    def _admit_grouped(self, now: float) -> None:
        """Monolithic admission (``prefill_chunk`` = 0): priority-then-FCFS
        with same-length grouping — the head-of-line request picks the
        prompt length; other waiting requests of that length ride along in
        ONE fused prefill+pack dispatch (prefill rows are independent, so
        grouping never changes a request's tokens).  Head-of-line blocking
        on pages is deliberate — big requests are not starved by later
        small ones.  The full page budget is claimed up front (invariant
        3), so this path never needs preemption."""
        while self.waiting and self.free_slots:
            head = self.waiting[0].req
            if not self.alloc.can_alloc(self.pages_needed(head)):
                return
            n = len(head.prompt)
            budget = (len(self.alloc.free) - self.alloc.reserve
                      - self.pages_needed(head))
            group = [self.waiting[0]]
            for e in self.waiting[1:]:
                if len(group) >= len(self.free_slots):
                    break
                if len(e.req.prompt) == n and self.pages_needed(e.req) <= budget:
                    group.append(e)
                    budget -= self.pages_needed(e.req)
            # power-of-two chunks: bounds the (length, group-size) compile
            # variants to log2(slots) per prompt length (see `warmup`);
            # the remainder is admitted by the next loop iteration
            group = group[: 1 << (len(group).bit_length() - 1)]
            for e in group:
                self.waiting.remove(e)
            slots = [self.free_slots.pop() for _ in group]
            pages_list = [self.alloc.alloc(self.pages_needed(e.req))
                          for e in group]
            for slot in slots:
                self.backend.alloc_slot(slot)

            try:
                logits = self.backend.prefill_group(
                    np.stack([e.req.prompt for e in group]).astype(np.int32),
                    slots, pages_list)
            except Exception:
                # fault-atomic admission: at this point the group's pages
                # and slots are claimed but not yet recorded in slot_pages
                # / slot_req — a raising backend would leak them all.
                # Unwind to the pre-admission state (entries back in the
                # queue, pages freed, slots returned) and re-raise so the
                # supervisor can retry the whole step.
                for slot, pages in zip(slots, pages_list):
                    self.alloc.release(pages)
                    self.free_slots.append(slot)
                    self.backend.retire(slot)
                self.backend.invalidate()
                for e in group:
                    self._enqueue(e)
                raise

            for i, (entry, slot, pages) in enumerate(
                    zip(group, slots, pages_list)):
                req = entry.req
                self.slot_req[slot] = req
                self.slot_entry[slot] = entry
                self.slot_pages[slot] = pages
                self.slot_out[slot] = []
                self.slot_times[slot] = []
                self.slot_npre[slot] = 0
                self._seq += 1
                self.slot_seq[slot] = self._seq
                self.page_table[slot] = 0
                self.page_table[slot, : len(pages)] = pages
                self.t[slot] = n
                self.active[slot] = True
                self.slot_rid[slot] = req.rid
                self.slot_temp[slot] = req.temperature
                self.backend.slot_filled(slot, n)
                first = self._sample(logits[i], req, 0)
                self.sample_idx[slot] = 1
                self.slot_meta[slot] = (now, time.perf_counter())
                self._emit(slot, first, time.perf_counter())
                self.tokens_in[slot] = first
                if req.max_new_tokens == 1:
                    self._retire(slot, time.perf_counter())
            self.backend.invalidate()

    # ------------------------------------------------------ chunked prefill --

    def _grow_pages(self, slot: int, target: int) -> bool:
        """Grow ``slot`` to ``target`` pages for the next prefill dispatch.

        On pressure, pages flow toward the best-keyed admitted work: the
        globally worst occupant — lowest priority, then most recently
        admitted (FCFS within a class) — is evicted until the allocation
        fits.  The worst occupant is never better-keyed than this job (the
        job is itself a candidate), so higher-priority and more-senior work
        is never disturbed; if this job IS the pool's worst occupant while
        others wait on it, it yields (self-preempt).  The strict total
        order (priority, admission seq) is what rules out livelock between
        equal-priority jobs."""
        delta = target - len(self.slot_pages[slot])
        if delta <= 0:
            return True
        self._reclaim_cache(delta)
        while not self.alloc.can_alloc(delta):
            victim = self._pick_victim()
            if victim is None or victim == slot:
                break
            self._preempt(victim)
            self._reclaim_cache(delta)
        if not self.alloc.can_alloc(delta):
            occupied = len(self.prefilling) + len(self.slot_req)
            if occupied > 1 and self._pick_victim() == slot:
                self._preempt(slot)
            return False
        pages = self.alloc.alloc(delta)
        base = len(self.slot_pages[slot])
        for i, p in enumerate(pages):
            self.page_table[slot, base + i] = p
        self.slot_pages[slot].extend(pages)
        self.backend.invalidate()
        return True

    def _advance_prefill(self, now: float) -> None:
        """Advance prefilling jobs: ONE fused dispatch per engine step.

        Batched mode (default): every prefilling slot that can grow its
        pages advances one chunk in a single `prefill_chunks` dispatch
        over a slot mask.  Per-job mode (the legacy baseline): only the
        best-keyed job advances, in its own dispatch."""
        if not self.prefilling:
            return
        if self.ecfg.prefill_mode == "batched":
            self._advance_prefill_batched(now)
        else:
            self._advance_prefill_per_job(now)

    def _advance_prefill_batched(self, now: float) -> None:
        """One dispatch advances EVERY prefilling job one chunk.  Jobs that
        cannot claim their next pages are masked out of the dispatch (and
        may have been self-preempted by `_grow_pages`), not serialized.
        Page growth runs best-key-first, so the victim order of `_grow
        _pages` (globally worst key first) can never evict a job already
        approved this step."""
        chunk = self.ecfg.prefill_chunk
        advancing: list[tuple[int, _PrefillJob, int]] = []
        for slot, job in sorted(self.prefilling.items(),
                                key=lambda kv: kv[1].entry.key):
            if self.prefilling.get(slot) is not job:
                continue              # evicted while an earlier job grew
            t0 = job.done
            nv = min(chunk, len(job.toks) - t0)
            target = self.backend.pages_needed(t0 + nv)
            if not self._grow_pages(slot, target):
                continue
            if self.prefilling.get(slot) is job:
                advancing.append((slot, job, nv))
        if not advancing:
            return
        # rows are jobs, packed to a power-of-two width so compute scales
        # with the number of prefilling requests (log2(slots)+1 compiled
        # variants — the monolithic admission-grouping bound).  Padding
        # rows borrow DISTINCT idle slot ids (inactive rows write their
        # slot's state back bit-identically), so the state scatter never
        # sees duplicate indices.
        p_w = 1 << (len(advancing) - 1).bit_length() if advancing else 1
        p_w = min(p_w, self.ecfg.n_slots)
        used = {s for s, _, _ in advancing}
        pads = [s for s in range(self.ecfg.n_slots) if s not in used]
        slot_ids = [s for s, _, _ in advancing] + pads[: p_w - len(advancing)]
        toks = np.zeros((p_w, chunk), np.int32)
        job_active = np.zeros(p_w, bool)
        t0s = np.zeros(p_w, np.int32)
        nvs = np.zeros(p_w, np.int32)
        ntr = np.ones(p_w, np.int32)
        for i, (slot, job, nv) in enumerate(advancing):
            toks[i, :nv] = job.toks[job.done:job.done + nv]
            job_active[i] = True
            t0s[i] = job.done
            nvs[i] = nv
            ntr[i] = job.n_train
        logits = self.backend.prefill_chunks(
            slot_ids, toks, job_active, self.page_table[slot_ids],
            t0s, nvs, ntr)
        self.n_chunks += len(advancing)
        self.prefill_dispatches += 1
        for i, (slot, job, nv) in enumerate(advancing):
            job.done += nv
            if job.done == len(job.toks):
                self._finish_prefill(slot, job, logits[i], now)

    def _advance_prefill_per_job(self, now: float) -> None:
        """Run ONE prefill dispatch (a chunk, or the monolithic path for a
        prompt the chunk program cannot start) for the best prefilling job
        — bounding per-step added latency to one chunk regardless of
        prompt length."""
        slot, job = min(self.prefilling.items(),
                        key=lambda kv: kv[1].entry.key)
        n_total = len(job.toks)
        if job.done == 0 and not self.backend.chunkable(job.n_train,
                                                        batched=False):
            # monolithic path: the program this prompt length would have
            # used unchunked (see docs/serving.md)
            n = job.n_train
            if not self._grow_pages(slot, self.backend.pages_needed(n)):
                return
            logits = self.backend.prefill_group(
                job.toks[None, :n].astype(np.int32), [slot],
                [self.slot_pages[slot]])
            job.done = n
            self.prefill_dispatches += 1
            if job.done == n_total:
                self._finish_prefill(slot, job, logits[0], now)
            return
        chunk = self.ecfg.prefill_chunk
        t0 = job.done
        nv = min(chunk, n_total - t0)
        if not self._grow_pages(slot, self.backend.pages_needed(t0 + nv)):
            return
        toks = np.zeros(chunk, np.int32)
        toks[:nv] = job.toks[t0:t0 + nv]
        logits = self.backend.prefill_chunk(
            slot, self.page_table[slot], toks, t0, nv, job.n_train)
        self.n_chunks += 1
        self.prefill_dispatches += 1
        job.done = t0 + nv
        if job.done == n_total:
            self._finish_prefill(slot, job, logits, now)

    def _finish_prefill(self, slot: int, job: _PrefillJob,
                        logits: np.ndarray, now: float) -> None:
        """Last chunk landed: move the slot into the decode batch.  Fresh
        requests sample their first token from the final chunk's logits;
        resumed (preempted) requests restore their emitted tokens and
        continue decoding from where they were evicted."""
        entry = job.entry
        req = entry.req
        del self.prefilling[slot]
        n_total = len(job.toks)
        self.slot_req[slot] = req
        self.slot_entry[slot] = entry
        self.t[slot] = n_total
        self.active[slot] = True
        self.backend.slot_filled(slot, n_total, snapshot=entry.snapshot)
        entry.snapshot = None
        self.backend.invalidate()
        if self.cache is not None and job.n_train % self.w == 0:
            # commit this prompt's windows to the radix cache: each new
            # node retains one reference on its page; the snapshot of the
            # per-window summary rows is taken lazily (only if the walk
            # actually adds nodes).  Shared-then-extended prompts deepen
            # an existing path; physically-diverging duplicates add
            # nothing (a node's rows must only reference pages on its own
            # root-anchored path)
            m = job.n_train // self.w
            self.cache.insert(
                job.toks, m, self.slot_pages[slot][:m],
                lambda: self.backend.prefix_snapshot(slot, m))
        self.slot_npre[slot] = entry.evictions
        self.slot_rid[slot] = req.rid
        self.slot_temp[slot] = req.temperature
        if entry.resume is None:
            self.slot_out[slot] = []
            self.slot_times[slot] = []
            first = self._sample(logits, req, 0)
            self.sample_idx[slot] = 1
            self.slot_meta[slot] = (job.admit_time, time.perf_counter())
            self._emit(slot, first, time.perf_counter())
            self.tokens_in[slot] = first
            if req.max_new_tokens == 1:
                self._retire(slot, time.perf_counter())
        else:
            out, times, meta = entry.resume
            entry.resume = None
            self.slot_out[slot] = list(out)
            self.slot_times[slot] = list(times)
            self.slot_meta[slot] = meta
            self.sample_idx[slot] = len(out)
            self.tokens_in[slot] = out[-1]

    def _ensure_append_pages(self) -> None:
        """Guarantee every active slot owns the page its next append lands
        in (invariant 3 in incremental form).  Appends may dip into the
        reserve; if the pool is truly dry the lowest-priority slot is
        preempted — possibly the appender itself, whose pages then fund the
        survivors."""
        for slot in np.nonzero(self.active)[0]:
            slot = int(slot)
            # one speculative round can commit up to spec_k + 1 tokens, so
            # a slot's position may have crossed SEVERAL page boundaries
            # since the last pass — grow page by page until covered
            # (non-speculative decode advances by one token and takes at
            # most one iteration, exactly the old behavior)
            while (self.active[slot]
                   and int(self.t[slot]) // self.w
                   >= len(self.slot_pages[slot])):
                need_idx = len(self.slot_pages[slot])
                self._reclaim_cache(1, reserved=True)
                while not self.alloc.can_alloc(1, reserved=True):
                    victim = self._pick_victim()
                    if victim is None:
                        break
                    self._preempt(victim)
                    self._reclaim_cache(1, reserved=True)
                    if victim == slot:
                        break
                if not self.active[slot]:
                    break             # preempted as a victim this pass
                page = self.alloc.alloc(1, reserved=True)[0]
                # a decode append writes the page in place (the fused
                # step's aliased scatter), so its target must never be
                # shared: fresh allocations carry exactly one reference,
                # and append pages are never inserted into the prefix
                # cache (inserts cover prompt windows only, which precede
                # every append index)
                assert self.alloc.refcount(page) == 1
                self.slot_pages[slot].append(page)
                self.page_table[slot, need_idx] = page
                self.backend.invalidate()

    # ---------------------------------------------------- speculative round --

    def _spec_round(self, now: float) -> None:
        """One draft/verify/commit round for the whole active batch.

        Per-slot draft length = min(spec_k, remaining - 1, the backend's
        draft horizon), floored at 0 — a zero-length slot still runs verify
        position 0 and commits one token, so every request retires at
        exactly the step count the non-speculative engine would reach.
        The commit rule is the lossless one: keep the longest draft prefix
        the exact decode rule reproduced token-for-token, plus its first
        correction; rejected suffix state is rewound by the backend."""
        k = self.ecfg.spec_k
        act = [int(s) for s in np.nonzero(self.active)[0]]
        remaining = np.zeros_like(self.t)
        for slot in act:
            remaining[slot] = (self.slot_req[slot].max_new_tokens
                               - len(self.slot_out[slot]))
        horizon = np.asarray(self.backend.draft_horizon(self.t))
        spec_len = np.where(
            self.active,
            np.minimum(np.minimum(k, remaining - 1), horizon),
            0).astype(np.int32)
        spec_len = np.maximum(spec_len, 0)

        drafts = self.backend.draft_steps(
            self.tokens_in, self.t, self.active, self.page_table,
            self.slot_rid, self.slot_temp, self.sample_idx, self._key,
            spec_len)
        verify = self.backend.verify_step(
            self.tokens_in, self.t, self.active, self.page_table,
            self.slot_rid, self.slot_temp, self.sample_idx, self._key,
            spec_len, drafts)

        commits = np.ones(len(self.t), np.int32)
        for slot in act:
            sl = int(spec_len[slot])
            j = 0
            while j < sl and drafts[j, slot] == verify[j, slot]:
                j += 1
            commits[slot] = j + 1
            self.n_spec_drafted += sl
            self.n_spec_accepted += j
            self.n_spec_rollbacks += int(j < sl)
        self.backend.rollback(commits, self.active)

        for slot in act:
            req = self.slot_req[slot]
            c = int(commits[slot])
            for i in range(c):
                self._emit(slot, int(verify[i, slot]), now)
            self.t[slot] += c
            self.sample_idx[slot] += c
            self.tokens_in[slot] = int(verify[c - 1, slot])
            if len(self.slot_out[slot]) >= req.max_new_tokens:
                self._retire(slot, now)
        # scheduler tensors moved by per-slot amounts: device mirrors are
        # stale no matter what (retire already invalidates, but a round
        # with no retirement must too)
        self.backend.invalidate()

    # ---------------------------------------------------------------- step --

    def step(self) -> bool:
        """One engine iteration: retire/admit, advance at most one prefill
        chunk, then one fused decode step — or, with ``spec_k`` > 0, one
        speculative draft/verify/commit round — for the active batch.
        Returns False when there is nothing left to do."""
        self._expire_deadlines()
        now = time.perf_counter()
        self._admit(now)
        self._advance_prefill(now)
        if self.ecfg.prefill_chunk:
            self._ensure_append_pages()
        if not self.active.any():
            return bool(self.waiting or self.prefilling)

        if self.ecfg.spec_k:
            t0 = time.perf_counter()
            self._spec_round(time.perf_counter())
            self.step_times.append(time.perf_counter() - t0)
            self.steps += 1
            return True

        fused_sampling = self.ecfg.sample_device == "fused"
        t0 = time.perf_counter()
        # fused sampling downloads [S] int32 tokens; the host path the
        # whole [S, V] logits (docs/serving.md, host-transfer budget)
        out = self.backend.decode_step(
            self.tokens_in, self.t, self.active, self.page_table,
            self.slot_rid, self.slot_temp, self.sample_idx, self._key)
        self.step_times.append(time.perf_counter() - t0)
        self.steps += 1

        now = time.perf_counter()
        for slot in np.nonzero(self.active)[0]:
            req = self.slot_req[slot]
            if fused_sampling:
                tok = int(out[slot])
            else:
                tok = self._sample(out[slot], req, len(self.slot_out[slot]))
            self._emit(slot, tok, now)
            self.t[slot] += 1
            self.sample_idx[slot] += 1
            self.tokens_in[slot] = tok
            if len(self.slot_out[slot]) >= req.max_new_tokens:
                self._retire(slot, now)
        return True

    def run(self, requests: list[Request],
            realtime: bool = False) -> list[FinishedRequest]:
        """Drive a whole trace, returning the requests finished during THIS
        call (an engine can serve many traces back-to-back).
        ``realtime=True`` honours arrival offsets on the wall clock
        (Poisson traces); otherwise all requests queue up front
        (max-throughput mode)."""
        pending = sorted(requests, key=lambda r: r.arrival)
        start = time.perf_counter()
        already_done = len(self.finished)
        idx = 0
        while (idx < len(pending) or self.waiting or self.prefilling
               or self.active.any()):
            now = time.perf_counter() - start
            while idx < len(pending) and (
                    not realtime or pending[idx].arrival <= now):
                self.submit(pending[idx])
                idx += 1
            progressed = self.step()
            if not progressed and idx < len(pending):
                if realtime:
                    time.sleep(max(0.0,
                                   pending[idx].arrival
                                   - (time.perf_counter() - start)))
        return sorted(self.finished[already_done:], key=lambda f: f.rid)
