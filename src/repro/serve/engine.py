"""Continuous-batching engine over the paged MiTA decode cache.

The scheduler is plain host Python; everything device-side is one of three
jitted programs (docs/serving.md has the page layout, the request-lifecycle
state machine, and the full program inventory):

  * ``prefill+pack`` — `lm_prefill` over an admission group (same-length
    waiting requests, power-of-two sizes) packed straight into the slots'
    pages; compiled per (window-aligned prompt capacity, group size);
    monolithic mode (``prefill_chunk = 0``) only;
  * ``batched chunk prefill`` — `lm_prefill_chunks`: ONE program per
    configured chunk length that advances EVERY currently-prefilling
    slot's chunk in a single dispatch per engine step (which slots
    advance, chunk index, resume point, and validity are data — the
    compiled shape is independent of how many requests are mid-prefill).
    Enabled by ``EngineConfig.prefill_chunk``; long prompts then admit
    incrementally, interleaved with the decode batch, instead of stalling
    it.  Non-window-aligned prompts ride the same program (the monolithic
    head's n//m landmark quirk is per-slot data).  Inside, the chunk
    dispatches between the fused Pallas chunk-prefill kernel and the XLA
    path (`kernels.ops.use_prefill_kernel`).
    ``EngineConfig.prefill_mode = "per-job"`` keeps the PR-2 baseline
    (`lm_prefill_chunk`, one job per step, monolithic non-aligned head);
  * ``decode``       — `lm_paged_decode_step`, ONE program for the whole
    slot batch regardless of per-request progress (per-slot positions, page
    tables, and activity are data, not shape).  The window-boundary
    landmark finalize is fused behind a scalar `lax.cond`, the per-slot
    position/finalize/sampling counters advance on device, and with
    ``EngineConfig.sample_device == "fused"`` sampling runs inside the
    program too — the hot loop then uploads and downloads [S] int32
    tokens instead of downloading [S, V] logits (docs/serving.md has the
    transfer budget).  Inside the program, the paged attention dispatches
    between the fused Pallas kernel and the XLA gather path
    (`kernels.ops.use_paged_kernel`).

Chunked mode also enables priority preemption: under page pressure the
scheduler evicts the lowest-priority victim (releasing its pages) and later
rebuilds it by chunk-prefilling prompt + generated-so-far — recompute-from-
prompt, vLLM-style.  A preempted request emits the same greedy tokens it
would have emitted unpreempted (`tests/test_serve_chunked.py` pins this).

Greedy sampling is exact w.r.t. the static `launch.serve` path: a request
decoded by the engine emits the same tokens it would emit in a fixed batch
(`tests/test_serve.py` pins this).  Temperature sampling derives its key
from (request id, token index) so results are batching-invariant too.
"""

from __future__ import annotations

import bisect
import dataclasses
import functools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mita_decode as mdec
from repro.models import transformer as tfm
from repro.models.modules import ModelConfig


@functools.lru_cache(maxsize=None)
def _decode_fn(cfg: ModelConfig, fused_finalize: bool,
               fused_sampling: bool) -> Callable:
    """Fused whole-batch decode step, cached at module level so every
    engine instance with the same model config shares compiled code.

    Scheduler tensors (t, m_done, sample index) advance ON DEVICE: the hot
    loop uploads only the fed-back tokens — page tables, activity,
    positions, and per-request (rid, temperature) are re-uploaded solely
    when admission/retire changes them.  With ``fused_sampling`` the step
    also samples inside the program (`tfm.sample_tokens`) and returns [S]
    int32 tokens; otherwise it returns the [S, V] logits for the host
    sampler."""
    w = cfg.attn.window

    def step(p, st, tok, t, m_done, pt, ac, rid, si, temp, key):
        due = None
        if fused_finalize:
            due = ac & (t % w == 0) & (t // w > m_done)
            m_done = jnp.where(due, t // w, m_done)
        sample = (rid, si, temp, key) if fused_sampling else None
        out, st = tfm.lm_paged_decode_step(p, st, tok, t, pt, ac, cfg,
                                           due=due, sample=sample)
        adv = ac.astype(t.dtype)
        return out, st, t + adv, m_done, si + adv

    return jax.jit(step, donate_argnums=(1, 3, 4, 8))


@functools.lru_cache(maxsize=None)
def _prefill_pack_fn(cfg: ModelConfig, cap: int, k: int) -> Callable:
    """Fused batched prefill + pack-into-slots: one dispatch admits ``k``
    same-length requests (compiled per window-aligned capacity and group
    size).  Prefill rows are independent, so batching admissions does not
    change any request's tokens."""

    def prefill_pack(p, st, toks, slots, pages):
        logits, pre = tfm.lm_prefill(p, toks, cfg, cap)
        for i in range(k):
            pre_i = jax.tree.map(
                lambda a: a[:, i:i + 1] if a.ndim >= 2 else a, pre)
            st = tfm.pack_prefill_into_states(st, pre_i, slots[i], pages[i],
                                              cfg)
        return logits, st

    return jax.jit(prefill_pack, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _chunk_prefill_fn(cfg: ModelConfig, chunk: int, m_slot: int) -> Callable:
    """Per-job chunked prefill program (``prefill_mode="per-job"``): ONE
    compiled shape per (chunk length, pages-per-slot) serves every chunk of
    every request — resume point, validity, and the training/decode
    semantics boundary are data."""

    def run(p, st, toks, slot, pt_row, t0, n_valid, n_train):
        return tfm.lm_prefill_chunk(p, st, toks, slot, pt_row, t0, n_valid,
                                    n_train, cfg)

    return jax.jit(run, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _batched_chunk_prefill_fn(cfg: ModelConfig, chunk: int,
                              m_slot: int) -> Callable:
    """Batched chunked prefill program (``prefill_mode="batched"``, the
    default): EVERY currently-prefilling slot advances one chunk in ONE
    dispatch — which slots advance, their resume points, and validity are
    data, so the engine issues exactly one prefill dispatch per step no
    matter how many requests are mid-prefill.  Rows are packed to power-
    of-two widths (compute scales with the number of prefilling jobs;
    ≤ log₂(slots)+1 compiled variants, the same bound as monolithic
    admission grouping).  Non-aligned prompts ride the same program (the
    n//m landmark quirk is per-slot data;
    `core.mita_decode.mita_batched_chunk_prefill`), so no monolithic
    prefill head remains in chunked mode."""

    def run(p, st, toks, job_active, pt, slots, t0, n_valid, n_train):
        return tfm.lm_prefill_chunks(p, st, toks, job_active, pt, slots,
                                     t0, n_valid, n_train, cfg)

    return jax.jit(run, donate_argnums=(1,))


@dataclasses.dataclass(eq=False)
class Request:
    """One generation job.

    Shape contract: ``prompt`` is a [n] int32 token array with n >= 1;
    ``max_new_tokens`` >= 1 counts every emitted token INCLUDING the first
    one sampled from the prefill logits, so a request occupies
    ``ceil((n + max_new_tokens) / window)`` pages at full length.

    ``priority``: higher wins.  Admission order is (priority desc, submit
    order); in chunked mode a higher-priority arrival may preempt the
    lowest-priority running request under page pressure (the victim is
    rebuilt later, emitting identical tokens).

    ``eq=False``: requests compare by identity — the scheduler removes them
    from its queue by object, and a generated __eq__ would compare the
    ndarray prompt."""
    rid: int
    prompt: np.ndarray              # [n] int32 token ids
    max_new_tokens: int
    temperature: float = 0.0
    arrival: float = 0.0            # seconds since trace start
    priority: int = 0               # higher = more important


@dataclasses.dataclass
class FinishedRequest:
    """``arrival`` is trace-relative (copied from the Request); all other
    stamps are absolute `time.perf_counter` values.  ``preemptions`` counts
    how many times the request was evicted and rebuilt."""
    rid: int
    tokens: np.ndarray              # [max_new_tokens] generated ids
    arrival: float
    admitted: float                 # when prefill started
    first_token: float              # TTFT reference point
    finished: float
    token_times: list[float] = dataclasses.field(default_factory=list)
    preemptions: int = 0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Slot/page budget and scheduling knobs.

    Invariants enforced at construction: the pool minus the reserve still
    fits one slot's maximum context (otherwise admission could deadlock),
    and ``prefill_chunk`` is a positive multiple of the landmark window
    (pages and landmarks are window-aligned, so chunk boundaries must be
    too).

    ``prefill_chunk`` = 0 (default) keeps the monolithic prefill path:
    full page budget up front, no preemption — exactly the PR-1 engine.
    ``prefill_chunk`` > 0 enables chunked prefill AND priority preemption:
    requests admit with their first chunk's pages only, grow page-by-page,
    and may be evicted for higher-priority work.

    ``reserve_pages``: pages the admission/prefill path may not claim;
    only decode-time appends (one page per ``window`` tokens per slot) can
    dip into them, which is what keeps running requests running when a
    burst of admissions would otherwise drain the pool.

    ``sample_device``: where decode-time sampling runs.  ``"host"``
    downloads the [S, V] logits every step and samples in Python (the
    PR-2 path); ``"fused"`` samples inside the decode program
    (`models.transformer.sample_tokens`) and downloads [S] int32 tokens —
    same greedy argmax, same (rid, index)-derived categorical keys, so
    tokens are bit-identical across the two modes.

    ``prefill_mode`` (chunked mode only): ``"batched"`` (default) advances
    EVERY prefilling slot one chunk per step in ONE fused dispatch (a slot
    mask, same compiled shape regardless of how many slots are prefilling)
    and serves non-window-aligned prompts through the same chunk program;
    ``"per-job"`` is the PR-2 baseline — at most one job advances one
    chunk per step in its own dispatch, non-aligned prompts take the
    monolithic head."""
    n_slots: int = 8                # decode batch width
    n_pages: int = 64               # shared pool size (pages of `window`)
    pages_per_slot: int = 8         # max context per request, in pages
    finalize: str = "external"      # external | inline (see core.mita_decode)
    prefill_chunk: int = 0          # chunk length (0 = monolithic prefill)
    reserve_pages: int = 0          # appends-only page reserve
    sample_device: str = "host"     # host | fused (on-device sampling)
    prefill_mode: str = "batched"   # batched | per-job (chunk dispatch)


class _PageAllocator:
    """Free-list over the shared pool.  A page belongs to ≤ 1 active slot.

    ``reserve`` pages are invisible to ordinary allocations (admission,
    prefill chunks) and only served when ``reserved=True`` (decode appends)
    — the high-water mark and the dip counter quantify how close the pool
    came to starving the decode batch."""

    def __init__(self, n_pages: int, reserve: int = 0):
        self.n_pages = n_pages
        self.reserve = reserve
        self.free: list[int] = list(range(n_pages))
        self.high_water = 0             # max pages ever in use
        self.reserve_dips = 0           # appends served from the reserve

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self.free)

    def can_alloc(self, n: int, reserved: bool = False) -> bool:
        avail = len(self.free) if reserved else len(self.free) - self.reserve
        return n <= avail

    def alloc(self, n: int, reserved: bool = False) -> list[int]:
        if not self.can_alloc(n, reserved):
            raise RuntimeError("page pool exhausted")
        if reserved and len(self.free) - n < self.reserve:
            self.reserve_dips += 1
        pages, self.free = self.free[:n], self.free[n:]
        self.high_water = max(self.high_water, self.in_use)
        return pages

    def release(self, pages: list[int]) -> None:
        self.free.extend(pages)


@dataclasses.dataclass(eq=False)
class _WaitEntry:
    """Queue entry: (priority desc, submit order) defines admission order.
    ``resume`` holds (tokens, times, meta) for a preempted request awaiting
    its recompute-from-prompt re-admission; ``evictions`` counts every
    preemption the request has suffered (mid-prefill restarts included)."""
    req: Request
    seq: int
    resume: Optional[tuple] = None
    evictions: int = 0

    @property
    def key(self):
        return (-self.req.priority, self.seq)


@dataclasses.dataclass(eq=False)
class _PrefillJob:
    """A request mid-(chunked)-prefill: owns a slot and a growing page set,
    but is NOT in the decode batch until the last chunk lands."""
    entry: _WaitEntry
    toks: np.ndarray                # prompt [+ generated-so-far] to pack
    n_train: int                    # original prompt length (semantics)
    admit_time: float
    done: int = 0                   # tokens packed so far (next chunk's t0)


class ServingEngine:
    """Admit/evict requests each step; keep the fused decode batch full."""

    def __init__(self, params: Any, cfg: ModelConfig,
                 ecfg: EngineConfig = EngineConfig(),
                 sample_key: jax.Array | None = None):
        if cfg.attn.backend not in ("mita", "mita_ref"):
            raise ValueError("ServingEngine drives MiTA decode caches")
        if ecfg.finalize not in ("external", "inline"):
            raise ValueError(f"unknown finalize mode {ecfg.finalize!r}")
        if ecfg.n_pages - ecfg.reserve_pages < ecfg.pages_per_slot:
            raise ValueError("pool minus reserve smaller than one slot's "
                             "max context — admission could deadlock")
        if ecfg.prefill_chunk and (ecfg.prefill_chunk < 0
                                   or ecfg.prefill_chunk % cfg.attn.window):
            raise ValueError("prefill_chunk must be a positive multiple of "
                             f"the landmark window ({cfg.attn.window})")
        if ecfg.reserve_pages < 0:
            raise ValueError("reserve_pages must be >= 0")
        if ecfg.sample_device not in ("host", "fused"):
            raise ValueError(f"unknown sample_device {ecfg.sample_device!r}")
        if ecfg.prefill_mode not in ("batched", "per-job"):
            raise ValueError(f"unknown prefill_mode {ecfg.prefill_mode!r}")
        self.params = params
        self.cfg = dataclasses.replace(
            cfg, attn=dataclasses.replace(
                cfg.attn, external_finalize=ecfg.finalize == "external"))
        self.ecfg = ecfg
        self.w = cfg.attn.window
        self._key = (jax.random.PRNGKey(0) if sample_key is None
                     else sample_key)

        s, m = ecfg.n_slots, ecfg.pages_per_slot
        self.states = tfm.init_paged_states(self.cfg, s, ecfg.n_pages, m)
        self.alloc = _PageAllocator(ecfg.n_pages, ecfg.reserve_pages)

        # host-owned scheduler state
        self.page_table = np.zeros((s, m), np.int32)
        self.t = np.zeros(s, np.int32)
        self.active = np.zeros(s, bool)
        self.tokens_in = np.zeros(s, np.int32)
        self.m_done = np.zeros(s, np.int32)   # finalized landmarks per slot
        # per-slot sampling inputs for the fused on-device sampler
        self.slot_rid = np.zeros(s, np.int32)
        self.slot_temp = np.zeros(s, np.float32)
        self.sample_idx = np.zeros(s, np.int32)   # next token index per slot
        self.free_slots: list[int] = list(range(s))
        self.slot_req: dict[int, Request] = {}
        self.slot_entry: dict[int, _WaitEntry] = {}
        self.slot_pages: dict[int, list[int]] = {}
        self.slot_out: dict[int, list[int]] = {}
        self.slot_times: dict[int, list[float]] = {}
        self.slot_meta: dict[int, tuple[float, float]] = {}  # admitted, ttft
        self.slot_seq: dict[int, int] = {}    # admission recency (victims)
        self.slot_npre: dict[int, int] = {}   # preemptions suffered so far
        self.prefilling: dict[int, _PrefillJob] = {}
        self.waiting: list[_WaitEntry] = []   # sorted by _WaitEntry.key
        self.finished: list[FinishedRequest] = []
        self.steps = 0
        self.n_preemptions = 0
        self.n_chunks = 0
        self.prefill_dispatches = 0
        self.step_times: list[float] = []
        self._seq = 0

        # window-boundary landmark finalize fused behind a lax.cond —
        # off-boundary steps skip the O(context) work inside ONE program
        self._decode = _decode_fn(self.cfg, ecfg.finalize == "external",
                                  ecfg.sample_device == "fused")
        # device mirrors of the scheduler tensors (uploaded on change)
        self._dirty = True
        self._t_dev = self._md_dev = self._pt_dev = self._ac_dev = None
        self._rid_dev = self._tp_dev = self._si_dev = None
        self._traceable: set[int] = set()   # validated prompt lengths
        self._inflight: set[int] = set()    # rids waiting or active

    # ------------------------------------------------------------ plumbing --

    def _prefill_fn(self, n: int, k: int) -> Callable:
        cap = mdec.window_aligned(n, self.w)
        return _prefill_pack_fn(self.cfg, cap, k)

    def _chunk_fn(self) -> Callable:
        return _chunk_prefill_fn(self.cfg, self.ecfg.prefill_chunk,
                                 self.ecfg.pages_per_slot)

    def _batched_chunk_fn(self) -> Callable:
        return _batched_chunk_prefill_fn(self.cfg, self.ecfg.prefill_chunk,
                                         self.ecfg.pages_per_slot)

    def _sample(self, logits: np.ndarray, req: Request, index: int) -> int:
        if req.temperature <= 0.0:
            return int(np.argmax(logits))
        key = jax.random.fold_in(jax.random.fold_in(self._key, req.rid), index)
        # temperature floor matches the fused sampler exactly
        # (`tfm.sample_tokens`) so host/fused tokens stay bit-identical
        # even for degenerate near-zero temperatures
        return int(jax.random.categorical(
            key, jnp.asarray(logits) / max(req.temperature, 1e-6)))

    def pages_needed(self, req: Request) -> int:
        cap = len(req.prompt) + req.max_new_tokens
        return mdec.window_aligned(cap, self.w) // self.w

    def _check_prefill_traceable(self, n: int) -> None:
        """Reject prompt lengths the prefill path cannot lower (e.g. the
        sorted-mita block_q divisibility constraint) at SUBMIT time, with
        abstract tracing only — a length that failed inside admission after
        scheduler state was mutated would leak the slot and its pages."""
        if n in self._traceable:
            return
        cap = mdec.window_aligned(n, self.w)
        mdl = self.cfg
        try:
            jax.eval_shape(
                lambda p, tok: tfm.lm_prefill(p, tok, mdl, cap),
                self.params,
                jax.ShapeDtypeStruct((1, n), jnp.int32))
        except Exception as e:
            raise ValueError(
                f"prompt length {n} is not servable by the "
                f"{mdl.attn.backend!r} prefill path (window {self.w}): {e}"
            ) from e
        self._traceable.add(n)

    def warmup(self, prompt_lens: list[int]) -> None:
        """Compile every program the serving loop can hit for the given
        prompt lengths: the fused decode step, the chunk-prefill program
        variants (chunked mode: per-job has one; batched has one per
        power-of-two row width, exercised by submitting that many probes
        at once so they prefill concurrently), and each monolithic prefill
        variant.  Runs on one scratch engine so this engine's
        pool/scheduler state is untouched (compile caches are shared
        module-wide)."""
        scratch = ServingEngine(self.params, self.cfg, self.ecfg)
        k_max = 1 if (self.ecfg.prefill_chunk
                      and self.ecfg.prefill_mode == "per-job") \
            else self.ecfg.n_slots
        if self.ecfg.prefill_chunk and self.ecfg.prefill_mode == "batched":
            # no compiled program depends on prompt length in batched
            # chunked mode (length, resume point, and the n//m quirk are
            # data) — one representative length covers every width variant
            prompt_lens = [max(prompt_lens)] if prompt_lens else []
        for n in sorted(set(prompt_lens)):
            # probe requests claim the MINIMAL page budget a real request
            # of this length would (max_new=1), so warmup never rejects a
            # length the engine can actually serve
            gen = 2 if mdec.window_aligned(n + 2, self.w) // self.w \
                <= self.ecfg.pages_per_slot else 1
            sizes = []
            k = 1
            while k <= k_max:
                sizes.append(k)
                k *= 2
            if sizes[-1] != k_max:
                # non-power-of-two slot counts cap the batched prefill row
                # width at k_max itself — compile that variant too
                sizes.append(k_max)
            for k in sizes:
                scratch.run([Request(rid=-1 - i, prompt=np.zeros(n, np.int32),
                                     max_new_tokens=gen) for i in range(k)])

    def stats(self) -> dict[str, float]:
        """Scheduler counters: fused steps, prefill chunks run (per slot),
        prefill dispatches issued (batched mode: ≤ 1 per step regardless of
        how many slots are prefilling), preemptions, and the allocator's
        high-water / reserve accounting."""
        return {"steps": self.steps, "chunks": self.n_chunks,
                "prefill_dispatches": self.prefill_dispatches,
                "preemptions": self.n_preemptions,
                "pages_high_water": self.alloc.high_water,
                "reserve_dips": self.alloc.reserve_dips}

    # ----------------------------------------------------------- scheduler --

    def submit(self, req: Request) -> None:
        """Queue a request.  Validates — before any scheduler state is
        touched — that the prompt is non-empty, that prompt + max_new fits a
        slot's page budget (invariant 3: an admitted request can always
        finish), that the rid is not already in flight, and that the prompt
        length lowers through whichever prefill path will serve it."""
        if len(req.prompt) < 1 or req.max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and ≥ 1 new token")
        if self.pages_needed(req) > self.ecfg.pages_per_slot:
            raise ValueError(
                f"request {req.rid} needs {self.pages_needed(req)} pages; a "
                f"slot owns {self.ecfg.pages_per_slot} "
                f"(max context {self.ecfg.pages_per_slot * self.w})")
        if req.rid in self._inflight:
            raise ValueError(f"request id {req.rid} is already in flight")
        n = len(req.prompt)
        if not self.ecfg.prefill_chunk or (
                self.ecfg.prefill_mode == "per-job" and n % self.w):
            self._check_prefill_traceable(n)
        elif n % self.w:
            # batched chunked mode serves non-aligned prompts through the
            # chunk program, which replicates the training head's n//m
            # landmark pooling — representable only when m divides n
            # (pool1d's constraint, the same lengths the static path serves)
            if n % max(1, n // self.w):
                raise ValueError(
                    f"prompt length {n} is not servable by the chunked "
                    f"prefill path (window {self.w}): the training-path "
                    f"landmark pooling needs n % (n // window) == 0")
        self._inflight.add(req.rid)
        self._seq += 1
        self._enqueue(_WaitEntry(req=req, seq=self._seq))

    def _enqueue(self, entry: _WaitEntry) -> None:
        bisect.insort(self.waiting, entry, key=lambda e: e.key)

    def _emit(self, slot: int, tok: int, now: float) -> None:
        self.slot_out[slot].append(tok)
        self.slot_times[slot].append(now)

    def _retire(self, slot: int, now: float) -> None:
        req = self.slot_req.pop(slot)
        self.slot_entry.pop(slot)
        out = self.slot_out.pop(slot)
        times = self.slot_times.pop(slot)
        admitted, ttft = self.slot_meta.pop(slot)
        self.alloc.release(self.slot_pages.pop(slot))
        self.slot_seq.pop(slot)
        npre = self.slot_npre.pop(slot)
        self.active[slot] = False
        self.t[slot] = 0
        self.page_table[slot] = 0     # unused entries must stay in-bounds
        # a stale temperature would defeat the fused sampler's all-greedy
        # fast path (sample_tokens conds on "any slot tempered")
        self.slot_temp[slot] = 0.0
        self.free_slots.append(slot)
        self._dirty = True
        self._inflight.discard(req.rid)
        self.finished.append(FinishedRequest(
            rid=req.rid, tokens=np.asarray(out, np.int32),
            arrival=req.arrival, admitted=admitted, first_token=ttft,
            finished=now, token_times=times, preemptions=npre))

    # ---------------------------------------------------------- preemption --

    def _pick_victim(self, below: Optional[int] = None) -> Optional[int]:
        """Lowest-priority occupied slot; ties broken toward the most
        recently admitted (its recompute loses the least work).  ``below``
        restricts candidates to strictly lower priorities (admission-side
        preemption never thrashes equals)."""
        cands = [(job.entry.req.priority, self.slot_seq[s], s)
                 for s, job in self.prefilling.items()]
        cands += [(req.priority, self.slot_seq[s], s)
                  for s, req in self.slot_req.items()]
        if below is not None:
            cands = [c for c in cands if c[0] < below]
        if not cands:
            return None
        cands.sort(key=lambda c: (c[0], -c[1]))
        return cands[0][2]

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``: release its pages and requeue its request.  A
        decoding victim keeps its emitted tokens/stamps and is rebuilt by
        recompute-from-prompt; a prefilling victim simply restarts (it has
        emitted nothing)."""
        self.n_preemptions += 1
        self.alloc.release(self.slot_pages.pop(slot))
        self.page_table[slot] = 0
        self.slot_seq.pop(slot)
        job = self.prefilling.pop(slot, None)
        if job is not None:
            entry = job.entry      # mid-prefill: restart, nothing emitted
        else:
            entry = self.slot_entry.pop(slot)
            self.slot_req.pop(slot)
            out = self.slot_out.pop(slot)
            times = self.slot_times.pop(slot)
            meta = self.slot_meta.pop(slot)
            self.slot_npre.pop(slot)
            entry.resume = (out, times, meta)
            self.active[slot] = False
            self.t[slot] = 0
            self.slot_temp[slot] = 0.0
            self._dirty = True
        entry.evictions += 1
        self.free_slots.append(slot)
        self._enqueue(entry)

    def _preempt_for(self, priority: int, pages: int,
                     need_slot: bool = False) -> None:
        """Evict strictly-lower-priority victims until ``pages`` are
        allocatable (and a slot is free, if requested) or none remain."""
        while ((need_slot and not self.free_slots)
               or not self.alloc.can_alloc(pages)):
            victim = self._pick_victim(below=priority)
            if victim is None:
                return
            self._preempt(victim)

    # ----------------------------------------------------------- admission --

    def _admit(self, now: float) -> None:
        if self.ecfg.prefill_chunk:
            self._admit_chunked(now)
        else:
            self._admit_grouped(now)

    def _first_chunk_pages(self, entry: _WaitEntry) -> int:
        """Pages the first prefill dispatch of this request needs: one
        chunk's worth — or, in per-job mode, the whole (window-aligned)
        prompt when the prompt is not window-aligned and must go through
        the monolithic head (batched mode chunks every prompt)."""
        n_train = len(entry.req.prompt)
        n_total = n_train if entry.resume is None \
            else n_train + len(entry.resume[0]) - 1
        if self.ecfg.prefill_mode == "per-job" and n_train % self.w:
            return mdec.window_aligned(n_train, self.w) // self.w
        first = min(self.ecfg.prefill_chunk, n_total)
        return mdec.window_aligned(first, self.w) // self.w

    def _admit_chunked(self, now: float) -> None:
        """Chunked admission: one request at a time, first-chunk pages only.
        A higher-priority arrival preempts the lowest strictly-lower victim
        when slots or pages run short (invariant 2 becomes priority-ordered
        head-of-line blocking)."""
        while self.waiting:
            entry = self.waiting[0]
            first = self._first_chunk_pages(entry)
            if not self.free_slots or not self.alloc.can_alloc(first):
                self._preempt_for(entry.req.priority, first, need_slot=True)
                if not self.free_slots or not self.alloc.can_alloc(first):
                    return
            self.waiting.pop(0)
            slot = self.free_slots.pop()
            if entry.resume is None:
                toks = np.asarray(entry.req.prompt, np.int32)
            else:
                out = entry.resume[0]
                toks = np.concatenate([
                    np.asarray(entry.req.prompt, np.int32),
                    np.asarray(out[:-1], np.int32)])
            self.prefilling[slot] = _PrefillJob(
                entry=entry, toks=toks, n_train=len(entry.req.prompt),
                admit_time=now)
            # claim the first dispatch's pages NOW so concurrent admissions
            # never overcommit the same free pages
            pages = self.alloc.alloc(first)
            self.slot_pages[slot] = pages
            self.page_table[slot] = 0
            self.page_table[slot, : len(pages)] = pages
            self._dirty = True
            self._seq += 1
            self.slot_seq[slot] = self._seq

    def _admit_grouped(self, now: float) -> None:
        """Monolithic admission (``prefill_chunk`` = 0): priority-then-FCFS
        with same-length grouping — the head-of-line request picks the
        prompt length; other waiting requests of that length ride along in
        ONE fused prefill+pack dispatch (prefill rows are independent, so
        grouping never changes a request's tokens).  Head-of-line blocking
        on pages is deliberate — big requests are not starved by later
        small ones.  The full page budget is claimed up front (invariant
        3), so this path never needs preemption."""
        while self.waiting and self.free_slots:
            head = self.waiting[0].req
            if not self.alloc.can_alloc(self.pages_needed(head)):
                return
            n = len(head.prompt)
            budget = (len(self.alloc.free) - self.alloc.reserve
                      - self.pages_needed(head))
            group = [self.waiting[0]]
            for e in self.waiting[1:]:
                if len(group) >= len(self.free_slots):
                    break
                if len(e.req.prompt) == n and self.pages_needed(e.req) <= budget:
                    group.append(e)
                    budget -= self.pages_needed(e.req)
            # power-of-two chunks: bounds the (length, group-size) compile
            # variants to log2(slots) per prompt length (see `warmup`);
            # the remainder is admitted by the next loop iteration
            group = group[: 1 << (len(group).bit_length() - 1)]
            for e in group:
                self.waiting.remove(e)
            slots = [self.free_slots.pop() for _ in group]
            pages_list = [self.alloc.alloc(self.pages_needed(e.req))
                          for e in group]
            cap_pre = mdec.window_aligned(n, self.w)

            logits, self.states = self._prefill_fn(n, len(group))(
                self.params, self.states,
                jnp.asarray(np.stack([e.req.prompt for e in group]),
                            jnp.int32),
                jnp.asarray(slots, jnp.int32),
                jnp.asarray(np.stack(
                    [pg[: cap_pre // self.w] for pg in pages_list]),
                    jnp.int32))
            logits = np.asarray(logits)

            for i, (entry, slot, pages) in enumerate(
                    zip(group, slots, pages_list)):
                req = entry.req
                self.slot_req[slot] = req
                self.slot_entry[slot] = entry
                self.slot_pages[slot] = pages
                self.slot_out[slot] = []
                self.slot_times[slot] = []
                self.slot_npre[slot] = 0
                self._seq += 1
                self.slot_seq[slot] = self._seq
                self.page_table[slot] = 0
                self.page_table[slot, : len(pages)] = pages
                self.t[slot] = n
                self.m_done[slot] = n // self.w
                self.active[slot] = True
                self.slot_rid[slot] = req.rid
                self.slot_temp[slot] = req.temperature
                first = self._sample(logits[i], req, 0)
                self.sample_idx[slot] = 1
                self.slot_meta[slot] = (now, time.perf_counter())
                self._emit(slot, first, time.perf_counter())
                self.tokens_in[slot] = first
                if req.max_new_tokens == 1:
                    self._retire(slot, time.perf_counter())
            self._dirty = True

    # ------------------------------------------------------ chunked prefill --

    def _grow_pages(self, slot: int, target: int) -> bool:
        """Grow ``slot`` to ``target`` pages for the next prefill dispatch.

        On pressure, pages flow toward the best-keyed admitted work: the
        globally worst occupant — lowest priority, then most recently
        admitted (FCFS within a class) — is evicted until the allocation
        fits.  The worst occupant is never better-keyed than this job (the
        job is itself a candidate), so higher-priority and more-senior work
        is never disturbed; if this job IS the pool's worst occupant while
        others wait on it, it yields (self-preempt).  The strict total
        order (priority, admission seq) is what rules out livelock between
        equal-priority jobs."""
        delta = target - len(self.slot_pages[slot])
        if delta <= 0:
            return True
        while not self.alloc.can_alloc(delta):
            victim = self._pick_victim()
            if victim is None or victim == slot:
                break
            self._preempt(victim)
        if not self.alloc.can_alloc(delta):
            occupied = len(self.prefilling) + len(self.slot_req)
            if occupied > 1 and self._pick_victim() == slot:
                self._preempt(slot)
            return False
        pages = self.alloc.alloc(delta)
        base = len(self.slot_pages[slot])
        for i, p in enumerate(pages):
            self.page_table[slot, base + i] = p
        self.slot_pages[slot].extend(pages)
        self._dirty = True
        return True

    def _advance_prefill(self, now: float) -> None:
        """Advance prefilling jobs: ONE fused dispatch per engine step.

        Batched mode (default): every prefilling slot that can grow its
        pages advances one chunk in a single `lm_prefill_chunks` dispatch
        over a slot mask.  Per-job mode (the PR-2 baseline): only the
        best-keyed job advances, in its own dispatch."""
        if not self.prefilling:
            return
        if self.ecfg.prefill_mode == "batched":
            self._advance_prefill_batched(now)
        else:
            self._advance_prefill_per_job(now)

    def _advance_prefill_batched(self, now: float) -> None:
        """One dispatch advances EVERY prefilling job one chunk.  Jobs that
        cannot claim their next pages are masked out of the dispatch (and
        may have been self-preempted by `_grow_pages`), not serialized.
        Page growth runs best-key-first, so the victim order of `_grow
        _pages` (globally worst key first) can never evict a job already
        approved this step."""
        chunk = self.ecfg.prefill_chunk
        advancing: list[tuple[int, _PrefillJob, int]] = []
        for slot, job in sorted(self.prefilling.items(),
                                key=lambda kv: kv[1].entry.key):
            if self.prefilling.get(slot) is not job:
                continue              # evicted while an earlier job grew
            t0 = job.done
            nv = min(chunk, len(job.toks) - t0)
            target = mdec.window_aligned(t0 + nv, self.w) // self.w
            if not self._grow_pages(slot, target):
                continue
            if self.prefilling.get(slot) is job:
                advancing.append((slot, job, nv))
        if not advancing:
            return
        # rows are jobs, packed to a power-of-two width so compute scales
        # with the number of prefilling requests (log2(slots)+1 compiled
        # variants — the monolithic admission-grouping bound).  Padding
        # rows borrow DISTINCT idle slot ids (inactive rows write their
        # slot's state back bit-identically), so the state scatter never
        # sees duplicate indices.
        p_w = 1 << (len(advancing) - 1).bit_length() if advancing else 1
        p_w = min(p_w, self.ecfg.n_slots)
        used = {s for s, _, _ in advancing}
        pads = [s for s in range(self.ecfg.n_slots) if s not in used]
        slot_ids = [s for s, _, _ in advancing] + pads[: p_w - len(advancing)]
        toks = np.zeros((p_w, chunk), np.int32)
        job_active = np.zeros(p_w, bool)
        t0s = np.zeros(p_w, np.int32)
        nvs = np.zeros(p_w, np.int32)
        ntr = np.ones(p_w, np.int32)
        for i, (slot, job, nv) in enumerate(advancing):
            toks[i, :nv] = job.toks[job.done:job.done + nv]
            job_active[i] = True
            t0s[i] = job.done
            nvs[i] = nv
            ntr[i] = job.n_train
        logits, self.states = self._batched_chunk_fn()(
            self.params, self.states, jnp.asarray(toks),
            jnp.asarray(job_active),
            jnp.asarray(self.page_table[slot_ids]),
            jnp.asarray(slot_ids, jnp.int32).reshape(p_w),
            jnp.asarray(t0s), jnp.asarray(nvs), jnp.asarray(ntr))
        self.n_chunks += len(advancing)
        self.prefill_dispatches += 1
        logits = np.asarray(logits)
        for i, (slot, job, nv) in enumerate(advancing):
            job.done += nv
            if job.done == len(job.toks):
                self._finish_prefill(slot, job, logits[i], now)

    def _advance_prefill_per_job(self, now: float) -> None:
        """Run ONE prefill dispatch (a chunk, or the monolithic head for a
        non-window-aligned prompt) for the best prefilling job — bounding
        per-step added latency to one chunk regardless of prompt length."""
        slot, job = min(self.prefilling.items(),
                        key=lambda kv: kv[1].entry.key)
        n_total = len(job.toks)
        if job.done == 0 and job.n_train % self.w:
            # monolithic head: the training-path prefill program this prompt
            # length would have used unchunked (non-aligned prompts keep the
            # quirkless monolithic semantics; see docs/serving.md)
            n = job.n_train
            cap = mdec.window_aligned(n, self.w)
            if not self._grow_pages(slot, cap // self.w):
                return
            logits, self.states = self._prefill_fn(n, 1)(
                self.params, self.states,
                jnp.asarray(job.toks[None, :n], jnp.int32),
                jnp.asarray([slot], jnp.int32),
                jnp.asarray([self.slot_pages[slot][: cap // self.w]],
                            jnp.int32))
            job.done = n
            self.prefill_dispatches += 1
            if job.done == n_total:
                self._finish_prefill(slot, job, np.asarray(logits)[0], now)
            return
        chunk = self.ecfg.prefill_chunk
        t0 = job.done
        nv = min(chunk, n_total - t0)
        target = mdec.window_aligned(t0 + nv, self.w) // self.w
        if not self._grow_pages(slot, target):
            return
        toks = np.zeros(chunk, np.int32)
        toks[:nv] = job.toks[t0:t0 + nv]
        logits, self.states = self._chunk_fn()(
            self.params, self.states, jnp.asarray(toks), np.int32(slot),
            jnp.asarray(self.page_table[slot]), np.int32(t0), np.int32(nv),
            np.int32(job.n_train))
        self.n_chunks += 1
        self.prefill_dispatches += 1
        job.done = t0 + nv
        if job.done == n_total:
            self._finish_prefill(slot, job, np.asarray(logits), now)

    def _finish_prefill(self, slot: int, job: _PrefillJob,
                        logits: np.ndarray, now: float) -> None:
        """Last chunk landed: move the slot into the decode batch.  Fresh
        requests sample their first token from the final chunk's logits;
        resumed (preempted) requests restore their emitted tokens and
        continue decoding from where they were evicted."""
        entry = job.entry
        req = entry.req
        del self.prefilling[slot]
        n_total = len(job.toks)
        self.slot_req[slot] = req
        self.slot_entry[slot] = entry
        self.t[slot] = n_total
        self.m_done[slot] = n_total // self.w
        self.active[slot] = True
        self._dirty = True
        self.slot_npre[slot] = entry.evictions
        self.slot_rid[slot] = req.rid
        self.slot_temp[slot] = req.temperature
        if entry.resume is None:
            self.slot_out[slot] = []
            self.slot_times[slot] = []
            first = self._sample(logits, req, 0)
            self.sample_idx[slot] = 1
            self.slot_meta[slot] = (job.admit_time, time.perf_counter())
            self._emit(slot, first, time.perf_counter())
            self.tokens_in[slot] = first
            if req.max_new_tokens == 1:
                self._retire(slot, time.perf_counter())
        else:
            out, times, meta = entry.resume
            entry.resume = None
            self.slot_out[slot] = list(out)
            self.slot_times[slot] = list(times)
            self.slot_meta[slot] = meta
            self.sample_idx[slot] = len(out)
            self.tokens_in[slot] = out[-1]

    def _ensure_append_pages(self) -> None:
        """Guarantee every active slot owns the page its next append lands
        in (invariant 3 in incremental form).  Appends may dip into the
        reserve; if the pool is truly dry the lowest-priority slot is
        preempted — possibly the appender itself, whose pages then fund the
        survivors."""
        for slot in np.nonzero(self.active)[0]:
            slot = int(slot)
            if not self.active[slot]:
                continue              # preempted as a victim this pass
            need_idx = int(self.t[slot]) // self.w
            if need_idx < len(self.slot_pages[slot]):
                continue
            while not self.alloc.can_alloc(1, reserved=True):
                victim = self._pick_victim()
                if victim is None:
                    break
                self._preempt(victim)
                if victim == slot:
                    break
            if not self.active[slot]:
                continue
            page = self.alloc.alloc(1, reserved=True)[0]
            self.slot_pages[slot].append(page)
            self.page_table[slot, need_idx] = page
            self._dirty = True

    # ---------------------------------------------------------------- step --

    def step(self) -> bool:
        """One engine iteration: retire/admit, advance at most one prefill
        chunk, then one fused decode step for the active batch.  Returns
        False when there is nothing left to do."""
        now = time.perf_counter()
        self._admit(now)
        self._advance_prefill(now)
        if self.ecfg.prefill_chunk:
            self._ensure_append_pages()
        if not self.active.any():
            return bool(self.waiting or self.prefilling)

        if self._dirty:
            self._t_dev = jnp.asarray(self.t)
            self._md_dev = jnp.asarray(self.m_done)
            self._pt_dev = jnp.asarray(self.page_table)
            self._ac_dev = jnp.asarray(self.active)
            self._rid_dev = jnp.asarray(self.slot_rid)
            self._tp_dev = jnp.asarray(self.slot_temp)
            self._si_dev = jnp.asarray(self.sample_idx)
            self._dirty = False
        # host mirror of the device-side due/m_done transition
        due = self.active & (self.t % self.w == 0) & (self.t // self.w
                                                      > self.m_done)
        self.m_done = np.where(due, self.t // self.w, self.m_done)

        fused_sampling = self.ecfg.sample_device == "fused"
        t0 = time.perf_counter()
        out, self.states, self._t_dev, self._md_dev, self._si_dev = \
            self._decode(self.params, self.states,
                         jnp.asarray(self.tokens_in), self._t_dev,
                         self._md_dev, self._pt_dev, self._ac_dev,
                         self._rid_dev, self._si_dev, self._tp_dev,
                         self._key)
        # fused sampling downloads [S] int32 tokens; the host path the
        # whole [S, V] logits (docs/serving.md, host-transfer budget)
        out = np.asarray(out)
        self.step_times.append(time.perf_counter() - t0)
        self.steps += 1

        now = time.perf_counter()
        for slot in np.nonzero(self.active)[0]:
            req = self.slot_req[slot]
            if fused_sampling:
                tok = int(out[slot])
            else:
                tok = self._sample(out[slot], req, len(self.slot_out[slot]))
            self._emit(slot, tok, now)
            self.t[slot] += 1
            self.sample_idx[slot] += 1
            self.tokens_in[slot] = tok
            if len(self.slot_out[slot]) >= req.max_new_tokens:
                self._retire(slot, now)
        return True

    def run(self, requests: list[Request],
            realtime: bool = False) -> list[FinishedRequest]:
        """Drive a whole trace, returning the requests finished during THIS
        call (an engine can serve many traces back-to-back).
        ``realtime=True`` honours arrival offsets on the wall clock
        (Poisson traces); otherwise all requests queue up front
        (max-throughput mode)."""
        pending = sorted(requests, key=lambda r: r.arrival)
        start = time.perf_counter()
        already_done = len(self.finished)
        idx = 0
        while (idx < len(pending) or self.waiting or self.prefilling
               or self.active.any()):
            now = time.perf_counter() - start
            while idx < len(pending) and (
                    not realtime or pending[idx].arrival <= now):
                self.submit(pending[idx])
                idx += 1
            progressed = self.step()
            if not progressed and idx < len(pending):
                if realtime:
                    time.sleep(max(0.0,
                                   pending[idx].arrival
                                   - (time.perf_counter() - start)))
        return sorted(self.finished[already_done:], key=lambda f: f.rid)
