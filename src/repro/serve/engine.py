"""Continuous-batching engine over the paged MiTA decode cache.

The scheduler is plain host Python; everything device-side is one of two
jitted programs (see README.md for the page layout and invariants):

  * ``prefill+pack`` — `lm_prefill` over an admission group (same-length
    waiting requests, power-of-two sizes) packed straight into the slots'
    pages; compiled per (window-aligned prompt capacity, group size);
  * ``decode``       — `lm_paged_decode_step`, ONE program for the whole
    slot batch regardless of per-request progress (per-slot positions, page
    tables, and activity are data, not shape).  The window-boundary
    landmark finalize is fused behind a scalar `lax.cond`, and the per-slot
    position/finalize counters advance on device so the hot loop uploads
    only the sampled tokens.

Greedy sampling is exact w.r.t. the static `launch.serve` path: a request
decoded by the engine emits the same tokens it would emit in a fixed batch
(`tests/test_serve.py` pins this).  Temperature sampling derives its key
from (request id, token index) so results are batching-invariant too.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mita_decode as mdec
from repro.models import transformer as tfm
from repro.models.modules import ModelConfig


@functools.lru_cache(maxsize=None)
def _decode_fn(cfg: ModelConfig, fused_finalize: bool) -> Callable:
    """Fused whole-batch decode step, cached at module level so every
    engine instance with the same model config shares compiled code.

    Scheduler tensors (t, m_done) advance ON DEVICE: the hot loop uploads
    only the sampled tokens and downloads only the logits — page tables,
    activity, and positions are re-uploaded solely when admission/retire
    changes them."""
    w = cfg.attn.window

    def step(p, st, tok, t, m_done, pt, ac):
        due = None
        if fused_finalize:
            due = ac & (t % w == 0) & (t // w > m_done)
            m_done = jnp.where(due, t // w, m_done)
        logits, st = tfm.lm_paged_decode_step(p, st, tok, t, pt, ac, cfg,
                                              due=due)
        return logits, st, t + ac.astype(t.dtype), m_done

    return jax.jit(step, donate_argnums=(1, 3, 4))


@functools.lru_cache(maxsize=None)
def _prefill_pack_fn(cfg: ModelConfig, cap: int, k: int) -> Callable:
    """Fused batched prefill + pack-into-slots: one dispatch admits ``k``
    same-length requests (compiled per window-aligned capacity and group
    size).  Prefill rows are independent, so batching admissions does not
    change any request's tokens."""

    def prefill_pack(p, st, toks, slots, pages):
        logits, pre = tfm.lm_prefill(p, toks, cfg, cap)
        for i in range(k):
            pre_i = jax.tree.map(
                lambda a: a[:, i:i + 1] if a.ndim >= 2 else a, pre)
            st = tfm.pack_prefill_into_states(st, pre_i, slots[i], pages[i],
                                              cfg)
        return logits, st

    return jax.jit(prefill_pack, donate_argnums=(1,))


@dataclasses.dataclass(eq=False)
class Request:
    """One generation job.  ``max_new_tokens`` includes the first token
    sampled from the prefill logits.  ``eq=False``: requests compare by
    identity — the scheduler removes them from its queue by object, and a
    generated __eq__ would compare the ndarray prompt."""
    rid: int
    prompt: np.ndarray              # [n] int32 token ids
    max_new_tokens: int
    temperature: float = 0.0
    arrival: float = 0.0            # seconds since trace start


@dataclasses.dataclass
class FinishedRequest:
    """``arrival`` is trace-relative (copied from the Request); all other
    stamps are absolute `time.perf_counter` values."""
    rid: int
    tokens: np.ndarray              # [max_new_tokens] generated ids
    arrival: float
    admitted: float                 # when prefill ran
    first_token: float              # TTFT reference point
    finished: float
    token_times: list[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8                # decode batch width
    n_pages: int = 64               # shared pool size (pages of `window`)
    pages_per_slot: int = 8         # max context per request, in pages
    finalize: str = "external"      # external | inline (see core.mita_decode)


class _PageAllocator:
    """Free-list over the shared pool.  A page belongs to ≤ 1 active slot."""

    def __init__(self, n_pages: int):
        self.free: list[int] = list(range(n_pages))

    def alloc(self, n: int) -> list[int]:
        if n > len(self.free):
            raise RuntimeError("page pool exhausted")
        pages, self.free = self.free[:n], self.free[n:]
        return pages

    def release(self, pages: list[int]) -> None:
        self.free.extend(pages)


class ServingEngine:
    """Admit/evict requests each step; keep the fused decode batch full."""

    def __init__(self, params: Any, cfg: ModelConfig,
                 ecfg: EngineConfig = EngineConfig(),
                 sample_key: jax.Array | None = None):
        if cfg.attn.backend not in ("mita", "mita_ref"):
            raise ValueError("ServingEngine drives MiTA decode caches")
        if ecfg.finalize not in ("external", "inline"):
            raise ValueError(f"unknown finalize mode {ecfg.finalize!r}")
        if ecfg.n_pages < ecfg.pages_per_slot:
            raise ValueError("pool smaller than one slot's max context — "
                             "admission could deadlock")
        self.params = params
        self.cfg = dataclasses.replace(
            cfg, attn=dataclasses.replace(
                cfg.attn, external_finalize=ecfg.finalize == "external"))
        self.ecfg = ecfg
        self.w = cfg.attn.window
        self._key = (jax.random.PRNGKey(0) if sample_key is None
                     else sample_key)

        s, m = ecfg.n_slots, ecfg.pages_per_slot
        self.states = tfm.init_paged_states(self.cfg, s, ecfg.n_pages, m)
        self.alloc = _PageAllocator(ecfg.n_pages)

        # host-owned scheduler state
        self.page_table = np.zeros((s, m), np.int32)
        self.t = np.zeros(s, np.int32)
        self.active = np.zeros(s, bool)
        self.tokens_in = np.zeros(s, np.int32)
        self.m_done = np.zeros(s, np.int32)   # finalized landmarks per slot
        self.free_slots: list[int] = list(range(s))
        self.slot_req: dict[int, Request] = {}
        self.slot_pages: dict[int, list[int]] = {}
        self.slot_out: dict[int, list[int]] = {}
        self.slot_times: dict[int, list[float]] = {}
        self.slot_meta: dict[int, tuple[float, float]] = {}  # admitted, ttft
        self.waiting: deque[Request] = deque()
        self.finished: list[FinishedRequest] = []
        self.steps = 0
        self.step_times: list[float] = []

        # window-boundary landmark finalize fused behind a lax.cond —
        # off-boundary steps skip the O(context) work inside ONE program
        self._decode = _decode_fn(self.cfg, ecfg.finalize == "external")
        # device mirrors of the scheduler tensors (uploaded on change)
        self._dirty = True
        self._t_dev = self._md_dev = self._pt_dev = self._ac_dev = None
        self._traceable: set[int] = set()   # validated prompt lengths
        self._inflight: set[int] = set()    # rids waiting or active

    # ------------------------------------------------------------ plumbing --

    def _prefill_fn(self, n: int, k: int) -> Callable:
        cap = mdec.window_aligned(n, self.w)
        return _prefill_pack_fn(self.cfg, cap, k)

    def _sample(self, logits: np.ndarray, req: Request, index: int) -> int:
        if req.temperature <= 0.0:
            return int(np.argmax(logits))
        key = jax.random.fold_in(jax.random.fold_in(self._key, req.rid), index)
        return int(jax.random.categorical(
            key, jnp.asarray(logits) / req.temperature))

    def pages_needed(self, req: Request) -> int:
        cap = len(req.prompt) + req.max_new_tokens
        return mdec.window_aligned(cap, self.w) // self.w

    def _check_prefill_traceable(self, n: int) -> None:
        """Reject prompt lengths the prefill path cannot lower (e.g. the
        sorted-mita block_q divisibility constraint) at SUBMIT time, with
        abstract tracing only — a length that failed inside `_admit` after
        scheduler state was mutated would leak the slot and its pages."""
        if n in self._traceable:
            return
        cap = mdec.window_aligned(n, self.w)
        mdl = self.cfg
        try:
            jax.eval_shape(
                lambda p, tok: tfm.lm_prefill(p, tok, mdl, cap),
                self.params,
                jax.ShapeDtypeStruct((1, n), jnp.int32))
        except Exception as e:
            raise ValueError(
                f"prompt length {n} is not servable by the "
                f"{mdl.attn.backend!r} prefill path (window {self.w}): {e}"
            ) from e
        self._traceable.add(n)

    def warmup(self, prompt_lens: list[int]) -> None:
        """Compile every program the serving loop can hit for the given
        prompt lengths: the fused decode step and each power-of-two
        admission-group prefill.  Runs on one scratch engine so this
        engine's pool/scheduler state is untouched (compile caches are
        shared module-wide)."""
        scratch = ServingEngine(self.params, self.cfg, self.ecfg)
        for n in sorted(set(prompt_lens)):
            # probe requests claim the MINIMAL page budget a real request
            # of this length would (max_new=1), so warmup never rejects a
            # length the engine can actually serve
            gen = 2 if mdec.window_aligned(n + 2, self.w) // self.w \
                <= self.ecfg.pages_per_slot else 1
            k = 1
            while k <= self.ecfg.n_slots:
                scratch.run([Request(rid=-1 - i, prompt=np.zeros(n, np.int32),
                                     max_new_tokens=gen) for i in range(k)])
                k *= 2

    # ----------------------------------------------------------- scheduler --

    def submit(self, req: Request) -> None:
        if len(req.prompt) < 1 or req.max_new_tokens < 1:
            raise ValueError("need a non-empty prompt and ≥ 1 new token")
        if self.pages_needed(req) > self.ecfg.pages_per_slot:
            raise ValueError(
                f"request {req.rid} needs {self.pages_needed(req)} pages; a "
                f"slot owns {self.ecfg.pages_per_slot} "
                f"(max context {self.ecfg.pages_per_slot * self.w})")
        if req.rid in self._inflight:
            raise ValueError(f"request id {req.rid} is already in flight")
        self._check_prefill_traceable(len(req.prompt))
        self._inflight.add(req.rid)
        self.waiting.append(req)

    def _emit(self, slot: int, tok: int, now: float) -> None:
        self.slot_out[slot].append(tok)
        self.slot_times[slot].append(now)

    def _retire(self, slot: int, now: float) -> None:
        req = self.slot_req.pop(slot)
        out = self.slot_out.pop(slot)
        times = self.slot_times.pop(slot)
        admitted, ttft = self.slot_meta.pop(slot)
        self.alloc.release(self.slot_pages.pop(slot))
        self.active[slot] = False
        self.t[slot] = 0
        self.page_table[slot] = 0     # unused entries must stay in-bounds
        self.free_slots.append(slot)
        self._dirty = True
        self._inflight.discard(req.rid)
        self.finished.append(FinishedRequest(
            rid=req.rid, tokens=np.asarray(out, np.int32),
            arrival=req.arrival, admitted=admitted, first_token=ttft,
            finished=now, token_times=times))

    def _admit(self, now: float) -> None:
        """FCFS admission with same-length grouping: the head-of-line
        request picks the prompt length; any other waiting requests of that
        length ride along in ONE fused prefill+pack dispatch (prefill rows
        are independent, so grouping never changes a request's tokens).
        Head-of-line blocking on pages is deliberate — big requests are not
        starved by later small ones."""
        while self.waiting and self.free_slots:
            head = self.waiting[0]
            if self.pages_needed(head) > len(self.alloc.free):
                return
            n = len(head.prompt)
            budget = len(self.alloc.free) - self.pages_needed(head)
            group = [head]
            for r in list(self.waiting)[1:]:
                if len(group) >= len(self.free_slots):
                    break
                if len(r.prompt) == n and self.pages_needed(r) <= budget:
                    group.append(r)
                    budget -= self.pages_needed(r)
            # power-of-two chunks: bounds the (length, group-size) compile
            # variants to log2(slots) per prompt length (see `warmup`);
            # the remainder is admitted by the next loop iteration
            group = group[: 1 << (len(group).bit_length() - 1)]
            for r in group:
                self.waiting.remove(r)
            slots = [self.free_slots.pop() for _ in group]
            pages_list = [self.alloc.alloc(self.pages_needed(r))
                          for r in group]
            cap_pre = mdec.window_aligned(n, self.w)

            logits, self.states = self._prefill_fn(n, len(group))(
                self.params, self.states,
                jnp.asarray(np.stack([r.prompt for r in group]), jnp.int32),
                jnp.asarray(slots, jnp.int32),
                jnp.asarray(np.stack(
                    [pg[: cap_pre // self.w] for pg in pages_list]),
                    jnp.int32))
            logits = np.asarray(logits)

            for i, (req, slot, pages) in enumerate(
                    zip(group, slots, pages_list)):
                self.slot_req[slot] = req
                self.slot_pages[slot] = pages
                self.slot_out[slot] = []
                self.slot_times[slot] = []
                self.page_table[slot] = 0
                self.page_table[slot, : len(pages)] = pages
                self.t[slot] = n
                self.m_done[slot] = n // self.w
                self.active[slot] = True
                first = self._sample(logits[i], req, 0)
                self.slot_meta[slot] = (now, time.perf_counter())
                self._emit(slot, first, time.perf_counter())
                self.tokens_in[slot] = first
                if req.max_new_tokens == 1:
                    self._retire(slot, time.perf_counter())
            self._dirty = True

    # ---------------------------------------------------------------- step --

    def step(self) -> bool:
        """One engine iteration: retire/admit, then one fused decode step.
        Returns False when there is nothing left to do."""
        now = time.perf_counter()
        self._admit(now)
        if not self.active.any():
            return bool(self.waiting)

        if self._dirty:
            self._t_dev = jnp.asarray(self.t)
            self._md_dev = jnp.asarray(self.m_done)
            self._pt_dev = jnp.asarray(self.page_table)
            self._ac_dev = jnp.asarray(self.active)
            self._dirty = False
        # host mirror of the device-side due/m_done transition
        due = self.active & (self.t % self.w == 0) & (self.t // self.w
                                                      > self.m_done)
        self.m_done = np.where(due, self.t // self.w, self.m_done)

        t0 = time.perf_counter()
        logits, self.states, self._t_dev, self._md_dev = self._decode(
            self.params, self.states, jnp.asarray(self.tokens_in),
            self._t_dev, self._md_dev, self._pt_dev, self._ac_dev)
        logits = np.asarray(logits)
        self.step_times.append(time.perf_counter() - t0)
        self.steps += 1

        now = time.perf_counter()
        for slot in np.nonzero(self.active)[0]:
            req = self.slot_req[slot]
            tok = self._sample(logits[slot], req, len(self.slot_out[slot]))
            self._emit(slot, tok, now)
            self.t[slot] += 1
            self.tokens_in[slot] = tok
            if len(self.slot_out[slot]) >= req.max_new_tokens:
                self._retire(slot, now)
        return True

    def run(self, requests: list[Request],
            realtime: bool = False) -> list[FinishedRequest]:
        """Drive a whole trace, returning the requests finished during THIS
        call (an engine can serve many traces back-to-back).
        ``realtime=True`` honours arrival offsets on the wall clock
        (Poisson traces); otherwise all requests queue up front
        (max-throughput mode)."""
        pending = sorted(requests, key=lambda r: r.arrival)
        start = time.perf_counter()
        already_done = len(self.finished)
        idx = 0
        while idx < len(pending) or self.waiting or self.active.any():
            now = time.perf_counter() - start
            while idx < len(pending) and (
                    not realtime or pending[idx].arrival <= now):
                self.submit(pending[idx])
                idx += 1
            progressed = self.step()
            if not progressed and idx < len(pending):
                if realtime:
                    time.sleep(max(0.0,
                                   pending[idx].arrival
                                   - (time.perf_counter() - start)))
        return sorted(self.finished[already_done:], key=lambda f: f.rid)
