"""Radix cache of committed window-aligned prompt prefixes.

One trie node per prompt WINDOW, keyed by that window's raw token bytes:
a root-to-node path spells a window-aligned token prefix, and the node
holds (a) the id of the pool page storing that window's context rows and
(b) an opaque per-window payload the backend snapshotted when the window
was first computed (for the paged-attention backend: the window's summary
and routing rows, which are byte-identical for every request sharing the
prefix — the fast-weight view of the paper makes prefix reuse exactly
this cheap).  The cache is generic: it never interprets payloads and
talks to the backend only through the engine.

Reference counting: every node retains ONE reference on its page via the
engine's `_PageAllocator`, held until the node is evicted.  Slots that
attach a matched prefix retain their own references, so cache eviction
and slot retirement are order-independent — the page frees when the last
holder lets go.

Path integrity invariant: a node's payload may only reference pages on
its own root-anchored path.  Two rules enforce it structurally:

  * `insert` extends the trie only while the inserting slot's pages
    PHYSICALLY match the existing path (first divergence stops the walk),
    so a deep node never mixes one request's pages with another's;
  * eviction removes LEAVES only (LRU by a monotonic clock, the whole
    matched path is touched on every hit), so an ancestor a descendant's
    payload depends on can never disappear first.
"""

from __future__ import annotations

from typing import Any, Optional

import numpy as np


class _Node:
    __slots__ = ("key", "page", "payload", "children", "parent", "last_used")

    def __init__(self, key: bytes, page: int, payload: Any,
                 parent: Optional["_Node"]):
        self.key = key
        self.page = page
        self.payload = payload
        self.children: dict[bytes, "_Node"] = {}
        self.parent = parent
        self.last_used = 0


class RadixPrefixCache:
    """Token-content-addressed trie over the shared page pool."""

    def __init__(self, alloc: Any, window: int):
        self.alloc = alloc
        self.w = window
        self.root = _Node(b"", -1, None, None)   # sentinel, owns no page
        self._clock = 0
        self.evictions = 0

    @property
    def n_nodes(self) -> int:
        count, stack = 0, list(self.root.children.values())
        while stack:
            node = stack.pop()
            count += 1
            stack.extend(node.children.values())
        return count

    @property
    def n_pages(self) -> int:
        """Pages currently pinned by the cache (== nodes: one page each)."""
        return self.n_nodes

    def _key(self, toks: np.ndarray, i: int) -> bytes:
        return np.ascontiguousarray(
            toks[i * self.w:(i + 1) * self.w], dtype=np.int32).tobytes()

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.last_used = self._clock

    def match(self, toks: np.ndarray, max_windows: int) -> list[_Node]:
        """Longest cached prefix of ``toks``, as the node path (at most
        ``max_windows`` deep).  Touches the whole matched path so no node
        a caller may attach is the next eviction candidate."""
        path: list[_Node] = []
        node = self.root
        for i in range(max_windows):
            child = node.children.get(self._key(toks, i))
            if child is None:
                break
            self._touch(child)
            path.append(child)
            node = child
        return path

    def insert(self, toks: np.ndarray, n_windows: int, pages: list[int],
               payload_fn: Any) -> int:
        """Commit ``n_windows`` leading windows of ``toks``, stored in
        ``pages``, to the trie.  ``payload_fn()`` must return one payload
        per window and is called at most once — only when the walk
        actually creates nodes.  Returns the number of nodes added."""
        node = self.root
        payloads = None
        added = 0
        for i in range(n_windows):
            key = self._key(toks, i)
            child = node.children.get(key)
            if child is not None:
                if child.page != pages[i]:
                    # same tokens, different physical page: a concurrent
                    # duplicate prefill — keep the incumbent path, and do
                    # NOT extend below it with this slot's pages
                    break
                self._touch(child)
                node = child
                continue
            if payloads is None:
                payloads = payload_fn()
            self.alloc.retain([pages[i]])
            child = _Node(key, pages[i], payloads[i], node)
            self._touch(child)
            node.children[key] = child
            node = child
            added += 1
        return added

    def evict_one(self) -> bool:
        """Drop the least-recently-used LEAF, releasing its page
        reference.  Returns False when the cache is empty."""
        leaf: Optional[_Node] = None
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            elif leaf is None or node.last_used < leaf.last_used:
                leaf = node
        if leaf is None:
            return False
        del leaf.parent.children[leaf.key]
        leaf.parent = None
        self.alloc.release([leaf.page])
        self.evictions += 1
        return True
