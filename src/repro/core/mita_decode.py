"""Incremental (decode-time) MiTA — our LM-serving adaptation.

The paper (§D) defers LLM decoding to future work; this module supplies it.
The key observation: the landmark/expert structures of causal MiTA depend
only on *completed* windows, so they can be maintained incrementally next to
the KV cache:

  * every step appends (k, v) to the cache and accumulates the query into a
    running window sum;
  * every ``window`` steps the just-completed window is *finalized*: its
    landmark query (mean of the window's queries), landmark value
    (cross-attention over the whole past), and top-k expert indices are
    computed once — O(t·d) work amortized to O(t·d/window) per token;
  * each decoded token then attends to: the shared expert (all finalized
    landmark pairs, ≤ m_max), its top-s routed experts (s·k gathered cache
    rows), and the local causal window — O(m_max + s·k + window) per token,
    which is what makes 500k-token decode lowerable.

State is per layer; models stack states over layers (scan axis 0).
Landmarks are shared per KV-head group (DESIGN.md GQA adaptation).
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.combine import (NEG_INF, Partial, combine,
                                partial_from_logits, partial_from_scores)


class MiTADecodeState(NamedTuple):
    """Decode-time cache for one attention layer.

    Shapes (B batch, Hkv KV heads, C cache capacity, d head dim,
    M = C // window landmark capacity, K expert width):
      k_cache, v_cache: [B, Hkv, C, d]
      lm_q, lm_v:       [B, Hkv, M, d]   finalized landmark queries/values
      expert_idx:       [B, Hkv, M, K]   gathered top-k cache rows per expert
      expert_valid:     [B, Hkv, M, K]
      q_sum:            [B, Hkv, d]      running query sum, current window
      t:                []               tokens currently in the cache
    """

    k_cache: jax.Array
    v_cache: jax.Array
    lm_q: jax.Array
    lm_v: jax.Array
    expert_idx: jax.Array
    expert_valid: jax.Array
    q_sum: jax.Array
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class DecodeConfig:
    window: int          # w — landmark window size (train-time N/m)
    k: int               # expert width
    s: int = 1           # routed experts per query
    capacity: int = 0    # C — cache capacity (set by init)
    # Externalize the every-w-steps landmark finalize into its own jitted
    # step (`mita_finalize_if_due`), called by the serving loop at window
    # boundaries.  The per-token decode step then carries no O(context)
    # branch (§Perf: the lax.cond finalize dominated the decode cell's
    # collective/memory terms even though it runs 1/w of steps).  Semantics
    # vs inline: the last token of each window routes among j instead of
    # j+1 experts (1/w of tokens, one-expert-stale routing).
    external_finalize: bool = False
    # Paged decode-step backend: "auto" (fused Pallas kernel on TPU when
    # its working set fits the VMEM budget; XLA gather path elsewhere),
    # "kernel" (force the kernel — interpret mode off-TPU — still bounded
    # by the budget), or "xla" (force the oracle).
    paged_impl: str = "auto"
    # Batched chunk-prefill backend, same tri-state (dispatched by
    # `kernels.ops.use_prefill_kernel`; REPRO_PREFILL_IMPL overrides).
    prefill_impl: str = "auto"
    # Paged landmark-finalize backend, same tri-state (dispatched by
    # `kernels.ops.use_finalize_kernel`; REPRO_FINALIZE_IMPL overrides).
    finalize_impl: str = "auto"
    # VMEM working-set budget for kernel dispatch; 0 = use the env/default
    # budget (`kernels.ops.vmem_budget_bytes`).
    vmem_budget: int = 0


def window_aligned(n: int, window: int) -> int:
    """Round a token count up to a whole number of landmark windows — the
    alignment every cache capacity and page boundary in this module (and
    the serving engine on top of it) must share."""
    return ((n + window - 1) // window) * window


def init_decode_state(batch: int, n_kv: int, head_dim: int, capacity: int,
                      cfg: DecodeConfig, dtype=jnp.bfloat16) -> MiTADecodeState:
    m_max = capacity // cfg.window
    z = lambda *s: jnp.zeros((batch, n_kv) + s, dtype)
    return MiTADecodeState(
        k_cache=z(capacity, head_dim), v_cache=z(capacity, head_dim),
        lm_q=z(m_max, head_dim), lm_v=z(m_max, head_dim),
        expert_idx=jnp.zeros((batch, n_kv, m_max, cfg.k), jnp.int32),
        expert_valid=jnp.zeros((batch, n_kv, m_max, cfg.k), bool),
        q_sum=jnp.zeros((batch, n_kv, head_dim), jnp.float32),
        t=jnp.zeros((), jnp.int32),
    )


def mita_prefill_state(q: jax.Array, k: jax.Array, v: jax.Array,
                       cfg: DecodeConfig, capacity: int) -> MiTADecodeState:
    """Build a decode state from a full-sequence prefill.

    q: [B, Hkv, G, N, d]; k, v: [B, Hkv, 1, N, d].  Landmark/expert caches
    are computed with the training-path functions so decode continues
    *exactly* where training-time causal MiTA leaves off.
    """
    from repro.core import mita as mref

    b, hkv, _, n, d = q.shape
    w = cfg.window
    m_cnt = n // w
    m_max = capacity // w
    dtype = k.dtype

    ql = jnp.mean(q, axis=2)                       # [B, Hkv, N, d] group-pool
    state = init_decode_state(b, hkv, d, capacity, cfg, dtype=dtype)

    if m_cnt > 0:
        mcfg = mref.MiTAConfig(m=m_cnt, k=cfg.k, s=cfg.s, causal=True)
        q_lm = jnp.mean(
            ql[:, :, : m_cnt * w].reshape(b, hkv, m_cnt, w, d), axis=3)
        s_kv = mref.landmark_scores(k[:, :, 0, :n], q_lm, mcfg)
        idx, valid = mref.topk_indices(s_kv, mcfg)
        v_lm = mref.landmark_values(v[:, :, 0, :n], s_kv)
        pad_m = m_max - m_cnt
        state = state._replace(
            lm_q=jnp.pad(q_lm.astype(dtype), ((0, 0), (0, 0), (0, pad_m), (0, 0))),
            lm_v=jnp.pad(v_lm.astype(dtype), ((0, 0), (0, 0), (0, pad_m), (0, 0))),
            expert_idx=jnp.pad(idx, ((0, 0), (0, 0), (0, pad_m), (0, 0))),
            expert_valid=jnp.pad(valid, ((0, 0), (0, 0), (0, pad_m), (0, 0))),
        )
    tail = ql[:, :, m_cnt * w:]                    # partial-window queries
    return state._replace(
        k_cache=jnp.pad(k[:, :, 0], ((0, 0), (0, 0), (0, capacity - n), (0, 0))),
        v_cache=jnp.pad(v[:, :, 0], ((0, 0), (0, 0), (0, capacity - n), (0, 0))),
        q_sum=jnp.sum(tail, axis=2).astype(jnp.float32),
        t=jnp.asarray(n, jnp.int32),
    )


# ------------------------------------------------- full-attention baseline --

class FullDecodeState(NamedTuple):
    k_cache: jax.Array   # [B, Hkv, C, d]
    v_cache: jax.Array
    t: jax.Array


def init_full_state(batch, n_kv, head_dim, capacity, dtype=jnp.bfloat16):
    z = lambda *s: jnp.zeros((batch, n_kv) + s, dtype)
    return FullDecodeState(k_cache=z(capacity, head_dim),
                           v_cache=z(capacity, head_dim),
                           t=jnp.zeros((), jnp.int32))


def full_prefill_state(k: jax.Array, v: jax.Array, capacity: int):
    """k, v: [B, Hkv, 1, N, d]."""
    n = k.shape[-2]
    pad = ((0, 0), (0, 0), (0, capacity - n), (0, 0))
    return FullDecodeState(k_cache=jnp.pad(k[:, :, 0], pad),
                           v_cache=jnp.pad(v[:, :, 0], pad),
                           t=jnp.asarray(n, jnp.int32))


def full_decode_step(state: FullDecodeState, q, k_new, v_new):
    """O(t) per token — the quadratic baseline MiTA replaces.
    q: [B, Hkv, G, d]; k_new/v_new: [B, Hkv, d]."""
    d = q.shape[-1]
    cap = state.k_cache.shape[-2]
    t = state.t
    kc = jax.lax.dynamic_update_slice_in_dim(
        state.k_cache, k_new[:, :, None, :].astype(state.k_cache.dtype), t, axis=2)
    vc = jax.lax.dynamic_update_slice_in_dim(
        state.v_cache, v_new[:, :, None, :].astype(state.v_cache.dtype), t, axis=2)
    logits = jnp.einsum("bhgd,bhnd->bhgn", q, kc) / math.sqrt(d)
    mask = jnp.arange(cap)[None, None, None, :] <= t
    out = combine([partial_from_scores(logits, vc, mask=mask)])
    return out, FullDecodeState(k_cache=kc, v_cache=vc, t=t + 1)


def mita_finalize_if_due(state: MiTADecodeState,
                         cfg: DecodeConfig) -> MiTADecodeState:
    """External-finalize step: call from the serving loop every ``window``
    tokens (or unconditionally — it no-ops off-boundary).  This is its own
    jitted program so the per-token decode step stays O(m + s·k + w)."""
    return jax.lax.cond(
        (state.t % cfg.window == 0) & (state.t > 0),
        lambda s: _finalize_window(s, cfg, s.t),
        lambda s: s,
        state)


def _finalize_window(state: MiTADecodeState, cfg: DecodeConfig,
                     t_new: jax.Array) -> MiTADecodeState:
    """Finalize landmark i = t_new//w - 1 from the accumulated query sum."""
    d = state.k_cache.shape[-1]
    cap = state.k_cache.shape[-2]
    i = t_new // cfg.window - 1
    q_lm = (state.q_sum / cfg.window).astype(state.k_cache.dtype)  # [B,Hkv,d]

    scores = jnp.einsum("bhnd,bhd->bhn", state.k_cache, q_lm) / math.sqrt(d)
    visible = jnp.arange(cap)[None, None, :] < t_new
    scores = jnp.where(visible, scores.astype(jnp.float32), NEG_INF)
    top_vals, top_idx = jax.lax.top_k(scores, cfg.k)        # [B,Hkv,K]
    valid = top_vals > NEG_INF / 2
    p = jax.nn.softmax(scores, axis=-1)
    v_lm = jnp.einsum("bhn,bhnd->bhd",
                      p.astype(state.v_cache.dtype), state.v_cache)

    return state._replace(
        lm_q=state.lm_q.at[:, :, i, :].set(q_lm),
        lm_v=state.lm_v.at[:, :, i, :].set(v_lm),
        expert_idx=state.expert_idx.at[:, :, i, :].set(top_idx),
        expert_valid=state.expert_valid.at[:, :, i, :].set(valid),
        q_sum=jnp.zeros_like(state.q_sum),
    )


def mita_decode_step(state: MiTADecodeState, q: jax.Array, k_new: jax.Array,
                     v_new: jax.Array, cfg: DecodeConfig) -> tuple[jax.Array, MiTADecodeState]:
    """One decode step.

    Args:
      q:     [B, Hkv, G, d] new queries (G = query heads per KV group).
      k_new: [B, Hkv, d] new key;  v_new: [B, Hkv, d] new value.
    Returns: (output [B, Hkv, G, d], updated state).
    """
    b, hkv, g, d = q.shape
    cap = state.k_cache.shape[-2]
    m_max = state.lm_q.shape[-2]
    t = state.t

    # 1. append to cache, accumulate window query sum
    state = state._replace(
        k_cache=jax.lax.dynamic_update_slice_in_dim(
            state.k_cache, k_new[:, :, None, :].astype(state.k_cache.dtype), t, axis=2),
        v_cache=jax.lax.dynamic_update_slice_in_dim(
            state.v_cache, v_new[:, :, None, :].astype(state.v_cache.dtype), t, axis=2),
        q_sum=state.q_sum + jnp.mean(q, axis=2).astype(jnp.float32),
    )
    t_new = t + 1

    # 2. finalize the window if it just completed (amortized O(t/w) per step)
    if not cfg.external_finalize:
        state = jax.lax.cond(
            t_new % cfg.window == 0,
            lambda s: _finalize_window(s, cfg, t_new),
            lambda s: s,
            state)

    # 3. attend: shared + routed + local window
    if cfg.external_finalize:
        # the serving loop finalizes at window boundaries; the last token of
        # a window does not yet see its own window's landmark
        m_cnt = t // cfg.window
    else:
        m_cnt = t_new // cfg.window  # finalized landmarks
    lm_mask = jnp.arange(m_max)[None, None, None, :] < m_cnt

    # routing / shared logits: [B, Hkv, G, M]
    r = jnp.einsum("bhgd,bhmd->bhgm", q, state.lm_q) / math.sqrt(d)
    r = jnp.where(lm_mask, r.astype(jnp.float32), NEG_INF)
    parts: list[Partial] = [partial_from_scores(r, state.lm_v)]

    # routed experts: gather s·k cache rows per (b, h, g)
    s_ = min(cfg.s, m_max)
    _, e_idx = jax.lax.top_k(r, s_)                         # [B,Hkv,G,s]
    e_ok = jnp.take_along_axis(r, e_idx, axis=-1) > NEG_INF / 2
    flat_e = e_idx.reshape(b, hkv, g * s_)
    rows = jnp.take_along_axis(
        state.expert_idx.reshape(b, hkv, m_max, cfg.k),
        flat_e[..., None], axis=2)                          # [B,Hkv,g*s,K]
    rows_valid = jnp.take_along_axis(
        state.expert_valid, flat_e[..., None], axis=2)
    rows = rows.reshape(b, hkv, g * s_ * cfg.k)
    k_sel = jnp.take_along_axis(state.k_cache, rows[..., None], axis=2)
    v_sel = jnp.take_along_axis(state.v_cache, rows[..., None], axis=2)
    k_sel = k_sel.reshape(b, hkv, g, s_ * cfg.k, d)
    v_sel = v_sel.reshape(b, hkv, g, s_ * cfg.k, d)
    logits = jnp.einsum("bhgd,bhgkd->bhgk", q, k_sel) / math.sqrt(d)
    mask = (rows_valid.reshape(b, hkv, g, s_, cfg.k)
            & e_ok[..., None]).reshape(b, hkv, g, s_ * cfg.k)
    parts.append(partial_from_logits(logits, v_sel, mask=mask))

    # local: the query's OWN window [ (t//w)*w, t ] — note t//w, not
    # t_new//w: the last token of a window still attends its window locally
    # (matching training-time `_local_partial`).
    start = (t // cfg.window) * cfg.window
    k_loc = jax.lax.dynamic_slice_in_dim(state.k_cache, start, cfg.window, axis=2)
    v_loc = jax.lax.dynamic_slice_in_dim(state.v_cache, start, cfg.window, axis=2)
    loc_logits = jnp.einsum("bhgd,bhwd->bhgw", q, k_loc) / math.sqrt(d)
    loc_mask = (jnp.arange(cfg.window)[None, None, None, :] + start) < t_new
    parts.append(partial_from_scores(loc_logits, v_loc, mask=loc_mask))

    out = combine(parts)
    return out, state._replace(t=t_new)


# ----------------------------------------------------------- paged decode --
#
# Serving-engine form of the same cache: instead of one monolithic
# [B, Hkv, C, d] cache per request batch, a single KV pool per layer is
# shared by every request.  A request owns window-aligned *pages* (page size
# == cfg.window, so one landmark per completed page); which rows a slot sees
# is entirely decided by its page table, and slots advance independently
# (per-slot t) — the continuous-batching engine (repro.serve) keeps the slot
# batch full regardless of per-request progress.
#
# Layout choices:
#   * pool rows lead ([R+1, Hkv, d]): append is a 1-row scatter at
#     rows_new[slot], gathers are plain row indexing.  Row R is a write
#     scratch for inactive slots so the step has no host-side branching.
#   * expert_idx stores GLOBAL pool rows (page_id * w + offset), assigned at
#     finalize/pack time — the decode-step gather needs no page-table lookup.


class PagedMiTAState(NamedTuple):
    """Paged decode cache for one layer, shared across S request slots.

    Shapes (R = n_pages * window pool rows, S slots,
    M = pages_per_slot = landmark capacity per slot, K expert width):
      k_pool, v_pool:   [R + 1, Hkv, d]  row R is a write scratch for
                                         inactive slots / padded tokens
      lm_q, lm_v:       [S, Hkv, M, d]   finalized landmark queries/values
      expert_idx:       [S, Hkv, M, K]   GLOBAL pool rows per expert
                                         (page_id * window + offset)
      expert_valid:     [S, Hkv, M, K]
      q_sum:            [S, Hkv, d]      running query sum, current window
                                         (f32; resumed across prefill chunks)
      pre_lm_q:         [S, Hkv, M, d]   transient PROMPT landmark queries —
                                         the training path pools the prompt's
                                         landmarks over n//m-sized windows
                                         (the `mita_prefill_state` quirk for
                                         non-window-aligned prompts), so the
                                         chunked prefill carries this second
                                         landmark-query set across chunks;
                                         dead weight after the last chunk
      pre_q_sum:        [S, Hkv, d]      running query sum of the open
                                         n//m-sized prompt window (f32)

    Ownership contract: per-slot progress (t), page tables, and activity
    live on the host and are passed into each step — the scheduler owns
    them and guarantees every page a step may WRITE (prefill rows at
    t >= the chunk's resume point, the decode append row at t) is
    referenced by exactly one slot.  Pages may be read-shared (the prefix
    cache attaches one page to many slots' tables, ref-counted), but a
    shared page is always a fully-committed prompt window that no program
    writes again: appends land past every slot's shared prefix, and the
    fused kernels' in-place aliasing only ever targets the writing slot's
    exclusively-owned page (docs/serving.md, invariant 1)."""

    k_pool: jax.Array
    v_pool: jax.Array
    lm_q: jax.Array
    lm_v: jax.Array
    expert_idx: jax.Array
    expert_valid: jax.Array
    q_sum: jax.Array
    pre_lm_q: jax.Array
    pre_q_sum: jax.Array


def init_paged_state(n_kv: int, head_dim: int, n_pages: int, n_slots: int,
                     pages_per_slot: int, cfg: DecodeConfig,
                     dtype=jnp.bfloat16) -> PagedMiTAState:
    rows = n_pages * cfg.window + 1
    return PagedMiTAState(
        k_pool=jnp.zeros((rows, n_kv, head_dim), dtype),
        v_pool=jnp.zeros((rows, n_kv, head_dim), dtype),
        lm_q=jnp.zeros((n_slots, n_kv, pages_per_slot, head_dim), dtype),
        lm_v=jnp.zeros((n_slots, n_kv, pages_per_slot, head_dim), dtype),
        expert_idx=jnp.zeros((n_slots, n_kv, pages_per_slot, cfg.k),
                             jnp.int32),
        expert_valid=jnp.zeros((n_slots, n_kv, pages_per_slot, cfg.k), bool),
        q_sum=jnp.zeros((n_slots, n_kv, head_dim), jnp.float32),
        pre_lm_q=jnp.zeros((n_slots, n_kv, pages_per_slot, head_dim), dtype),
        pre_q_sum=jnp.zeros((n_slots, n_kv, head_dim), jnp.float32),
    )


def _paged_finalize(state: PagedMiTAState, page_table: jax.Array,
                    t_new: jax.Array, due: jax.Array,
                    cfg: DecodeConfig) -> PagedMiTAState:
    """Finalize landmark i = t_new//w - 1 for every slot with due[s].

    Computed for all slots, committed where ``due`` — identical per-slot
    semantics to `_finalize_window` on a monolithic cache whose rows are the
    slot's pages in table order.

    Backend dispatch (``cfg.finalize_impl``,
    `kernels.ops.use_finalize_kernel`): the fused per-(slot, KV-head)
    Pallas kernel (`kernels.mita_paged_finalize`) when it fits the VMEM
    budget; the XLA gather path below is the fallback and the bit-exact
    oracle.
    """
    from repro.kernels import ops
    from repro.kernels.ops import gather_pages

    w = cfg.window
    n_slots, _, m_max, _ = state.expert_idx.shape
    d = state.k_pool.shape[-1]
    ctx = m_max * w

    if ops.use_finalize_kernel(
            cfg.finalize_impl, window=w, m=m_max, k_width=cfg.k, d=d,
            itemsize=state.k_pool.dtype.itemsize, budget=cfg.vmem_budget):
        lm_q, lm_v, ei, ev, q_sum = ops.paged_finalize(
            state.q_sum, state.lm_q, state.lm_v, state.expert_idx,
            state.expert_valid, state.k_pool, state.v_pool, page_table,
            t_new, due, window=w, k_width=cfg.k)
        return state._replace(lm_q=lm_q, lm_v=lm_v, expert_idx=ei,
                              expert_valid=ev.astype(bool), q_sum=q_sum)

    # gather only pages covering positions < t_new; unowned table entries
    # redirect to the scratch row (they are masked below either way)
    owned = (t_new + w - 1) // w
    k_ctx = gather_pages(state.k_pool, page_table, w, owned=owned)
    v_ctx = gather_pages(state.v_pool, page_table, w, owned=owned)
    q_lm = (state.q_sum / w).astype(state.k_pool.dtype)  # [S, Hkv, d]

    scores = jnp.einsum("schd,shd->shc", k_ctx, q_lm) / math.sqrt(d)
    visible = jnp.arange(ctx)[None, None, :] < t_new[:, None, None]
    scores = jnp.where(visible, scores.astype(jnp.float32), NEG_INF)
    top_vals, top_loc = jax.lax.top_k(scores, cfg.k)     # [S, Hkv, K] ctx idx
    valid = top_vals > NEG_INF / 2
    # ctx position -> global pool row via the page table
    ctx_rows = (page_table[:, :, None] * w
                + jnp.arange(w)[None, None, :]).reshape(n_slots, ctx)
    rows = jnp.take_along_axis(
        jnp.broadcast_to(ctx_rows[:, None, :], top_loc.shape[:-1] + (ctx,)),
        top_loc, axis=-1)
    p = jax.nn.softmax(scores, axis=-1)
    v_lm = jnp.einsum("shc,schd->shd", p.astype(state.v_pool.dtype), v_ctx)

    i = t_new // w - 1                                   # [S]
    sel = due[:, None] & (jnp.arange(m_max)[None, :] == i[:, None])  # [S, M]
    sel4 = sel[:, None, :, None]
    return state._replace(
        lm_q=jnp.where(sel4, q_lm[:, :, None, :], state.lm_q),
        lm_v=jnp.where(sel4, v_lm[:, :, None, :], state.lm_v),
        expert_idx=jnp.where(sel4, rows[:, :, None, :], state.expert_idx),
        expert_valid=jnp.where(sel4, valid[:, :, None, :], state.expert_valid),
        q_sum=jnp.where(due[:, None, None], 0.0, state.q_sum),
    )


def mita_paged_finalize(state: PagedMiTAState, page_table: jax.Array,
                        t: jax.Array, due: jax.Array,
                        cfg: DecodeConfig) -> PagedMiTAState:
    """External-finalize entry point for the serving loop (its own jitted
    program).  ``due`` comes from the scheduler: active slots whose last
    completed window has not been finalized yet (t % w == 0 and the window
    count exceeds the finalized count — the scheduler tracks the latter, so
    a freshly prefilled boundary-aligned slot is never re-finalized from a
    zero q_sum)."""
    return _paged_finalize(state, page_table, t, due, cfg)


def mita_paged_decode_step(state: PagedMiTAState, q: jax.Array,
                           k_new: jax.Array, v_new: jax.Array,
                           page_table: jax.Array, t: jax.Array,
                           active: jax.Array,
                           cfg: DecodeConfig) -> tuple[jax.Array, PagedMiTAState]:
    """One fused decode step for the whole slot batch.

    Args:
      q:          [S, Hkv, G, d] new queries.
      k_new:      [S, Hkv, d]; v_new: [S, Hkv, d].
      page_table: [S, M] int32 page ids owned by each slot (unused entries
                  must hold any in-bounds page id; they are masked).
      t:          [S] int32 tokens already in each slot's cache.
      active:     [S] bool — inactive slots write to the scratch row and
                  return zeros.
    Returns: (output [S, Hkv, G, d], updated state).  The caller advances
    ``t`` for active slots.

    This is ONE program for the whole batch regardless of per-request
    progress: positions, page tables, and activity are data, not shape.
    Scheduler invariants relied on (docs/serving.md): the page named by
    ``page_table[s, t[s] // w]`` exists for every active slot (the engine
    allocates the next page BEFORE the step that appends into it), and
    pages of distinct slots are disjoint, so the per-slot 1-row scatter
    can never race another slot's rows.

    Backend dispatch (``cfg.paged_impl``, `kernels.ops.use_paged_kernel`):
    the fused Pallas kernel (`kernels.mita_paged_attn`) replaces the
    append + gather-then-attend below when it fits the VMEM budget; the
    XLA path here stays as the fallback and the parity oracle.  Inline
    finalize needs the appended row in the pool before scoring, so in
    that mode the append/finalize run in XLA and the kernel only attends."""
    from repro.kernels import ops

    n_slots, hkv, g, d = q.shape
    w = cfg.window
    m_max = state.lm_q.shape[-2]
    scratch = state.k_pool.shape[0] - 1
    s_ = min(cfg.s, m_max)

    use_kernel = ops.use_paged_kernel(
        cfg.paged_impl, window=w, m=m_max, k_width=cfg.k, g=g, d=d,
        itemsize=state.k_pool.dtype.itemsize, budget=cfg.vmem_budget)

    # 1. append to the slot's current page, accumulate window query sum
    # (the kernel fuses the append when it also owns the attend)
    cur_page = jnp.take_along_axis(page_table, (t // w)[:, None], axis=1)[:, 0]
    rows_new = jnp.where(active, cur_page * w + t % w, scratch)
    state = state._replace(
        q_sum=state.q_sum + jnp.where(
            active[:, None, None], jnp.mean(q, axis=2).astype(jnp.float32), 0.0),
    )
    t_new = t + 1
    fuse_append = use_kernel and cfg.external_finalize
    if not fuse_append:
        state = state._replace(
            k_pool=ops.scatter_pool_rows(state.k_pool, rows_new, k_new),
            v_pool=ops.scatter_pool_rows(state.v_pool, rows_new, v_new),
        )

    # 2. finalize slots whose window just completed (masked, all-slot
    # compute).  External mode defers this to `mita_paged_finalize`, called
    # by the scheduler only on steps where some slot is actually due — the
    # hot step then stays O(m + s·k + w) per token.
    if not cfg.external_finalize:
        due = active & (t_new % w == 0)
        state = _paged_finalize(state, page_table, t_new, due, cfg)
        m_cnt = t_new // w
    else:
        m_cnt = t // w

    if use_kernel:
        out, kp, vp = ops.paged_decode_attend(
            q, k_new, v_new, state.lm_q, state.lm_v, state.expert_idx,
            state.expert_valid, state.k_pool, state.v_pool, page_table, t,
            active, m_cnt, window=w, n_route=s_, fuse_append=fuse_append)
        return out, state._replace(k_pool=kp, v_pool=vp)

    # 3. attend: shared + routed + local window (same branch math as
    # `mita_decode_step`, with every cache access routed through the pool)
    gather_pages = ops.gather_pages
    gather_pool_rows = ops.gather_pool_rows
    lm_mask = jnp.arange(m_max)[None, None, None, :] < m_cnt[:, None, None, None]
    r = jnp.einsum("shgd,shmd->shgm", q, state.lm_q) / math.sqrt(d)
    r = jnp.where(lm_mask, r.astype(jnp.float32), NEG_INF)
    parts: list[Partial] = [partial_from_scores(r, state.lm_v)]

    _, e_idx = jax.lax.top_k(r, s_)                     # [S, Hkv, G, s]
    e_ok = jnp.take_along_axis(r, e_idx, axis=-1) > NEG_INF / 2
    flat_e = e_idx.reshape(n_slots, hkv, g * s_)
    rows = jnp.take_along_axis(state.expert_idx, flat_e[..., None], axis=2)
    rows_valid = jnp.take_along_axis(state.expert_valid, flat_e[..., None],
                                     axis=2)
    rows = rows.reshape(n_slots, hkv, g * s_ * cfg.k)
    k_sel = gather_pool_rows(state.k_pool, rows).reshape(
        n_slots, hkv, g, s_ * cfg.k, d)
    v_sel = gather_pool_rows(state.v_pool, rows).reshape(
        n_slots, hkv, g, s_ * cfg.k, d)
    logits = jnp.einsum("shgd,shgkd->shgk", q, k_sel) / math.sqrt(d)
    mask = (rows_valid.reshape(n_slots, hkv, g, s_, cfg.k)
            & e_ok[..., None]).reshape(n_slots, hkv, g, s_ * cfg.k)
    parts.append(partial_from_logits(logits, v_sel, mask=mask))

    # local: the slot's own (current) page
    k_loc = jnp.swapaxes(
        gather_pages(state.k_pool, cur_page[:, None], w), 1, 2)  # [S,Hkv,w,d]
    v_loc = jnp.swapaxes(
        gather_pages(state.v_pool, cur_page[:, None], w), 1, 2)
    loc_logits = jnp.einsum("shgd,shwd->shgw", q, k_loc) / math.sqrt(d)
    start = (t // w) * w
    loc_mask = (jnp.arange(w)[None, :] + start[:, None]
                < t_new[:, None])[:, None, None, :]
    parts.append(partial_from_scores(loc_logits, v_loc, mask=loc_mask))

    out = combine(parts)
    return jnp.where(active[:, None, None, None], out, 0.0), state


def mita_paged_landmark_attend(state: PagedMiTAState, q: jax.Array,
                               m_cnt: jax.Array,
                               cfg: DecodeConfig) -> jax.Array:
    """Compressed-branch-only attention for the speculative drafter.

    The shared landmark branch alone — no expert gather, no page walk, no
    KV append, no q_sum accumulation, no state mutation of any kind.  This
    is the cheap standalone approximation MiTA's compress-and-route design
    gives away for free: a draft token costs O(m) reads of slot-resident
    landmark tiles instead of O(m + s·k + w) with two pool gathers.

    Args:
      q:      [S, Hkv, G, d] draft-position queries (RoPE'd by the caller).
      m_cnt:  [S] finalized landmark count per slot (the drafter sees the
              landmarks committed so far; any in-flight window stays
              invisible, exactly like the external-finalize decode rule).
    Returns [S, Hkv, G, d].  Slots with m_cnt == 0 attend a zero-value
    sink instead (deterministic output, no NaNs) — their drafts are
    near-random and simply get rejected at verify time.
    """
    d = q.shape[-1]
    m_max = state.lm_q.shape[-2]
    lm_mask = (jnp.arange(m_max)[None, None, None, :]
               < m_cnt[:, None, None, None])
    r = jnp.einsum("shgd,shmd->shgm", q, state.lm_q) / math.sqrt(d)
    r = jnp.where(lm_mask, r.astype(jnp.float32), NEG_INF)
    sink = partial_from_scores(
        jnp.zeros(r.shape[:-1] + (1,), jnp.float32),
        jnp.zeros_like(state.lm_v[:, :, :1]),
        mask=(m_cnt == 0)[:, None, None, None])
    return combine([partial_from_scores(r, state.lm_v), sink])


def pack_prefill_into_pages(state: PagedMiTAState, pre: MiTADecodeState,
                            slot: jax.Array, pages: jax.Array,
                            cfg: DecodeConfig) -> PagedMiTAState:
    """Copy a single-request monolithic prefill state into a slot's pages.

    Shape contract: ``pre`` has B == 1 and a window-aligned cache capacity
    C = P_used * w; ``pages`` is ``[P_used]`` int32 page ids in table order
    (token order).  KV rows land at ``pages[c // w] * w + c % w`` and expert
    indices are rebased from cache-local rows to GLOBAL pool rows, so the
    decode-step gather needs no page-table lookup afterwards.

    Scheduler invariant preserved: only ``slot``'s landmark/expert/q_sum
    entries and the rows of ``pages`` are written — a pack can never touch
    pages owned by another slot (invariant 1 in docs/serving.md).  The open
    final window's ``q_sum`` is carried into the slot, so decode (or a later
    `mita_chunk_prefill` call) resumes the window exactly where the prefill
    left it."""
    w = cfg.window
    c_pre = pre.k_cache.shape[-2]
    if c_pre % w:
        raise ValueError(f"prefill capacity {c_pre} not window-aligned")
    p_used = c_pre // w
    m_max = state.lm_q.shape[-2]
    m_pre = pre.lm_q.shape[-2]
    if p_used > m_max or m_pre > m_max:
        raise ValueError("request needs more pages than a slot owns")

    dst_rows = (pages[:, None] * w + jnp.arange(w)).reshape(-1)   # [C]
    k_rows = jnp.swapaxes(pre.k_cache[0], 0, 1)                   # [C, Hkv, d]
    v_rows = jnp.swapaxes(pre.v_cache[0], 0, 1)

    # cache-local expert rows -> global pool rows
    loc = pre.expert_idx[0]                                       # [Hkv, M', K]
    grows = pages[loc // w] * w + loc % w

    pad_m = ((0, 0), (0, m_max - m_pre), (0, 0))
    return state._replace(
        k_pool=state.k_pool.at[dst_rows].set(k_rows.astype(state.k_pool.dtype)),
        v_pool=state.v_pool.at[dst_rows].set(v_rows.astype(state.v_pool.dtype)),
        lm_q=state.lm_q.at[slot].set(
            jnp.pad(pre.lm_q[0], pad_m).astype(state.lm_q.dtype)),
        lm_v=state.lm_v.at[slot].set(
            jnp.pad(pre.lm_v[0], pad_m).astype(state.lm_v.dtype)),
        expert_idx=state.expert_idx.at[slot].set(jnp.pad(grows, pad_m)),
        expert_valid=state.expert_valid.at[slot].set(
            jnp.pad(pre.expert_valid[0], pad_m)),
        q_sum=state.q_sum.at[slot].set(pre.q_sum[0]),
    )


# --------------------------------------------------------- chunked prefill --
#
# Serving engines bound admission latency by splitting a long prompt into
# fixed-size chunks and interleaving chunk prefill with the decode batch
# (vLLM-style chunked prefill).  `mita_chunk_prefill` is the MiTA form of
# one such chunk: it appends the chunk's KV rows to the slot's pages,
# finalizes every landmark window the chunk completes (scores over the
# WHOLE gathered past, exactly like `_finalize_window`), resumes the open
# window's query sum across chunk boundaries, and computes the chunk's
# attention outputs so the model forward over the chunk is exact.
#
# The same op is the recompute path for preemption: a preempted request is
# rebuilt by chunk-prefilling prompt + generated tokens.  Because decode ran
# with a given finalize mode, positions >= n_train replicate the DECODE
# availability rule (external mode: the last token of a window routes one
# expert stale) while positions < n_train replicate the training/prefill
# rule — so the rebuilt state continues bit-compatibly with the state the
# request had when it was evicted.


def mita_chunk_prefill(state: PagedMiTAState, q: jax.Array, k: jax.Array,
                       v: jax.Array, page_table: jax.Array, slot: jax.Array,
                       t0: jax.Array, n_valid: jax.Array, n_train: jax.Array,
                       cfg: DecodeConfig) -> tuple[jax.Array, PagedMiTAState]:
    """Prefill one chunk of a single slot's prompt into the paged pool.

    Args:
      q:          [Hkv, G, nc, d] chunk queries (RoPE'd at positions
                  ``t0 + arange(nc)``).
      k, v:       [Hkv, nc, d] chunk keys/values.
      page_table: [M] int32 — the slot's page-table row.  Pages covering
                  positions < t0 + n_valid must already be allocated.
      slot:       scalar int32 — which slot's landmark/expert/q_sum to edit.
      t0:         scalar int32 — tokens already packed for this slot (the
                  chunk covers positions [t0, t0 + n_valid)).  Need NOT be
                  window-aligned: an open window is resumed from the slot's
                  ``q_sum``.
      n_valid:    scalar int32 — valid tokens in the chunk; positions >=
                  n_valid are padding (their KV rows go to the scratch row,
                  their outputs are garbage and must be ignored).
      n_train:    scalar int32 — training/decode semantics boundary.  For a
                  fresh prompt pass t0 + n_valid (everything is "prompt");
                  for preemption recompute pass the ORIGINAL prompt length
                  so recomputed generated positions see landmarks exactly as
                  the decode step did (external-finalize staleness included).

    Returns (out [Hkv, G, nc, d], updated state).  One compiled program per
    chunk shape serves every chunk of every request — chunk index, length
    and resume point are data.

    Scheduler invariants preserved: writes touch only ``slot``'s state rows,
    the rows of pages named by ``page_table``, and the scratch row; landmark
    i of the slot summarizes exactly the tokens of ``page_table[i]``.
    """
    from repro.kernels.ops import gather_pages, gather_pool_rows

    w = cfg.window
    hkv, g, nc, d = q.shape
    m_slot = page_table.shape[0]
    ctx = m_slot * w
    scratch = state.k_pool.shape[0] - 1

    pos = t0 + jnp.arange(nc)                       # [nc] global positions
    valid_tok = jnp.arange(nc) < n_valid            # [nc]

    # 1. append chunk KV to the slot's pages (padding -> scratch row)
    page_idx = jnp.clip(pos // w, 0, m_slot - 1)
    dst = jnp.where(valid_tok, page_table[page_idx] * w + pos % w, scratch)
    kp = state.k_pool.at[dst].set(
        jnp.swapaxes(k, 0, 1).astype(state.k_pool.dtype))
    vp = state.v_pool.at[dst].set(
        jnp.swapaxes(v, 0, 1).astype(state.v_pool.dtype))

    # gathered slot context in token order: [ctx, Hkv, d] — only pages
    # covering positions < t0 + n_valid are real; later table entries
    # redirect to the scratch row (all reads past the valid prefix are
    # masked below, so this only avoids gathering unowned pages)
    owned = ((t0 + n_valid + w - 1) // w)[None]
    k_ctx = gather_pages(kp, page_table[None], w, owned=owned)[0]
    v_ctx = gather_pages(vp, page_table[None], w, owned=owned)[0]

    # 2. finalize every window the chunk completes (windows [m0, m_new)),
    # resuming the open window's query sum from the previous chunk
    m0 = t0 // w
    m_new = (t0 + n_valid) // w
    li = jnp.arange(m_slot)                         # landmark slot ids [M]
    ql = jnp.mean(q, axis=1)                        # [Hkv, nc, d] group pool
    win_of = pos // w
    tok_in_win = valid_tok[None, :] & (win_of[None, :] == li[:, None])
    sums = jnp.einsum("mn,hnd->hmd", tok_in_win.astype(jnp.float32),
                      ql.astype(jnp.float32))       # [Hkv, M, d]
    resume = (li == m0)[None, :, None] & (t0 % w != 0)
    sums = sums + jnp.where(resume, state.q_sum[slot][:, None, :], 0.0)

    q_lm_new = (sums / w).astype(kp.dtype)          # [Hkv, M, d]
    ends = (li + 1) * w                             # [M] strict window ends
    s_lm = jnp.einsum("chd,hmd->hmc", k_ctx, q_lm_new) / math.sqrt(d)
    vis = jnp.arange(ctx)[None, None, :] < ends[None, :, None]
    s_lm = jnp.where(vis, s_lm.astype(jnp.float32), NEG_INF)
    top_vals, top_loc = jax.lax.top_k(s_lm, cfg.k)  # [Hkv, M, K] ctx idx
    new_valid = top_vals > NEG_INF / 2
    ctx_rows = (page_table[:, None] * w + jnp.arange(w)[None, :]).reshape(ctx)
    new_rows = ctx_rows[top_loc]                    # ctx idx -> global rows
    p_lm = jax.nn.softmax(s_lm, axis=-1)
    v_lm_new = jnp.einsum("hmc,chd->hmd", p_lm.astype(vp.dtype), v_ctx)

    commit = ((li >= m0) & (li < m_new))[None, :, None]
    lm_q_s = jnp.where(commit, q_lm_new, state.lm_q[slot])
    lm_v_s = jnp.where(commit, v_lm_new, state.lm_v[slot])
    ei_s = jnp.where(commit, new_rows, state.expert_idx[slot])
    ev_s = jnp.where(commit, new_valid, state.expert_valid[slot])
    # open window after the chunk: tail of this chunk, plus the resumed sum
    # if the chunk closed no window at all
    tail = jnp.einsum("n,hnd->hd",
                      (valid_tok & (win_of == m_new)).astype(jnp.float32),
                      ql.astype(jnp.float32))
    q_sum_s = tail + jnp.where((m_new == m0) & (t0 % w != 0),
                               state.q_sum[slot], 0.0)

    # 3. chunk attention: shared + routed + local, same branch math as the
    # training path / decode step, with per-position landmark availability
    is_train = (pos < n_train)[:, None]             # [nc, 1]
    avail_train = ends[None, :] <= pos[:, None] + 1
    avail_dec = ends[None, :] <= pos[:, None] if cfg.external_finalize \
        else avail_train
    avail = jnp.where(is_train, avail_train, avail_dec)   # [nc, M]

    r = jnp.einsum("hgnd,hmd->hgnm", q, lm_q_s) / math.sqrt(d)
    r = jnp.where(avail[None, None], r.astype(jnp.float32), NEG_INF)
    parts: list[Partial] = [partial_from_scores(r, lm_v_s[:, None])]

    s_ = min(cfg.s, m_slot)
    _, e_idx = jax.lax.top_k(r, s_)                 # [Hkv, G, nc, s]
    e_ok = jnp.take_along_axis(r, e_idx, axis=-1) > NEG_INF / 2
    flat_e = e_idx.reshape(hkv, g * nc * s_)
    rows = jnp.take_along_axis(ei_s, flat_e[..., None], axis=1)
    rows_valid = jnp.take_along_axis(ev_s, flat_e[..., None], axis=1)
    rows = rows.reshape(hkv, g * nc * s_ * cfg.k)
    k_sel = gather_pool_rows(kp, rows[None])[0].reshape(
        hkv, g, nc, s_ * cfg.k, d)
    v_sel = gather_pool_rows(vp, rows[None])[0].reshape(
        hkv, g, nc, s_ * cfg.k, d)
    logits = jnp.einsum("hgnd,hgnkd->hgnk", q, k_sel) / math.sqrt(d)
    mask = (rows_valid.reshape(hkv, g, nc, s_, cfg.k)
            & e_ok[..., None]).reshape(hkv, g, nc, s_ * cfg.k)
    parts.append(partial_from_logits(logits, v_sel, mask=mask))

    # local: each chunk position attends its own window, which may start in
    # a previous chunk (resume) — the gathered context covers both
    loc_idx = (jnp.clip(pos // w, 0, m_slot - 1) * w)[:, None] \
        + jnp.arange(w)[None, :]                    # [nc, w] ctx positions
    k_loc = jnp.moveaxis(k_ctx[loc_idx], 2, 0)      # [Hkv, nc, w, d]
    v_loc = jnp.moveaxis(v_ctx[loc_idx], 2, 0)
    loc_logits = jnp.einsum("hgnd,hnwd->hgnw", q, k_loc) / math.sqrt(d)
    loc_mask = (loc_idx <= pos[:, None])[None, None]
    parts.append(partial_from_logits(loc_logits, v_loc[:, None],
                                     mask=loc_mask))

    out = combine(parts)
    return out, state._replace(
        k_pool=kp, v_pool=vp,
        lm_q=state.lm_q.at[slot].set(lm_q_s),
        lm_v=state.lm_v.at[slot].set(lm_v_s),
        expert_idx=state.expert_idx.at[slot].set(ei_s),
        expert_valid=state.expert_valid.at[slot].set(ev_s),
        q_sum=state.q_sum.at[slot].set(q_sum_s),
    )


# ------------------------------------------------- batched chunked prefill --
#
# `mita_batched_chunk_prefill` advances ONE window-aligned chunk for EVERY
# currently-prefilling slot in a single program — the serving engine's
# prefill work per step is then one dispatch of one compiled shape no matter
# how many requests are mid-prefill.  Which slots advance, their resume
# points, chunk validity, and the training/decode semantics boundary are all
# data ([S] vectors); inactive rows write only to the scratch row and pass
# their slot state through untouched.
#
# Unlike the single-slot op above, this one also serves NON-window-aligned
# prompts, replicating the monolithic head exactly so the engine needs no
# monolithic fallback.  The monolithic path has a quirk worth naming: for a
# prompt of n tokens the *training-path forward* (`attention_apply`) pools
# m = n // w landmark queries over windows of w' = n // m tokens and masks
# landmark visibility at (i+1) * w' — while `mita_prefill_state` builds the
# DECODE cache's landmarks from exact w-token query windows scored against
# the same (i+1) * w' key ends.  Both systems are therefore maintained per
# chunk:
#
#   * the "A" system (prompt positions < n_train): w'-pooled landmark
#     queries carried in `pre_lm_q` / `pre_q_sum`; landmark values and
#     expert tiles are recomputed each chunk from the gathered context
#     (append-only pages make the recompute exact), feeding the chunk's
#     attention outputs so the forward over the prompt equals the training
#     path, chunk boundaries notwithstanding;
#   * the "B" system (the decode cache): w-pooled landmark queries committed
#     into `lm_q` as soon as their query window completes, scores/values/
#     expert rows committed once the (i+1) * w' key context exists — for
#     window-aligned prompts w' == w and both systems coincide with the
#     single-slot op above.
#
# Generated positions (>= n_train, the preemption-recompute shape) attend
# through the B system with decode-time landmark availability, exactly like
# the single-slot op.  Backend dispatch (`cfg.prefill_impl`,
# `kernels.ops.use_prefill_kernel`): the fused Pallas kernel
# (`kernels.mita_chunk_prefill`) replaces this XLA path when its working set
# fits the VMEM budget; the XLA path stays as fallback and bit-exact oracle.


def _quirk_windows(n_train: jax.Array, w: int):
    """Per-slot prompt landmark structure: (m_train, m_a, w_a) where
    ``m_train`` counts the decode cache's w-sized prompt windows, and the
    training forward pools ``m_a = max(1, m_train)`` landmarks over
    ``w_a = n_train // m_a``-sized windows (the n//m quirk; w_a == w for
    window-aligned prompts).  All int32, safe for n_train == 0 rows."""
    m_train = n_train // w
    m_a = jnp.maximum(m_train, 1)
    w_a = jnp.maximum(n_train // m_a, 1)
    return m_train, m_a, w_a


def mita_batched_chunk_prefill(state: PagedMiTAState, q: jax.Array,
                               k: jax.Array, v: jax.Array,
                               page_table: jax.Array, slots: jax.Array,
                               t0: jax.Array, n_valid: jax.Array,
                               n_train: jax.Array, active: jax.Array,
                               cfg: DecodeConfig
                               ) -> tuple[jax.Array, PagedMiTAState]:
    """Prefill one chunk for every active row in one fused program.

    Rows are *jobs*, not slots: the engine packs the currently-prefilling
    slots (padded with DISTINCT idle slots to a fixed width P) so compute
    scales with the number of prefilling requests, not the slot-batch
    width.  All per-row quantities are data; P is the only shape.

    Args:
      q:          [P, Hkv, G, nc, d] chunk queries per row (RoPE'd at
                  positions ``t0[p] + arange(nc)``; garbage for inactive
                  rows).
      k, v:       [P, Hkv, nc, d] chunk keys/values.
      page_table: [P, M] int32 — each row's slot's page-table row.  Pages
                  covering positions < t0 + n_valid must be allocated.
      slots:      [P] int32 UNIQUE slot ids (duplicates would make the
                  state write-back order undefined).
      t0:         [P] int32 resume points (tokens already packed; always a
                  multiple of the chunk length, hence window-aligned).
      n_valid:    [P] int32 valid tokens per row; padding past it lands in
                  the scratch row and yields garbage outputs.
      n_train:    [P] int32 training/decode semantics boundary (original
                  prompt length) — positions >= n_train replicate decode-
                  time landmark availability, exactly as the single-slot op.
      active:     [P] bool — inactive rows leave every piece of their
                  slot's state (and every owned page) bit-identical.

    Returns (out [P, Hkv, G, nc, d], updated state).
    """
    from repro.kernels import ops

    w = cfg.window
    _, _, g, nc, d = q.shape
    m_slot = page_table.shape[1]
    s_ = min(cfg.s, m_slot)
    pdt = state.k_pool.dtype

    # gather the rows' slot state once; both backends compute compact
    # [P, ...] updates that are scattered back below
    lm_q_r = state.lm_q[slots]
    lm_v_r = state.lm_v[slots]
    ei_r = state.expert_idx[slots]
    ev_r = state.expert_valid[slots]
    qs_r = state.q_sum[slots]
    plm_r = state.pre_lm_q[slots]
    pqs_r = state.pre_q_sum[slots]

    if ops.use_prefill_kernel(
            cfg.prefill_impl, nc=nc, window=w, m=m_slot, k_width=cfg.k,
            g=g, d=d, itemsize=pdt.itemsize, budget=cfg.vmem_budget):
        # the budget also sizes the local-branch tile (static: a budget
        # change retraces, mirroring the dispatch decision itself)
        q_block = ops.select_prefill_q_block(
            nc, w, m_slot, cfg.k, g, d, itemsize=pdt.itemsize,
            budget=cfg.vmem_budget) or 0
        (out, lm_q_n, lm_v_n, ei_n, ev_n, qs_n, plm_n, pqs_n, kp, vp) = \
            ops.batched_chunk_prefill(
                q, k, v, lm_q_r, lm_v_r, ei_r, ev_r, qs_r, plm_r, pqs_r,
                state.k_pool, state.v_pool, page_table, t0, n_valid,
                n_train, active, window=w, k_width=cfg.k, n_route=s_,
                external_finalize=cfg.external_finalize, q_block=q_block)
        ev_n = ev_n.astype(bool)
    else:
        (out, lm_q_n, lm_v_n, ei_n, ev_n, qs_n, plm_n, pqs_n, kp, vp) = \
            _batched_chunk_prefill_xla(
                state.k_pool, state.v_pool, q, k, v, lm_q_r, lm_v_r, ei_r,
                ev_r, qs_r, plm_r, pqs_r, page_table, t0, n_valid, n_train,
                active, cfg)

    return out, state._replace(
        k_pool=kp, v_pool=vp,
        lm_q=state.lm_q.at[slots].set(lm_q_n),
        lm_v=state.lm_v.at[slots].set(lm_v_n),
        expert_idx=state.expert_idx.at[slots].set(ei_n),
        expert_valid=state.expert_valid.at[slots].set(ev_n),
        q_sum=state.q_sum.at[slots].set(qs_n),
        pre_lm_q=state.pre_lm_q.at[slots].set(plm_n),
        pre_q_sum=state.pre_q_sum.at[slots].set(pqs_n))


def _batched_chunk_prefill_xla(k_pool, v_pool, q, k, v, lm_q_r, lm_v_r,
                               ei_r, ev_r, qs_r, plm_r, pqs_r, page_table,
                               t0, n_valid, n_train, active,
                               cfg: DecodeConfig):
    """XLA path of `mita_batched_chunk_prefill` — the fallback and the
    bit-exact oracle of the fused kernel.  The A-system (training-head)
    and B-system (decode-cache) attention branches are gated behind
    `lax.cond`s on whether any row has prompt / generated positions, so a
    fresh-prompt chunk pays one attention pass, not two; the skipped
    branch's partials are empty (m = -inf, l = 0), which the per-position
    selection discards — bit-identical to computing both."""
    w = cfg.window
    p_rows, hkv, g, nc, d = q.shape
    m_slot = page_table.shape[1]
    ctx = m_slot * w
    scratch = k_pool.shape[0] - 1
    s_ = min(cfg.s, m_slot)
    pdt = k_pool.dtype
    from repro.kernels import ops

    t0 = t0.astype(jnp.int32)
    n_valid = n_valid.astype(jnp.int32)
    n_train = n_train.astype(jnp.int32)
    pos = t0[:, None] + jnp.arange(nc)                  # [P, nc]
    valid = (jnp.arange(nc)[None, :] < n_valid[:, None]) & active[:, None]
    li = jnp.arange(m_slot)                             # landmark ids [M]
    cpos = jnp.arange(ctx)                              # context positions
    m_train, m_a, w_a = _quirk_windows(n_train, w)

    # 1. append chunk KV to the rows' pages (padding/inactive -> scratch).
    # Page ordinal == pos // w, so a token's context index IS its position.
    page_idx = jnp.clip(pos // w, 0, m_slot - 1)
    dst = jnp.where(valid,
                    jnp.take_along_axis(page_table, page_idx, axis=1) * w
                    + pos % w, scratch)
    kp = k_pool.at[dst.reshape(-1)].set(
        jnp.swapaxes(k, 1, 2).reshape(-1, hkv, d).astype(pdt))
    vp = v_pool.at[dst.reshape(-1)].set(
        jnp.swapaxes(v, 1, 2).reshape(-1, hkv, d).astype(pdt))

    # gathered per-row context in token order; unowned table entries
    # redirect to the scratch row (reads past the valid prefix are masked
    # or zero-weighted below either way)
    owned = (t0 + n_valid + w - 1) // w
    k_ctx = ops.gather_pages(kp, page_table, w, owned=owned)  # [P,ctx,Hkv,d]
    v_ctx = ops.gather_pages(vp, page_table, w, owned=owned)

    ql32 = jnp.mean(q, axis=2).astype(jnp.float32)      # [P, Hkv, nc, d]

    # 2. B system — the decode cache.  Landmark queries commit as soon as
    # their w-token query window completes; scores/values/expert rows
    # commit once the window's key end exists (ends differ only under the
    # non-aligned n//m quirk, where a prompt landmark's key context extends
    # (i+1)*(w_a - w) tokens past its query window).
    win_b = pos // w
    tok_b = valid[:, None, :] & (win_b[:, None, :] == li[None, :, None])
    sums_b = jnp.einsum("smn,shnd->shmd", tok_b.astype(jnp.float32), ql32)
    m0 = t0 // w
    resume_b = (li[None, :] == m0[:, None]) & (t0 % w != 0)[:, None]
    sums_b = sums_b + jnp.where(resume_b[:, None, :, None],
                                qs_r[:, :, None, :], 0.0)
    q_lm_b = (sums_b / w).astype(pdt)                   # [P, Hkv, M, d]
    wend = (li + 1) * w                                 # [M]
    new_end = t0 + n_valid
    qdone_b = (active[:, None] & (wend[None, :] > t0[:, None])
               & (wend[None, :] <= new_end[:, None]))
    lm_q_s = jnp.where(qdone_b[:, None, :, None], q_lm_b, lm_q_r)

    ends_b = jnp.where(li[None, :] < m_train[:, None],
                       (li[None, :] + 1) * w_a[:, None], wend[None, :])
    s_b = jnp.einsum("schd,shmd->shmc", k_ctx, lm_q_s) / math.sqrt(d)
    vis_b = cpos[None, None, :] < ends_b[:, :, None]
    s_b = jnp.where(vis_b[:, None], s_b.astype(jnp.float32), NEG_INF)
    top_vals, top_loc = jax.lax.top_k(s_b, cfg.k)       # [P, Hkv, M, K]
    new_valid = top_vals > NEG_INF / 2
    ctx_rows = (page_table[:, :, None] * w
                + jnp.arange(w)[None, None, :]).reshape(p_rows, ctx)
    new_rows = jnp.take_along_axis(
        jnp.broadcast_to(ctx_rows[:, None, None, :],
                         (p_rows, hkv, m_slot, ctx)), top_loc, axis=-1)
    p_b = jax.nn.softmax(s_b, axis=-1)
    v_lm_b = jnp.einsum("shmc,schd->shmd", p_b.astype(pdt), v_ctx)
    scommit = (active[:, None] & (ends_b > t0[:, None])
               & (ends_b <= new_end[:, None]))
    sc4 = scommit[:, None, :, None]
    lm_v_s = jnp.where(sc4, v_lm_b, lm_v_r)
    ei_s = jnp.where(sc4, new_rows, ei_r)
    ev_s = jnp.where(sc4, new_valid, ev_r)

    # open-window sum == the open row of the sums matrix (the resume
    # contribution already sits inside row m0), selected so the kernel's
    # row-select reproduces it bit-exactly; rows past M mean an exactly
    # full slot, whose open window is empty
    m_new = new_end // w
    q_sum_s = jnp.sum(jnp.where(
        (li[None, :] == m_new[:, None])[:, None, :, None], sums_b, 0.0),
        axis=2)
    q_sum_s = jnp.where(active[:, None, None], q_sum_s, qs_r)

    is_tr = pos < n_train[:, None]
    any_tr = jnp.any(valid & is_tr)
    any_gen = jnp.any(valid & ~is_tr)
    k_ctx_h = jnp.swapaxes(k_ctx, 1, 2)                 # [P, Hkv, ctx, d]
    v_ctx_h = jnp.swapaxes(v_ctx, 1, 2)

    def shared_routed(lm_q_sys, lm_v_sys, avail):
        r = jnp.einsum("shgnd,shmd->shgnm", q, lm_q_sys) / math.sqrt(d)
        r = jnp.where(avail[:, None, None], r.astype(jnp.float32), NEG_INF)
        shared = partial_from_scores(r, lm_v_sys[:, :, None])
        _, e_idx = jax.lax.top_k(r, s_)                 # [P, Hkv, G, nc, s]
        e_ok = jnp.take_along_axis(r, e_idx, axis=-1) > NEG_INF / 2
        return shared, e_idx, e_ok

    def empty_partials(_):
        zo = jnp.zeros((p_rows, hkv, g, nc, d), pdt)
        zm = jnp.full((p_rows, hkv, g, nc), NEG_INF, jnp.float32)
        zl = jnp.zeros((p_rows, hkv, g, nc), jnp.float32)
        return (zo, zm, zl), (zo, zm, zl)

    # 3. A system — the transient prompt-forward landmarks (w_a-pooled).
    # Values/expert tiles are recomputed from the gathered context each
    # chunk (pages are append-only, so the recompute is exact); only the
    # pooled queries and the open-window sum cross chunk boundaries.
    win_a = pos // w_a[:, None]
    tok_a = ((valid & is_tr)[:, None, :]
             & (win_a[:, None, :] == li[None, :, None]))
    sums_a = jnp.einsum("smn,shnd->shmd", tok_a.astype(jnp.float32), ql32)
    m0_a = t0 // w_a
    resume_a = ((li[None, :] == m0_a[:, None])
                & ((t0 % w_a != 0) & (t0 < n_train))[:, None])
    sums_a = sums_a + jnp.where(resume_a[:, None, :, None],
                                pqs_r[:, :, None, :], 0.0)
    q_lm_a = (sums_a / w_a[:, None, None, None].astype(jnp.float32)
              ).astype(pdt)
    ends_a = (li[None, :] + 1) * w_a[:, None]           # [P, M]
    qdone_a = (active[:, None] & (ends_a > t0[:, None])
               & (ends_a <= new_end[:, None])
               & (li[None, :] < m_a[:, None]))
    pre_lm_q_s = jnp.where(qdone_a[:, None, :, None], q_lm_a, plm_r)

    open_a = new_end // w_a
    pre_q_sum_s = jnp.sum(jnp.where(
        (li[None, :] == open_a[:, None])[:, None, :, None], sums_a, 0.0),
        axis=2)
    pre_q_sum_s = jnp.where(active[:, None, None], pre_q_sum_s, pqs_r)

    def a_products(_):
        """A-system landmark scores/values/expert locations — the quirk
        build (w_a != w somewhere in the batch)."""
        s_a = jnp.einsum("schd,shmd->shmc", k_ctx, pre_lm_q_s) / math.sqrt(d)
        vis_a = ((cpos[None, None, :] < ends_a[:, :, None])
                 & (li[None, :, None] < m_a[:, None, None]))
        s_a = jnp.where(vis_a[:, None], s_a.astype(jnp.float32), NEG_INF)
        tv_a, tl_a = jax.lax.top_k(s_a, cfg.k)          # [P, Hkv, M, K]
        p_a = jax.nn.softmax(s_a, axis=-1)
        v_lm_a = jnp.einsum("shmc,schd->shmd", p_a.astype(pdt), v_ctx)
        return v_lm_a, tl_a, tv_a > NEG_INF / 2

    def a_reuse(_):
        """All rows window-aligned: the A system IS the B system (same
        pooled queries, same ends), so reuse its products.  Rows at
        landmark ids >= m_a (generated windows) differ, but every read of
        them is availability-masked to an exact-zero contribution."""
        return v_lm_b, top_loc, new_valid

    def a_branches(_):
        """A-system shared/routed partials for prompt positions (skipped
        when the chunk has none)."""
        quirky = jnp.any(active & (n_train % w != 0))
        v_lm_a, tl_a, val_a = jax.lax.cond(quirky, a_products, a_reuse,
                                           None)
        flat_tl = tl_a.reshape(p_rows, hkv, m_slot * cfg.k)
        k_e_a = jnp.take_along_axis(k_ctx_h, flat_tl[..., None], axis=2
                                    ).reshape(p_rows, hkv, m_slot, cfg.k, d)
        v_e_a = jnp.take_along_axis(v_ctx_h, flat_tl[..., None], axis=2
                                    ).reshape(p_rows, hkv, m_slot, cfg.k, d)

        avail_a = ((ends_a[:, None, :] <= pos[:, :, None] + 1)
                   & (li[None, None, :] < m_a[:, None, None])
                   & is_tr[:, :, None])
        shared_a, e_a, eok_a = shared_routed(pre_lm_q_s, v_lm_a, avail_a)
        fe_a = e_a.reshape(p_rows, hkv, g * nc * s_)
        k_sel = jnp.take_along_axis(
            k_e_a.reshape(p_rows, hkv, m_slot, cfg.k * d), fe_a[..., None],
            axis=2).reshape(p_rows, hkv, g, nc, s_ * cfg.k, d)
        v_sel = jnp.take_along_axis(
            v_e_a.reshape(p_rows, hkv, m_slot, cfg.k * d), fe_a[..., None],
            axis=2).reshape(p_rows, hkv, g, nc, s_ * cfg.k, d)
        va_sel = jnp.take_along_axis(
            val_a, fe_a[..., None], axis=2).reshape(p_rows, hkv, g, nc, s_,
                                                    cfg.k)
        lg = jnp.einsum("shgnd,shgnkd->shgnk", q, k_sel) / math.sqrt(d)
        routed_a = partial_from_logits(
            lg, v_sel,
            mask=(va_sel & eok_a[..., None]).reshape(p_rows, hkv, g, nc,
                                                     s_ * cfg.k))
        return ((shared_a.o, shared_a.m, shared_a.l),
                (routed_a.o, routed_a.m, routed_a.l))

    def b_branches(_):
        """B-system shared/routed partials for generated positions — the
        preemption-recompute shape (skipped for fresh-prompt chunks)."""
        off = 0 if cfg.external_finalize else 1
        avail_b = ((wend[None, None, :] <= pos[:, :, None] + off)
                   & ~is_tr[:, :, None])
        shared_b, e_b, eok_b = shared_routed(lm_q_s, lm_v_s, avail_b)
        fe_b = e_b.reshape(p_rows, hkv, g * nc * s_)
        rows_b = jnp.take_along_axis(ei_s, fe_b[..., None], axis=2)
        rv_b = jnp.take_along_axis(ev_s, fe_b[..., None], axis=2)
        k_sel = ops.gather_pool_rows(
            kp, rows_b.reshape(p_rows, hkv, -1)).reshape(
            p_rows, hkv, g, nc, s_ * cfg.k, d)
        v_sel = ops.gather_pool_rows(
            vp, rows_b.reshape(p_rows, hkv, -1)).reshape(
            p_rows, hkv, g, nc, s_ * cfg.k, d)
        lg = jnp.einsum("shgnd,shgnkd->shgnk", q, k_sel) / math.sqrt(d)
        routed_b = partial_from_logits(
            lg, v_sel,
            mask=(rv_b.reshape(p_rows, hkv, g, nc, s_, cfg.k)
                  & eok_b[..., None]).reshape(p_rows, hkv, g, nc,
                                              s_ * cfg.k))
        return ((shared_b.o, shared_b.m, shared_b.l),
                (routed_b.o, routed_b.m, routed_b.l))

    sh_a, ro_a = jax.lax.cond(any_tr, a_branches, empty_partials, None)
    sh_b, ro_b = jax.lax.cond(any_gen, b_branches, empty_partials, None)

    # local: each position attends its own window [start, pos] (w_a-sized
    # inside the prompt, w-sized outside; w_a <= 2w - 1, so a 2w-wide
    # per-position gather from the context covers both)
    lw = 2 * w
    start = jnp.where(is_tr, win_a * w_a[:, None], (pos // w) * w)
    loc_pos = start[:, :, None] + jnp.arange(lw)[None, None, :]  # [P,nc,2w]
    loc_idx = jnp.clip(loc_pos, 0, ctx - 1)
    k_loc = jnp.take_along_axis(
        k_ctx_h, loc_idx.reshape(p_rows, 1, nc * lw, 1),
        axis=2).reshape(p_rows, hkv, nc, lw, d)
    v_loc = jnp.take_along_axis(
        v_ctx_h, loc_idx.reshape(p_rows, 1, nc * lw, 1),
        axis=2).reshape(p_rows, hkv, nc, lw, d)
    s_loc = jnp.einsum("shgnd,shnwd->shgnw", q, k_loc) / math.sqrt(d)
    local = partial_from_logits(
        s_loc, v_loc[:, :, None],
        mask=(loc_pos <= pos[:, :, None])[:, None, None])

    sel = is_tr[:, None, None, :]                       # over [P, H, G, nc]

    def pick(pa, pb):
        return Partial(o=jnp.where(sel[..., None], pa[0], pb[0]),
                       m=jnp.where(sel, pa[1], pb[1]),
                       l=jnp.where(sel, pa[2], pb[2]))

    out = combine([pick(sh_a, sh_b), pick(ro_a, ro_b), local])
    out = jnp.where(active[:, None, None, None, None], out, 0.0)
    return (out, lm_q_s, lm_v_s, ei_s, ev_s, q_sum_s, pre_lm_q_s,
            pre_q_sum_s, kp, vp)
