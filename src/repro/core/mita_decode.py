"""Incremental (decode-time) MiTA — our LM-serving adaptation.

The paper (§D) defers LLM decoding to future work; this module supplies it.
The key observation: the landmark/expert structures of causal MiTA depend
only on *completed* windows, so they can be maintained incrementally next to
the KV cache:

  * every step appends (k, v) to the cache and accumulates the query into a
    running window sum;
  * every ``window`` steps the just-completed window is *finalized*: its
    landmark query (mean of the window's queries), landmark value
    (cross-attention over the whole past), and top-k expert indices are
    computed once — O(t·d) work amortized to O(t·d/window) per token;
  * each decoded token then attends to: the shared expert (all finalized
    landmark pairs, ≤ m_max), its top-s routed experts (s·k gathered cache
    rows), and the local causal window — O(m_max + s·k + window) per token,
    which is what makes 500k-token decode lowerable.

State is per layer; models stack states over layers (scan axis 0).
Landmarks are shared per KV-head group (DESIGN.md GQA adaptation).
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.combine import (NEG_INF, Partial, combine,
                                partial_from_logits, partial_from_scores)


class MiTADecodeState(NamedTuple):
    """Decode-time cache for one attention layer.

    Shapes (B batch, Hkv KV heads, C cache capacity, d head dim,
    M = C // window landmark capacity, K expert width):
      k_cache, v_cache: [B, Hkv, C, d]
      lm_q, lm_v:       [B, Hkv, M, d]   finalized landmark queries/values
      expert_idx:       [B, Hkv, M, K]   gathered top-k cache rows per expert
      expert_valid:     [B, Hkv, M, K]
      q_sum:            [B, Hkv, d]      running query sum, current window
      t:                []               tokens currently in the cache
    """

    k_cache: jax.Array
    v_cache: jax.Array
    lm_q: jax.Array
    lm_v: jax.Array
    expert_idx: jax.Array
    expert_valid: jax.Array
    q_sum: jax.Array
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class DecodeConfig:
    window: int          # w — landmark window size (train-time N/m)
    k: int               # expert width
    s: int = 1           # routed experts per query
    capacity: int = 0    # C — cache capacity (set by init)
    # Externalize the every-w-steps landmark finalize into its own jitted
    # step (`mita_finalize_if_due`), called by the serving loop at window
    # boundaries.  The per-token decode step then carries no O(context)
    # branch (§Perf: the lax.cond finalize dominated the decode cell's
    # collective/memory terms even though it runs 1/w of steps).  Semantics
    # vs inline: the last token of each window routes among j instead of
    # j+1 experts (1/w of tokens, one-expert-stale routing).
    external_finalize: bool = False


def init_decode_state(batch: int, n_kv: int, head_dim: int, capacity: int,
                      cfg: DecodeConfig, dtype=jnp.bfloat16) -> MiTADecodeState:
    m_max = capacity // cfg.window
    z = lambda *s: jnp.zeros((batch, n_kv) + s, dtype)
    return MiTADecodeState(
        k_cache=z(capacity, head_dim), v_cache=z(capacity, head_dim),
        lm_q=z(m_max, head_dim), lm_v=z(m_max, head_dim),
        expert_idx=jnp.zeros((batch, n_kv, m_max, cfg.k), jnp.int32),
        expert_valid=jnp.zeros((batch, n_kv, m_max, cfg.k), bool),
        q_sum=jnp.zeros((batch, n_kv, head_dim), jnp.float32),
        t=jnp.zeros((), jnp.int32),
    )


def mita_prefill_state(q: jax.Array, k: jax.Array, v: jax.Array,
                       cfg: DecodeConfig, capacity: int) -> MiTADecodeState:
    """Build a decode state from a full-sequence prefill.

    q: [B, Hkv, G, N, d]; k, v: [B, Hkv, 1, N, d].  Landmark/expert caches
    are computed with the training-path functions so decode continues
    *exactly* where training-time causal MiTA leaves off.
    """
    from repro.core import mita as mref

    b, hkv, _, n, d = q.shape
    w = cfg.window
    m_cnt = n // w
    m_max = capacity // w
    dtype = k.dtype

    ql = jnp.mean(q, axis=2)                       # [B, Hkv, N, d] group-pool
    state = init_decode_state(b, hkv, d, capacity, cfg, dtype=dtype)

    if m_cnt > 0:
        mcfg = mref.MiTAConfig(m=m_cnt, k=cfg.k, s=cfg.s, causal=True)
        q_lm = jnp.mean(
            ql[:, :, : m_cnt * w].reshape(b, hkv, m_cnt, w, d), axis=3)
        s_kv = mref.landmark_scores(k[:, :, 0, :n], q_lm, mcfg)
        idx, valid = mref.topk_indices(s_kv, mcfg)
        v_lm = mref.landmark_values(v[:, :, 0, :n], s_kv)
        pad_m = m_max - m_cnt
        state = state._replace(
            lm_q=jnp.pad(q_lm.astype(dtype), ((0, 0), (0, 0), (0, pad_m), (0, 0))),
            lm_v=jnp.pad(v_lm.astype(dtype), ((0, 0), (0, 0), (0, pad_m), (0, 0))),
            expert_idx=jnp.pad(idx, ((0, 0), (0, 0), (0, pad_m), (0, 0))),
            expert_valid=jnp.pad(valid, ((0, 0), (0, 0), (0, pad_m), (0, 0))),
        )
    tail = ql[:, :, m_cnt * w:]                    # partial-window queries
    return state._replace(
        k_cache=jnp.pad(k[:, :, 0], ((0, 0), (0, 0), (0, capacity - n), (0, 0))),
        v_cache=jnp.pad(v[:, :, 0], ((0, 0), (0, 0), (0, capacity - n), (0, 0))),
        q_sum=jnp.sum(tail, axis=2).astype(jnp.float32),
        t=jnp.asarray(n, jnp.int32),
    )


# ------------------------------------------------- full-attention baseline --

class FullDecodeState(NamedTuple):
    k_cache: jax.Array   # [B, Hkv, C, d]
    v_cache: jax.Array
    t: jax.Array


def init_full_state(batch, n_kv, head_dim, capacity, dtype=jnp.bfloat16):
    z = lambda *s: jnp.zeros((batch, n_kv) + s, dtype)
    return FullDecodeState(k_cache=z(capacity, head_dim),
                           v_cache=z(capacity, head_dim),
                           t=jnp.zeros((), jnp.int32))


def full_prefill_state(k: jax.Array, v: jax.Array, capacity: int):
    """k, v: [B, Hkv, 1, N, d]."""
    n = k.shape[-2]
    pad = ((0, 0), (0, 0), (0, capacity - n), (0, 0))
    return FullDecodeState(k_cache=jnp.pad(k[:, :, 0], pad),
                           v_cache=jnp.pad(v[:, :, 0], pad),
                           t=jnp.asarray(n, jnp.int32))


def full_decode_step(state: FullDecodeState, q, k_new, v_new):
    """O(t) per token — the quadratic baseline MiTA replaces.
    q: [B, Hkv, G, d]; k_new/v_new: [B, Hkv, d]."""
    d = q.shape[-1]
    cap = state.k_cache.shape[-2]
    t = state.t
    kc = jax.lax.dynamic_update_slice_in_dim(
        state.k_cache, k_new[:, :, None, :].astype(state.k_cache.dtype), t, axis=2)
    vc = jax.lax.dynamic_update_slice_in_dim(
        state.v_cache, v_new[:, :, None, :].astype(state.v_cache.dtype), t, axis=2)
    logits = jnp.einsum("bhgd,bhnd->bhgn", q, kc) / math.sqrt(d)
    mask = jnp.arange(cap)[None, None, None, :] <= t
    out = combine([partial_from_scores(logits, vc, mask=mask)])
    return out, FullDecodeState(k_cache=kc, v_cache=vc, t=t + 1)


def mita_finalize_if_due(state: MiTADecodeState,
                         cfg: DecodeConfig) -> MiTADecodeState:
    """External-finalize step: call from the serving loop every ``window``
    tokens (or unconditionally — it no-ops off-boundary).  This is its own
    jitted program so the per-token decode step stays O(m + s·k + w)."""
    return jax.lax.cond(
        (state.t % cfg.window == 0) & (state.t > 0),
        lambda s: _finalize_window(s, cfg, s.t),
        lambda s: s,
        state)


def _finalize_window(state: MiTADecodeState, cfg: DecodeConfig,
                     t_new: jax.Array) -> MiTADecodeState:
    """Finalize landmark i = t_new//w - 1 from the accumulated query sum."""
    d = state.k_cache.shape[-1]
    cap = state.k_cache.shape[-2]
    i = t_new // cfg.window - 1
    q_lm = (state.q_sum / cfg.window).astype(state.k_cache.dtype)  # [B,Hkv,d]

    scores = jnp.einsum("bhnd,bhd->bhn", state.k_cache, q_lm) / math.sqrt(d)
    visible = jnp.arange(cap)[None, None, :] < t_new
    scores = jnp.where(visible, scores.astype(jnp.float32), NEG_INF)
    top_vals, top_idx = jax.lax.top_k(scores, cfg.k)        # [B,Hkv,K]
    valid = top_vals > NEG_INF / 2
    p = jax.nn.softmax(scores, axis=-1)
    v_lm = jnp.einsum("bhn,bhnd->bhd",
                      p.astype(state.v_cache.dtype), state.v_cache)

    return state._replace(
        lm_q=state.lm_q.at[:, :, i, :].set(q_lm),
        lm_v=state.lm_v.at[:, :, i, :].set(v_lm),
        expert_idx=state.expert_idx.at[:, :, i, :].set(top_idx),
        expert_valid=state.expert_valid.at[:, :, i, :].set(valid),
        q_sum=jnp.zeros_like(state.q_sum),
    )


def mita_decode_step(state: MiTADecodeState, q: jax.Array, k_new: jax.Array,
                     v_new: jax.Array, cfg: DecodeConfig) -> tuple[jax.Array, MiTADecodeState]:
    """One decode step.

    Args:
      q:     [B, Hkv, G, d] new queries (G = query heads per KV group).
      k_new: [B, Hkv, d] new key;  v_new: [B, Hkv, d] new value.
    Returns: (output [B, Hkv, G, d], updated state).
    """
    b, hkv, g, d = q.shape
    cap = state.k_cache.shape[-2]
    m_max = state.lm_q.shape[-2]
    t = state.t

    # 1. append to cache, accumulate window query sum
    state = state._replace(
        k_cache=jax.lax.dynamic_update_slice_in_dim(
            state.k_cache, k_new[:, :, None, :].astype(state.k_cache.dtype), t, axis=2),
        v_cache=jax.lax.dynamic_update_slice_in_dim(
            state.v_cache, v_new[:, :, None, :].astype(state.v_cache.dtype), t, axis=2),
        q_sum=state.q_sum + jnp.mean(q, axis=2).astype(jnp.float32),
    )
    t_new = t + 1

    # 2. finalize the window if it just completed (amortized O(t/w) per step)
    if not cfg.external_finalize:
        state = jax.lax.cond(
            t_new % cfg.window == 0,
            lambda s: _finalize_window(s, cfg, t_new),
            lambda s: s,
            state)

    # 3. attend: shared + routed + local window
    if cfg.external_finalize:
        # the serving loop finalizes at window boundaries; the last token of
        # a window does not yet see its own window's landmark
        m_cnt = t // cfg.window
    else:
        m_cnt = t_new // cfg.window  # finalized landmarks
    lm_mask = jnp.arange(m_max)[None, None, None, :] < m_cnt

    # routing / shared logits: [B, Hkv, G, M]
    r = jnp.einsum("bhgd,bhmd->bhgm", q, state.lm_q) / math.sqrt(d)
    r = jnp.where(lm_mask, r.astype(jnp.float32), NEG_INF)
    parts: list[Partial] = [partial_from_scores(r, state.lm_v)]

    # routed experts: gather s·k cache rows per (b, h, g)
    s_ = min(cfg.s, m_max)
    _, e_idx = jax.lax.top_k(r, s_)                         # [B,Hkv,G,s]
    e_ok = jnp.take_along_axis(r, e_idx, axis=-1) > NEG_INF / 2
    flat_e = e_idx.reshape(b, hkv, g * s_)
    rows = jnp.take_along_axis(
        state.expert_idx.reshape(b, hkv, m_max, cfg.k),
        flat_e[..., None], axis=2)                          # [B,Hkv,g*s,K]
    rows_valid = jnp.take_along_axis(
        state.expert_valid, flat_e[..., None], axis=2)
    rows = rows.reshape(b, hkv, g * s_ * cfg.k)
    k_sel = jnp.take_along_axis(state.k_cache, rows[..., None], axis=2)
    v_sel = jnp.take_along_axis(state.v_cache, rows[..., None], axis=2)
    k_sel = k_sel.reshape(b, hkv, g, s_ * cfg.k, d)
    v_sel = v_sel.reshape(b, hkv, g, s_ * cfg.k, d)
    logits = jnp.einsum("bhgd,bhgkd->bhgk", q, k_sel) / math.sqrt(d)
    mask = (rows_valid.reshape(b, hkv, g, s_, cfg.k)
            & e_ok[..., None]).reshape(b, hkv, g, s_ * cfg.k)
    parts.append(partial_from_logits(logits, v_sel, mask=mask))

    # local: the query's OWN window [ (t//w)*w, t ] — note t//w, not
    # t_new//w: the last token of a window still attends its window locally
    # (matching training-time `_local_partial`).
    start = (t // cfg.window) * cfg.window
    k_loc = jax.lax.dynamic_slice_in_dim(state.k_cache, start, cfg.window, axis=2)
    v_loc = jax.lax.dynamic_slice_in_dim(state.v_cache, start, cfg.window, axis=2)
    loc_logits = jnp.einsum("bhgd,bhwd->bhgw", q, k_loc) / math.sqrt(d)
    loc_mask = (jnp.arange(cfg.window)[None, None, None, :] + start) < t_new
    parts.append(partial_from_scores(loc_logits, v_loc, mask=loc_mask))

    out = combine(parts)
    return out, state._replace(t=t_new)
