"""Baseline attention mechanisms from the paper's taxonomy (Tab. 1).

These are the comparison points the paper measures against, implemented in
the same [..., N, d] convention as `mita.py`:

  * ``full_attention``    — the N-width fast-weight MLP itself (Eq. 1/3).
  * ``local_attention``   — banded sliding-window attention (locality prior).
  * ``linear_attention``  — scaling-by-compression into one linear layer
                            (Katharopoulos et al., 2020; elu+1 feature map).
  * ``moba_attention``    — scaling-by-routing with *rigid* block experts
                            (MoBA, Lu et al. 2025): the paper's route-only,
                            fixed-shape-expert ancestor.
  * Agent Attention       — scaling-by-compression with landmark probing is
                            exactly ``mita_attention`` with
                            ``compress_only=True`` (paper Sec. 4 notes Agent
                            is the degenerate compress-only case of MiTA).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.combine import NEG_INF, Partial, combine, partial_from_logits


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   causal: bool = False) -> jax.Array:
    """Vanilla scaled-dot-product attention (paper Eq. 1)."""
    d = q.shape[-1]
    logits = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32)
    logits = logits / math.sqrt(d)
    if causal:
        n = q.shape[-2]
        mask = jnp.tril(jnp.ones((n, n), bool))
        logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p.astype(v.dtype), v)


def local_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    window: int, causal: bool = True) -> jax.Array:
    """Sliding-window attention.

    Causal: query t attends keys in (t-window, t].  Implemented blockwise
    (block size = window) so cost is O(N·window), not O(N²): each query block
    attends to its own and the previous key block with a banded mask.
    """
    n, d = q.shape[-2:]
    if n % window:
        raise ValueError(f"N={n} not divisible by window={window}")
    nb = n // window
    lead = q.shape[:-2]
    qb = q.reshape(lead + (nb, window, d))
    kb = k.reshape(lead + (nb, window, d))
    vb = v.reshape(lead + (nb, window, d))

    # keys for block b: blocks [b-1, b] concatenated -> [..., nb, 2w, d]
    prev_k = jnp.roll(kb, 1, axis=-3).at[..., 0, :, :].set(0.0)
    prev_v = jnp.roll(vb, 1, axis=-3).at[..., 0, :, :].set(0.0)
    k2 = jnp.concatenate([prev_k, kb], axis=-2)
    v2 = jnp.concatenate([prev_v, vb], axis=-2)

    logits = jnp.einsum("...qd,...kd->...qk", qb, k2) / math.sqrt(d)
    # mask: position of query within block = i; key j in [0, 2w);
    # absolute key offset = j - w relative to query block start.
    i = jnp.arange(window)[:, None]
    j = jnp.arange(2 * window)[None, :]
    rel = j - window - i  # key position minus query position
    if causal:
        band = (rel <= 0) & (rel > -window)
    else:
        band = jnp.abs(rel) < window
    # first block has no previous block
    first = jnp.zeros((nb, 1, 1), bool).at[0].set(True)
    valid = band[None] & ~(first & (j[None] < window))
    logits = jnp.where(valid, logits, NEG_INF)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("...qk,...kd->...qd", p.astype(v.dtype), v2)
    return out.reshape(lead + (n, d))


def linear_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     causal: bool = False) -> jax.Array:
    """Linear attention with elu(x)+1 features (Katharopoulos et al.).

    Bidirectional: O = phi(Q) (phi(K)^T V) / (phi(Q) phi(K)^T 1).
    Causal: running-sum recurrence via cumulative sums (the fast-weight
    'compressed linear layer' of the taxonomy).
    """
    phi_q = jax.nn.elu(q) + 1.0
    phi_k = jax.nn.elu(k) + 1.0
    if not causal:
        kv = jnp.einsum("...nd,...ne->...de", phi_k, v)
        z = jnp.einsum("...nd,...d->...n", phi_q, jnp.sum(phi_k, axis=-2))
        out = jnp.einsum("...nd,...de->...ne", phi_q, kv)
        return out / jnp.maximum(z[..., None], 1e-6)
    # causal: cumulative fast-weight state
    kv_t = jnp.einsum("...nd,...ne->...nde", phi_k, v)
    kv_cum = jnp.cumsum(kv_t, axis=-3)
    k_cum = jnp.cumsum(phi_k, axis=-2)
    out = jnp.einsum("...nd,...nde->...ne", phi_q, kv_cum)
    z = jnp.einsum("...nd,...nd->...n", phi_q, k_cum)
    return out / jnp.maximum(z[..., None], 1e-6)


def moba_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   block_size: int, top_blocks: int,
                   causal: bool = True) -> jax.Array:
    """Mixture of Block Attention (MoBA) — rigid routed experts.

    Experts are contiguous blocks; routing vector of a block is its
    mean-pooled key.  Causal rule (as in the MoBA paper): a query attends its
    own block causally and routes to ``top_blocks`` fully-past blocks.
    """
    n, d = q.shape[-2:]
    if n % block_size:
        raise ValueError("N must divide by block_size")
    nb = n // block_size
    lead = q.shape[:-2]
    kb = k.reshape(lead + (nb, block_size, d))
    vb = v.reshape(lead + (nb, block_size, d))
    k_mean = jnp.mean(kb, axis=-2)  # [..., nb, d]

    r = jnp.einsum("...nd,...bd->...nb", q, k_mean) / math.sqrt(d)
    pos = jnp.arange(n)
    ends = (jnp.arange(nb) + 1) * block_size
    if causal:
        avail = ends[None, :] <= pos[:, None] + 1
        # own block handled by the local branch; exclude it from routing
        own = (pos[:, None] // block_size) == jnp.arange(nb)[None, :]
        r = jnp.where(avail & ~own, r, NEG_INF)
    _, sel = jax.lax.top_k(r, min(top_blocks, nb))  # [..., N, g]
    sel_valid = jnp.take_along_axis(r, sel, axis=-1) > NEG_INF / 2

    g = sel.shape[-1]
    flat = sel.reshape(lead + (n * g,))
    k_sel = jnp.take_along_axis(
        kb.reshape(lead + (nb, block_size * d)), flat[..., None], axis=-2
    ).reshape(lead + (n, g * block_size, d))
    v_sel = jnp.take_along_axis(
        vb.reshape(lead + (nb, block_size * d)), flat[..., None], axis=-2
    ).reshape(lead + (n, g * block_size, d))
    logits = jnp.einsum("...nd,...nkd->...nk", q, k_sel) / math.sqrt(d)
    mask = jnp.repeat(sel_valid, block_size, axis=-1)
    parts = [partial_from_logits(logits, v_sel, mask=mask)]

    if causal:
        from repro.core.mita import MiTAConfig, _local_partial
        cfg = MiTAConfig(m=nb, k=1, causal=True)
        parts.append(_local_partial(q, k, v, cfg))
    return combine(parts)
