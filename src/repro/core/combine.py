"""Online-softmax combination of attention partials (paper Alg. 1, line 16).

Each attention *branch* (shared expert, routed expert(s), local window) is
computed independently and summarized by the triple

    (o, m, l)  with  o = sum_j exp(s_j - m) v_j,   m = max_j s_j,
                     l = sum_j exp(s_j - m)

over its own set of logits ``s_j``.  Branches are then merged exactly as in
FlashAttention's online softmax so the final result equals one softmax over
the concatenation of all branches' key/value pairs (paper Eq. 10).

All statistics are kept in float32 regardless of the value dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

NEG_INF = float(jnp.finfo(jnp.float32).min)


@dataclasses.dataclass
class Partial:
    """Un-normalized attention partial.

    Attributes:
      o: [..., d] un-normalized weighted values, sum_j exp(s_j - m) v_j.
      m: [...]    running max of logits (float32; NEG_INF if branch empty).
      l: [...]    running sum of exp(s_j - m) (float32; 0 if branch empty).
    """

    o: jax.Array
    m: jax.Array
    l: jax.Array


def partial_from_logits(logits: jax.Array, values: jax.Array,
                        mask: jax.Array | None = None) -> Partial:
    """Build a Partial from raw logits and values.

    Args:
      logits: [..., n] attention logits for one branch.
      values: [..., n, d] corresponding values.
      mask:   optional [..., n] boolean; False entries are excluded.
    """
    logits = logits.astype(jnp.float32)
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    # Guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1.
    safe_m = jnp.where(m == NEG_INF, 0.0, m)
    p = jnp.exp(logits - safe_m[..., None])
    if mask is not None:
        p = jnp.where(mask, p, 0.0)
    else:
        p = jnp.where(logits == NEG_INF, 0.0, p)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("...n,...nd->...d", p.astype(values.dtype), values)
    return Partial(o=o, m=m, l=l)


def partial_from_scores(scores: jax.Array, values: jax.Array,
                        mask: jax.Array | None = None) -> Partial:
    """Like ``partial_from_logits`` but for a [..., Q, K] score matrix with
    values [..., K, d] shared across the query axis (avoids materializing
    per-query value copies)."""
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1)
    safe_m = jnp.where(m == NEG_INF, 0.0, m)
    p = jnp.exp(scores - safe_m[..., None])
    p = jnp.where(scores == NEG_INF, 0.0, p)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("...qk,...kd->...qd", p.astype(values.dtype), values)
    return Partial(o=o, m=m, l=l)


def combine(partials: Sequence[Partial]) -> jax.Array:
    """Merge branch partials into the final normalized attention output.

    Equivalent to a single softmax over the concatenation of all branches'
    logits/values.  Queries with no valid key in any branch return zeros.
    """
    if not partials:
        raise ValueError("need at least one partial")
    m_star = partials[0].m
    for p in partials[1:]:
        m_star = jnp.maximum(m_star, p.m)
    safe_m = jnp.where(m_star == NEG_INF, 0.0, m_star)

    l_tot = jnp.zeros_like(partials[0].l)
    o_tot = jnp.zeros_like(partials[0].o, dtype=jnp.float32)
    for p in partials:
        scale = jnp.exp(jnp.where(p.m == NEG_INF, NEG_INF, p.m - safe_m))
        l_tot = l_tot + p.l * scale
        o_tot = o_tot + p.o.astype(jnp.float32) * scale[..., None]

    denom = jnp.where(l_tot == 0.0, 1.0, l_tot)
    out = o_tot / denom[..., None]
    return jnp.where((l_tot == 0.0)[..., None], 0.0, out).astype(partials[0].o.dtype)
