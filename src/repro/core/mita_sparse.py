"""Efficient MiTA — the production O(N·(m+ks)) implementations of Alg. 1.

Two interchangeable routed-branch strategies (both exact w.r.t. `mita.py`
up to documented drop conditions):

``sorted``  — the paper's Alg. 1 adapted to TPU static shapes: sub-queries are
    sorted by expert assignment (line 13); attention is computed in fixed-size
    query blocks.  Because assignments are sorted, a block touches a
    *contiguous* range of experts; we load a static span of ``expert_span``
    expert KV tiles per block and mask.  Expected span is
    1 + (m-1)/(N/block_q) ≪ expert_span; queries whose expert falls outside
    the span (pathological skew) fall back to shared+local branches only.

``capacity`` — beyond-paper optimization: classic MoE capacity routing.  Each
    expert processes at most ``C = ceil(s·N/m · capacity_factor)`` queries;
    attention is a fully dense [m, C, k] batched matmul (zero masked-lane
    waste beyond the capacity factor).  Overflowing queries drop their routed
    branch.  Use with the load-balance auxiliary loss (`aux_load_balance`).

The gather of the m·k expert key/value rows happens **once per layer** and is
reused by every routed query — the TPU-native restructuring of the paper's
per-query gather bottleneck (DESIGN.md, "Hardware adaptation").
"""

from __future__ import annotations

import math
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import mita as mref
from repro.core.combine import (NEG_INF, Partial, combine,
                                partial_from_scores)
from repro.core.mita import MiTAConfig


def _routed_sorted(q, k_e, v_e, valid, r, cfg: MiTAConfig,
                   block_q: int, expert_span: int) -> Partial:
    """Sorted block-span routed branch.  q: [..., N, d].

    ``r`` may have broadcast-1 lead dims (route_per_group): the assignment,
    sort order, and expert-tile spans are then computed ONCE per KV group
    and shared by all G query heads — the G× traffic saving is real because
    every group-shared array below keeps the broadcast-1 lead (``rlead``).
    """
    lead = q.shape[:-2]
    rlead = r.shape[:-2]                               # may be broadcast-1
    n, d = q.shape[-2:]
    s = cfg.s
    m, kk = cfg.m, cfg.k

    if s == 1:   # argmax is a plain reduction — shards cleanly where the
        # sort-based top_k forces GSPMD to all-gather the [*, N, m] logits
        # (§Perf iteration: qwen3-32b train)
        e_idx = jnp.argmax(r, axis=-1)[..., None]
        e_ok = (jnp.max(r, axis=-1) > NEG_INF / 2)[..., None]
    else:
        _, e_idx = jax.lax.top_k(r, s)                 # [rlead, N, s]
        e_ok = jnp.take_along_axis(r, e_idx, axis=-1) > NEG_INF / 2

    # flatten sub-queries: each query contributes s routed lookups
    ns = n * s
    a = e_idx.reshape(rlead + (ns,))                   # assignment per sub-q
    ok = e_ok.reshape(rlead + (ns,))
    # push invalid sub-queries to the end so they don't pollute spans
    a_sortkey = jnp.where(ok, a, m)
    order = jnp.argsort(a_sortkey, axis=-1, stable=True)     # [rlead, ns]
    inv = jnp.argsort(order, axis=-1)

    sub_q = jnp.repeat(q, s, axis=-2)                  # [lead..., ns, d]
    q_sorted = jnp.take_along_axis(sub_q, order[..., None], axis=-2)
    a_sorted = jnp.take_along_axis(a_sortkey, order, axis=-1)

    if expert_span == 0:   # Pallas kernel path: dynamic expert walk
        # (no NS % block_q constraint — the kernel wrapper pads internally)
        from repro.kernels.ops import routed_expert_partial
        o_s, m_s, l_s = routed_expert_partial(
            q_sorted, jnp.broadcast_to(a_sorted, lead + (ns,)),
            k_e, v_e, valid, block_q=block_q)
        o = jnp.take_along_axis(o_s, inv[..., None], axis=-2)
        mm = jnp.take_along_axis(m_s, inv, axis=-1)
        ll = jnp.take_along_axis(l_s, inv, axis=-1)
        return _merge_subqueries(o, mm, ll, lead, n, s, q.dtype)

    if ns % block_q:
        raise ValueError(f"N*s={ns} not divisible by block_q={block_q} "
                         "(the static-span path needs whole blocks; "
                         "impl='pallas' pads internally)")
    nb = ns // block_q
    qb = q_sorted.reshape(lead + (nb, block_q, d))
    ab = a_sorted.reshape(rlead + (nb, block_q))
    lo = jnp.minimum(ab[..., 0], m - 1)                # first expert in block

    # static span of expert tiles per block: ids lo..lo+span-1.  Slots past
    # expert m-1 are gathered clipped but masked out below (a clipped slot
    # would otherwise duplicate expert m-1 in the softmax).
    raw_ids = lo[..., None] + jnp.arange(expert_span)           # [..., nb, e]
    slot_ok = raw_ids <= m - 1
    # sentinel m+1: must differ from the invalid-sub-query sort key (m)
    span_ids = jnp.where(slot_ok, raw_ids, m + 1)
    gather_ids = jnp.minimum(raw_ids, m - 1)
    flat_span = gather_ids.reshape(rlead + (nb * expert_span,))

    def take(arr, trailing):
        """[kv_lead..., m, *trailing-dims] -> [lead..., nb, span, width].
        kv_lead may have broadcast-1 dims (GQA group-shared experts)."""
        kv_lead = arr.shape[:-(trailing + 1)]
        width = math.prod(arr.shape[-trailing:])
        arr2 = arr.reshape(kv_lead + (m, width))
        out = jnp.take_along_axis(arr2, flat_span[..., None], axis=-2)
        return out.reshape(rlead + (nb, expert_span, width))

    k_span = take(k_e, 2).reshape(rlead + (nb, expert_span, kk, d))
    v_span = take(v_e, 2).reshape(rlead + (nb, expert_span, kk, d))
    valid_span = take(valid, 1)                        # [..., nb, span, kk]

    scores = jnp.einsum("...bqd,...bekd->...bqek", qb, k_span) / math.sqrt(d)
    # mask: sub-query's expert must equal the span slot's expert id
    match = ab[..., :, None] == span_ids[..., None, :]          # [..., nb, q, e]
    mask = match[..., None] & valid_span[..., None, :, :]       # [...,nb,q,e,kk]
    p = partial_from_scores(
        scores.reshape(lead + (nb, block_q, expert_span * kk)),
        v_span.reshape(rlead + (nb, expert_span * kk, d)),
        mask=mask.reshape(rlead + (nb, block_q, expert_span * kk)))

    # unsort sub-queries, then merge the s partials of each query
    o = jnp.take_along_axis(p.o.reshape(lead + (ns, d)), inv[..., None], axis=-2)
    mm = jnp.take_along_axis(p.m.reshape(lead + (ns,)), inv, axis=-1)
    ll = jnp.take_along_axis(p.l.reshape(lead + (ns,)), inv, axis=-1)
    return _merge_subqueries(o, mm, ll, lead, n, s, q.dtype)


def _merge_subqueries(o, mm, ll, lead, n, s, dtype) -> Partial:
    """Merge the s per-sub-query partials of each query (online softmax)."""
    d = o.shape[-1]
    if s == 1:
        return Partial(o=o.reshape(lead + (n, d)), m=mm, l=ll)
    subs = [Partial(o=o.reshape(lead + (n, s, d))[..., j, :],
                    m=mm.reshape(lead + (n, s))[..., j],
                    l=ll.reshape(lead + (n, s))[..., j]) for j in range(s)]
    m_star = subs[0].m
    for pp in subs[1:]:
        m_star = jnp.maximum(m_star, pp.m)
    safe = jnp.where(m_star == NEG_INF, 0.0, m_star)
    l_tot = sum(pp.l * jnp.exp(jnp.where(pp.m == NEG_INF, NEG_INF, pp.m - safe))
                for pp in subs)
    o_tot = sum(pp.o.astype(jnp.float32)
                * jnp.exp(jnp.where(pp.m == NEG_INF, NEG_INF, pp.m - safe))[..., None]
                for pp in subs)
    return Partial(o=o_tot.astype(dtype), m=m_star, l=l_tot)


def _routed_capacity(q, k_e, v_e, valid, r, cfg: MiTAConfig,
                     capacity_factor: float) -> Partial:
    """Capacity-routed branch (beyond-paper, fully dense)."""
    lead = q.shape[:-2]
    n, d = q.shape[-2:]
    r = jnp.broadcast_to(r, lead + r.shape[-2:])   # group-shared routing ok
    s, m, kk = cfg.s, cfg.m, cfg.k
    cap = int(math.ceil(s * n / m * capacity_factor))
    cap = max(8, ((cap + 7) // 8) * 8)  # pad to lane multiple

    _, e_idx = jax.lax.top_k(r, s)                     # [..., N, s]
    e_ok = jnp.take_along_axis(r, e_idx, axis=-1) > NEG_INF / 2
    a = e_idx.reshape(lead + (n * s,))
    ok = e_ok.reshape(lead + (n * s,))

    # position of each sub-query within its expert's queue (stable order)
    onehot = jax.nn.one_hot(jnp.where(ok, a, m), m + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=-2) - 1              # [..., ns, m+1]
    slot = jnp.take_along_axis(
        pos, jnp.where(ok, a, m)[..., None], axis=-1)[..., 0]
    keep = ok & (slot < cap)

    # scatter sub-queries into [..., m, cap, d]
    flat_dst = jnp.where(keep, a * cap + slot, m * cap)
    qpad = jnp.zeros(lead + (m * cap + 1, d), q.dtype)
    q_exp = _scatter_rows(qpad, flat_dst, jnp.repeat(q, s, axis=-2))
    q_exp = q_exp[..., : m * cap, :].reshape(lead + (m, cap, d))

    scores = jnp.einsum("...mcd,...mkd->...mck", q_exp, k_e) / math.sqrt(d)
    p = partial_from_scores(scores, v_e, mask=valid[..., None, :])
    # gather partials back per sub-query
    src = jnp.where(keep, a * cap + slot, m * cap)
    o = _gather_rows(_pad_rows(p.o.reshape(lead + (m * cap, d))), src)
    mm = _gather_vals(_pad_vals(p.m.reshape(lead + (m * cap,)), NEG_INF), src)
    ll = _gather_vals(_pad_vals(p.l.reshape(lead + (m * cap,)), 0.0), src)
    mm = jnp.where(keep, mm, NEG_INF)
    ll = jnp.where(keep, ll, 0.0)
    o = jnp.where(keep[..., None], o, 0.0)

    if s == 1:
        return Partial(o=o, m=mm, l=ll)
    sub = [Partial(o=o.reshape(lead + (n, s, d))[..., j, :],
                   m=mm.reshape(lead + (n, s))[..., j],
                   l=ll.reshape(lead + (n, s))[..., j]) for j in range(s)]
    m_star = sub[0].m
    for pp in sub[1:]:
        m_star = jnp.maximum(m_star, pp.m)
    safe = jnp.where(m_star == NEG_INF, 0.0, m_star)
    l_tot = sum(pp.l * jnp.exp(jnp.where(pp.m == NEG_INF, NEG_INF, pp.m - safe))
                for pp in sub)
    o_tot = sum(pp.o.astype(jnp.float32)
                * jnp.exp(jnp.where(pp.m == NEG_INF, NEG_INF, pp.m - safe))[..., None]
                for pp in sub)
    return Partial(o=o_tot.astype(q.dtype), m=m_star, l=l_tot)


def _scatter_rows(dst, idx, rows):
    return dst.at[..., idx, :].set(rows) if dst.ndim == 2 else _batched_scatter(dst, idx, rows)


def _batched_scatter(dst, idx, rows):
    def one(d_, i_, r_):
        return d_.at[i_, :].set(r_)
    fn = one
    for _ in range(dst.ndim - 2):
        fn = jax.vmap(fn)
    return fn(dst, idx, rows)


def _gather_rows(src, idx):
    return jnp.take_along_axis(src, idx[..., None], axis=-2)


def _gather_vals(src, idx):
    return jnp.take_along_axis(src, idx, axis=-1)


def _pad_rows(x):
    pad = [(0, 0)] * (x.ndim - 2) + [(0, 1), (0, 0)]
    return jnp.pad(x, pad)


def _pad_vals(x, val):
    pad = [(0, 0)] * (x.ndim - 1) + [(0, 1)]
    return jnp.pad(x, pad, constant_values=val)


def aux_load_balance(r: jax.Array, cfg: MiTAConfig) -> jax.Array:
    """Switch-style load-balance loss over expert assignments (beyond-paper;
    keeps the capacity path's drop rate low)."""
    probs = jax.nn.softmax(jnp.where(r <= NEG_INF / 2, NEG_INF, r), axis=-1)
    top = jnp.argmax(r, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top, cfg.m, dtype=jnp.float32), axis=-2)
    imp = jnp.mean(probs, axis=-2)
    return cfg.m * jnp.mean(jnp.sum(frac * imp, axis=-1))


def mita_attention_sparse(
    q: jax.Array, k: jax.Array, v: jax.Array, cfg: MiTAConfig,
    impl: Literal["sorted", "capacity", "pallas"] = "sorted",
    block_q: int = 128, expert_span: int = 4,
    capacity_factor: float = 1.25,
    q_landmarks: jax.Array | None = None,
) -> jax.Array:
    """Production MiTA.  Semantics == `mita.mita_attention` (oracle), with the
    routed branch computed by the selected static-shape strategy."""
    q_lm = mref.extract_landmarks(q if q_landmarks is None else q_landmarks,
                                  cfg)
    s_kv = mref.landmark_scores(k, q_lm, cfg)
    r = mref.routing_logits(q, q_lm, cfg)
    if cfg.route_per_group and q_landmarks is not None:
        r_route = mref.routing_logits(q_landmarks, q_lm, cfg)
    else:
        r_route = r

    parts: list[Partial] = []
    if not cfg.route_only:
        parts.append(mref._shared_partial(r, mref.landmark_values(v, s_kv)))
    if not cfg.compress_only:
        k_e, v_e, valid = mref.gather_topk(k, v, s_kv, cfg)
        if impl == "sorted":
            bq = min(block_q, q.shape[-2] * cfg.s)
            parts.append(_routed_sorted(q, k_e, v_e, valid, r_route, cfg, bq,
                                        min(expert_span, cfg.m)))
        elif impl == "pallas":
            # expert_span=0 routes _routed_sorted to the Pallas kernel
            bq = min(block_q, q.shape[-2] * cfg.s)
            parts.append(_routed_sorted(q, k_e, v_e, valid, r_route, cfg,
                                        bq, 0))
        else:
            parts.append(_routed_capacity(q, k_e, v_e, valid, r_route, cfg,
                                          capacity_factor))
    if cfg.causal and cfg.include_local:
        parts.append(mref._local_partial(q, k, v, cfg))
    return combine(parts)
