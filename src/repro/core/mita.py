"""Mixture-of-Top-k Attention (MiTA) — reference implementation.

Paper: "Mixture-of-Top-k Attention: Efficient Attention via Scalable Fast
Weights" (a.k.a. "MiTA Attention: Efficient Fast-Weight Scaling via a Mixture
of Top-k Activations").

This module is the *semantic definition* of MiTA: a straightforwardly
vectorized pure-jnp implementation used as (a) the oracle for the efficient
implementations (`mita_sparse.py`, `kernels/mita_expert_attn.py`) and (b) the
small-scale research path.  It implements:

  * the paper's bidirectional form (vision; Sec. 3.2, Alg. 1), and
  * our causal LM adaptation (DESIGN.md "Causal MiTA"): MoBA-style window
    causality — an expert/landmark is visible to query t only when its whole
    window lies in the past, plus an always-on local causal branch over the
    query's own window.

Shapes follow [..., N, d] with arbitrary leading (batch, head) dims.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import landmarks as lm
from repro.core.combine import (NEG_INF, Partial, combine,
                                partial_from_logits, partial_from_scores)


@dataclasses.dataclass(frozen=True)
class MiTAConfig:
    """MiTA hyper-parameters (paper Sec. 3.2).

    Attributes:
      m: number of landmark queries == number of routed experts.
      k: expert width — top-k key/value pairs gathered per landmark.
      s: routed experts per query (paper uses s=1 throughout).
      causal: causal LM adaptation (DESIGN.md) vs the paper's bidirectional.
      landmark: extraction strategy (Tab. 6): pool1d | pool2d | random.
      grid_hw / m_hw: patch grid and landmark grid for pool2d.
      include_local: causal-only — attend to the query's own window causally
        (MoBA's "current block" rule).  Ignored in bidirectional mode.
      route_only: drop the shared (compressed) expert  — Tab. 6 ablation.
      compress_only: drop the routed experts           — Tab. 6 ablation
        (this degenerates to Agent Attention).
    """

    m: int
    k: int
    s: int = 1
    causal: bool = False
    landmark: str = "pool1d"
    grid_hw: Optional[tuple[int, int]] = None
    m_hw: Optional[tuple[int, int]] = None
    include_local: bool = True
    route_only: bool = False
    compress_only: bool = False
    # Beyond-paper (DESIGN.md): one routing decision per KV-head group
    # (from the group-pooled queries).  The gathered expert tiles and sort
    # order are then shared by all G query heads of the group — G× less
    # gather/sort traffic.  The shared-expert branch stays per-head.
    route_per_group: bool = False

    def __post_init__(self):
        if self.route_only and self.compress_only:
            raise ValueError("route_only and compress_only are exclusive")
        if self.s < 1:
            raise ValueError("s >= 1 required")


def extract_landmarks(q: jax.Array, cfg: MiTAConfig) -> jax.Array:
    if cfg.landmark == "pool1d":
        return lm.pool1d(q, cfg.m)
    if cfg.landmark == "pool2d":
        assert cfg.grid_hw and cfg.m_hw
        return lm.pool2d(q, cfg.grid_hw, cfg.m_hw)
    if cfg.landmark == "random":
        return lm.random_select(q, cfg.m)
    raise ValueError(f"unknown landmark extractor {cfg.landmark!r}")


def landmark_scores(k: jax.Array, q_lm: jax.Array, cfg: MiTAConfig) -> jax.Array:
    """S^kv = K^T Q~ / sqrt(d)  (Alg. 1 line 4), causally masked if needed.

    Returns [..., N, m]; entry (n, i) is the score of key n for landmark i.
    In causal mode key n is visible to landmark i only when n < end(i).
    """
    d = k.shape[-1]
    s_kv = jnp.einsum("...nd,...md->...nm", k, q_lm) / math.sqrt(d)
    if cfg.causal:
        n = k.shape[-2]
        ends = lm.window_ends(n, cfg.m)  # [m]
        visible = jnp.arange(n)[:, None] < ends[None, :]  # [N, m]
        s_kv = jnp.where(visible, s_kv, NEG_INF)
    return s_kv


def topk_indices(s_kv: jax.Array, cfg: MiTAConfig):
    """Top-k key indices per landmark (Alg. 1 line 6).

    Returns (top_idx [..., m, k], valid [..., m, k]); `valid` is False for
    padded entries (causal mode, when a window end < k).
    """
    scores_t = jnp.swapaxes(s_kv, -1, -2)  # [..., m, N]
    top_vals, top_idx = jax.lax.top_k(scores_t, cfg.k)  # [..., m, k]
    valid = top_vals > NEG_INF / 2
    return top_idx, valid


def gather_topk(keys: jax.Array, values: jax.Array, s_kv: jax.Array,
                cfg: MiTAConfig):
    """Top-k gather per landmark (Alg. 1 lines 6-7).

    Returns (k_e, v_e, valid):
      k_e, v_e: [..., m, k, d] gathered key/value pairs per expert.
      valid:    [..., m, k] bool — False for padded (masked-out) entries,
                which arise in causal mode when a window end < k.
    """
    top_idx, valid = topk_indices(s_kv, cfg)
    lead = top_idx.shape[:-2]
    flat_idx = top_idx.reshape(lead + (cfg.m * cfg.k,))
    k_e = jnp.take_along_axis(keys, flat_idx[..., None], axis=-2)
    v_e = jnp.take_along_axis(values, flat_idx[..., None], axis=-2)
    k_e = k_e.reshape(lead + (cfg.m, cfg.k, keys.shape[-1]))
    v_e = v_e.reshape(lead + (cfg.m, cfg.k, values.shape[-1]))
    return k_e, v_e, valid


def landmark_values(values: jax.Array, s_kv: jax.Array) -> jax.Array:
    """V~ = V softmax(S^kv) over keys (Alg. 1 line 9): [..., m, d]."""
    p = jax.nn.softmax(s_kv.astype(jnp.float32), axis=-2)  # over N
    return jnp.einsum("...nm,...nd->...md", p.astype(values.dtype), values)


def routing_logits(q: jax.Array, q_lm: jax.Array, cfg: MiTAConfig) -> jax.Array:
    """Q^T Q~ / sqrt(d): [..., N, m]; availability-masked in causal mode.

    Expert i is available to query t iff (i+1)*w <= t+1 (its window — keys,
    pooled queries, and landmark value — lies entirely in the past).
    """
    d = q.shape[-1]
    r = jnp.einsum("...nd,...md->...nm", q, q_lm) / math.sqrt(d)
    if cfg.causal:
        n = q.shape[-2]
        ends = lm.window_ends(n, cfg.m)
        avail = ends[None, :] <= jnp.arange(n)[:, None] + 1  # [N, m]
        r = jnp.where(avail, r, NEG_INF)
    return r


def _local_partial(q: jax.Array, k: jax.Array, v: jax.Array,
                   cfg: MiTAConfig) -> Partial:
    """Causal attention of each query over its own window (current block)."""
    n, d = q.shape[-2:]
    m, w = cfg.m, n // cfg.m
    lead = q.shape[:-2]
    qw = q.reshape(lead + (m, w, d))
    kw = k.reshape(k.shape[:-2] + (m, w, d))  # kv lead may broadcast (GQA)
    vw = v.reshape(v.shape[:-2] + (m, w, d))
    logits = jnp.einsum("...qd,...kd->...qk", qw, kw) / math.sqrt(d)
    causal = jnp.tril(jnp.ones((w, w), bool))
    p = partial_from_scores(logits, vw, mask=causal)
    return Partial(
        o=p.o.reshape(lead + (n, d)),
        m=p.m.reshape(lead + (n,)),
        l=p.l.reshape(lead + (n,)),
    )


def _shared_partial(r: jax.Array, v_lm: jax.Array) -> Partial:
    """Queries attend to (landmark-query, landmark-value) pairs (Eq. 9);
    reuses the routing logits ``r`` as the paper prescribes."""
    return partial_from_scores(r, v_lm)


def _routed_partial(q: jax.Array, k_e: jax.Array, v_e: jax.Array,
                    valid: jax.Array, r: jax.Array, cfg: MiTAConfig) -> Partial:
    """Each query attends to the union of its s routed experts' top-k pairs.

    Reference implementation: gathers [..., N, s, k, d] — O(N s k d) memory,
    fine for the oracle; the production paths avoid this materialization.
    """
    d = q.shape[-1]
    lead = q.shape[:-2]
    n = q.shape[-2]
    # routing logits may be group-shared (route_per_group): broadcast-1 lead
    r = jnp.broadcast_to(r, lead + r.shape[-2:])
    _, e_idx = jax.lax.top_k(r, cfg.s)  # [..., N, s]
    # expert availability for the chosen experts (causal early tokens may
    # have no available expert at all).
    e_avail = jnp.take_along_axis(r, e_idx, axis=-1) > NEG_INF / 2

    flat_e = e_idx.reshape(lead + (n * cfg.s,))

    def take_expert(arr):  # [kv_lead..., m, k, d] -> [lead..., N, s, k, d]
        kv_lead = arr.shape[:-3]
        out = jnp.take_along_axis(
            arr.reshape(kv_lead + (cfg.m, cfg.k * arr.shape[-1])),
            flat_e[..., None], axis=-2)
        return out.reshape(lead + (n, cfg.s, cfg.k, arr.shape[-1]))

    k_sel = take_expert(k_e)
    v_sel = take_expert(v_e)
    valid_sel = jnp.take_along_axis(
        valid, flat_e[..., None], axis=-2
    ).reshape(lead + (n, cfg.s, cfg.k))
    valid_sel = valid_sel & e_avail[..., None]

    logits = jnp.einsum("...nd,...nskd->...nsk", q, k_sel) / math.sqrt(d)
    logits = logits.reshape(lead + (n, cfg.s * cfg.k))
    vals = v_sel.reshape(lead + (n, cfg.s * cfg.k, d))
    return partial_from_logits(logits, vals,
                               mask=valid_sel.reshape(lead + (n, cfg.s * cfg.k)))


def mita_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   cfg: MiTAConfig,
                   q_landmarks: jax.Array | None = None) -> jax.Array:
    """MiTA attention (paper Eq. 10): softmax over the concatenation of the
    shared expert's (Q~, V~) pairs and the routed experts' top-k pairs —
    computed branch-wise and merged with the online softmax.

    ``q_landmarks``: optional query tensor to pool landmarks from — used by
    GQA models to share one landmark/expert set per KV-head group (pass the
    group-pooled queries with a broadcastable leading 1 on the group axis).
    """
    q_lm = extract_landmarks(q if q_landmarks is None else q_landmarks, cfg)
    s_kv = landmark_scores(k, q_lm, cfg)
    r = routing_logits(q, q_lm, cfg)
    if cfg.route_per_group and q_landmarks is not None:
        r_route = routing_logits(q_landmarks, q_lm, cfg)
    else:
        r_route = r

    parts: list[Partial] = []
    if not cfg.route_only:
        v_lm = landmark_values(v, s_kv)
        parts.append(_shared_partial(r, v_lm))
    if not cfg.compress_only:
        k_e, v_e, valid = gather_topk(k, v, s_kv, cfg)
        parts.append(_routed_partial(q, k_e, v_e, valid, r_route, cfg))
    if cfg.causal and cfg.include_local:
        parts.append(_local_partial(q, k, v, cfg))
    return combine(parts)
