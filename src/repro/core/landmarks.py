"""Landmark-query extraction strategies (paper Sec. 3.2 + Tab. 6 ablation).

A landmark extractor maps per-head queries ``q: [..., N, d]`` to ``m``
landmark queries ``[..., m, d]``.  The paper's default — average pooling over
uniformly spaced, equal-sized windows — is ``pool1d`` (sequences) and
``pool2d`` (vision, over the patch grid).  ``random`` and ``learnable`` are
the Tab. 6 ablation alternatives.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pool1d(q: jax.Array, m: int) -> jax.Array:
    """Average-pool queries over m contiguous windows. N must divide by m."""
    n = q.shape[-2]
    if n % m:
        raise ValueError(f"sequence length {n} not divisible by m={m}")
    w = n // m
    shape = q.shape[:-2] + (m, w, q.shape[-1])
    return jnp.mean(q.reshape(shape), axis=-2)


def pool2d(q: jax.Array, grid_hw: tuple[int, int], m_hw: tuple[int, int]) -> jax.Array:
    """2-D average pooling over the (H, W) patch grid (the paper's default
    for vision).  ``q`` is [..., H*W, d]; returns [..., mh*mw, d]."""
    h, w = grid_hw
    mh, mw = m_hw
    if h % mh or w % mw:
        raise ValueError(f"grid {grid_hw} not divisible by landmark grid {m_hw}")
    d = q.shape[-1]
    lead = q.shape[:-2]
    x = q.reshape(lead + (mh, h // mh, mw, w // mw, d))
    x = jnp.mean(x, axis=(-4, -2))
    return x.reshape(lead + (mh * mw, d))


def random_select(q: jax.Array, m: int, seed: int = 0) -> jax.Array:
    """Select m queries at fixed random positions (Tab. 6 'Random Selection')."""
    n = q.shape[-2]
    idx = jax.random.permutation(jax.random.PRNGKey(seed), n)[:m]
    idx = jnp.sort(idx)
    return jnp.take(q, idx, axis=-2)


def learnable(params: jax.Array, batch_shape: tuple[int, ...]) -> jax.Array:
    """Broadcast slow-weight landmark parameters [m, d] (Tab. 6 'Learnable')."""
    return jnp.broadcast_to(params, batch_shape + params.shape)


def window_ends(n: int, m: int) -> jax.Array:
    """End position (exclusive) of each landmark window: [(i+1)*w]_i."""
    w = n // m
    return (jnp.arange(m) + 1) * w


EXTRACTORS = {
    "pool1d": pool1d,
    "pool2d": pool2d,
    "random": random_select,
}
