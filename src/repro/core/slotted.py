"""Slot-addressed pytree helpers for constant-size decode states.

The recurrent serving backends (`repro.serve.backends.recurrent`) keep one
state pytree per model whose leaves are stacked ``[L, S, ...]`` — layer
axis first (the models scan over it), request-slot axis second.  Unlike the
paged MiTA cache, these states are constant-size per slot, so "paging" needs
no indirection: a slot is an index, and the scheduler's page accounting is
pure admission-control currency (docs/serving.md, backend protocol).

These helpers are the whole ownership contract:

  * a slot's state is touched only through its slot index;
  * `zero_slot` at admission gives chunked prefill a clean accumulator;
  * `where_slots` masks per-token updates inside chunk scans so a row whose
    chunk is shorter than the compiled shape (or inactive) keeps its state
    bit-identical — the property preemption-recompute exactness rests on.

All helpers are shape-polymorphic over leaf rank: masks broadcast from the
leading slot axis.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def where_slots(mask: jax.Array, new: Any, old: Any, axis: int = 0) -> Any:
    """Per-slot select between two state pytrees.

    ``mask``: [S] bool over the slot axis of every leaf — axis 0 inside a
    per-layer body (leaves [S, ...]), axis 1 on a whole stacked state
    (leaves [L, S, ...]).  Scalar-per-slot leaves (e.g. a vmapped cache's
    per-slot ``t`` of shape [..., S]) work unchanged.
    """

    def sel(a, b):
        m = mask.reshape((1,) * axis + (-1,) + (1,) * (a.ndim - axis - 1))
        return jnp.where(m, a, b)

    return jax.tree.map(sel, new, old)


def zero_slot(states: Any, slot) -> Any:
    """Zero one slot across every leaf of a stacked [L, S, ...] state."""
    return jax.tree.map(
        lambda a: a.at[:, slot].set(jnp.zeros_like(a[:, slot])), states)


def set_slot(states: Any, sub: Any, slot) -> Any:
    """Write a single-request state (leaves [L, 1, ...]) into ``slot``."""
    return jax.tree.map(lambda a, b: a.at[:, slot].set(b[:, 0]), states, sub)


def gather_slots(states: Any, ids: jax.Array) -> Any:
    """Gather a row-packed sub-state ([L, P, ...]) by slot ids [P]."""
    return jax.tree.map(lambda a: a[:, ids], states)


def scatter_slots(states: Any, ids: jax.Array, sub: Any) -> Any:
    """Scatter a row-packed sub-state back; ``ids`` must be unique (the
    serving engine pads prefill rows with DISTINCT idle slots)."""
    return jax.tree.map(lambda a, b: a.at[:, ids].set(b), states, sub)
