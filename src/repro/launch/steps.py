"""Step-function + input-spec builders for every (arch × shape) cell.

`build_cell(arch, shape, mesh)` returns everything the dry-run / trainer /
server needs: the jit-able step function, abstract input shapes
(ShapeDtypeStruct — no allocation), and in/out shardings.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ArchConfig, ShapeSpec
from repro.distributed import sharding as shd
from repro.models import mamba2 as mb
from repro.models import rglru as rg
from repro.models import transformer as tfm
from repro.models import whisper as wh
from repro.optim import OptConfig, adamw_init, adamw_update


@dataclasses.dataclass
class Cell:
    name: str
    fn: Callable              # jit-able step function
    args: tuple               # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any        # None -> GSPMD chooses
    donate_argnums: tuple = ()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ------------------------------------------------------------- family fns ---

def family_fns(arch: ArchConfig):
    """(init, loss, prefill, decode_step, init_decode_states) per family."""
    cfg = arch.model
    fam = arch.family
    if fam in ("dense", "moe", "vlm"):
        return dict(
            init=lambda rng: tfm.lm_init(rng, cfg),
            loss=lambda p, b: tfm.lm_loss(p, b, cfg),
            prefill=lambda p, b, cap: tfm.lm_prefill(
                p, b["tokens"], cfg, cap,
                extra_embeds=b.get("image_embeds")),
            decode=lambda p, st, tok, pos: tfm.lm_decode_step(p, st, tok, pos, cfg),
            init_states=lambda b, cap: tfm.init_decode_states(cfg, b, cap),
        )
    if fam == "hybrid":
        return dict(
            init=lambda rng: rg.rg_init(rng, cfg),
            loss=lambda p, b: rg.rg_loss(p, b, cfg),
            prefill=None,
            decode=lambda p, st, tok, pos: rg.rg_decode_step(p, st, tok, pos, cfg),
            init_states=lambda b, cap: rg.rg_init_decode_states(cfg, b, cap),
        )
    if fam == "ssm":
        return dict(
            init=lambda rng: mb.mamba_init(rng, cfg),
            loss=lambda p, b: mb.mamba_loss(p, b, cfg),
            prefill=None,
            decode=lambda p, st, tok, pos: mb.mamba_decode_step(p, st, tok, pos, cfg),
            init_states=lambda b, cap: mb.mamba_init_decode_states(cfg, b, cap),
        )
    if fam == "encdec":
        return dict(
            init=lambda rng: wh.whisper_init(rng, cfg, t_enc=arch.t_enc),
            loss=lambda p, b: wh.whisper_loss(p, b, cfg),
            prefill=None,
            decode=lambda p, st, tok, pos: wh.whisper_decode_step(p, st, tok, pos, cfg),
            init_states=None,   # whisper serve states need params (xattn KV)
        )
    raise ValueError(fam)


def abstract_params(arch: ArchConfig):
    fns = family_fns(arch)
    return jax.eval_shape(lambda: fns["init"](jax.random.PRNGKey(0)))


# ------------------------------------------------------------------ cells ---

def _train_batch_shapes(arch: ArchConfig, shape: ShapeSpec):
    cfg = arch.model
    b, s = shape.batch, shape.seq
    if arch.family == "encdec":
        # enc-dec: audio frames (stub frontend) + native decoder length
        return {
            "audio_embeds": _sds((b, arch.t_enc, cfg.d_model), cfg.compute_dtype),
            "tokens": _sds((b, arch.dec_len), jnp.int32),
            "labels": _sds((b, arch.dec_len), jnp.int32),
        }
    batch = {"tokens": _sds((b, s), jnp.int32),
             "labels": _sds((b, s), jnp.int32)}
    if arch.family == "vlm":
        batch["image_embeds"] = _sds((b, arch.n_img_tokens, cfg.d_model),
                                     cfg.compute_dtype)
    return batch


def _batch_specs(batch, mesh, b):
    return {k: shd.batch_spec(mesh, b, rank=len(v.shape)) for k, v in batch.items()}


def build_cell(arch: ArchConfig, shape: ShapeSpec, mesh,
               opt_cfg: Optional[OptConfig] = None,
               state_policy: str = "seq",
               microbatch: int = 1) -> Cell:
    fns = family_fns(arch)
    cfg = arch.model
    params = abstract_params(arch)
    pspecs = shd.param_specs(params, mesh)
    psh = shd.tree_shardings(pspecs, mesh)
    name = f"{arch.arch_id}:{shape.name}"

    if shape.kind == "train":
        opt_cfg = opt_cfg or OptConfig()
        opt_state = jax.eval_shape(adamw_init, params)
        opt_sh = type(opt_state)(
            mu=shd.tree_shardings(pspecs, mesh),
            nu=shd.tree_shardings(pspecs, mesh),
            step=NamedSharding(mesh, P()))
        batch = _train_batch_shapes(arch, shape)
        bspecs = _batch_specs(batch, mesh, shape.batch)
        bsh = {k: NamedSharding(mesh, s) for k, s in bspecs.items()}

        loss_fn = fns["loss"]

        if microbatch > 1:
            # gradient accumulation: scan over A microbatches — activation
            # memory / A at identical total FLOPs/collective bytes (the
            # HBM-fit lever for the big train cells, §Perf).
            if shape.batch % microbatch:
                raise ValueError("microbatch must divide global batch")

            def train_step(p, opt, b):
                def split(x):
                    return x.reshape((microbatch, x.shape[0] // microbatch)
                                     + x.shape[1:])
                mb = jax.tree.map(split, b)

                # remat the accumulation body: without it the outer scan
                # hoists every microbatch's inner-layer residuals and the
                # activation-memory saving evaporates (§Perf measurement)
                @functools.partial(jax.checkpoint,
                                   policy=jax.checkpoint_policies.nothing_saveable)
                def acc_fn(carry, bi):
                    loss, grads = jax.value_and_grad(loss_fn)(p, bi)
                    g_acc, l_acc = carry
                    return (jax.tree.map(jnp.add, g_acc, grads),
                            l_acc + loss), None

                g0 = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), p)
                (grads, loss), _ = jax.lax.scan(
                    acc_fn, (g0, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / microbatch, grads)
                new_p, new_opt, metrics = adamw_update(grads, opt, p, opt_cfg)
                metrics["loss"] = loss / microbatch
                return new_p, new_opt, metrics
        else:
            def train_step(p, opt, b):
                loss, grads = jax.value_and_grad(loss_fn)(p, b)
                new_p, new_opt, metrics = adamw_update(grads, opt, p, opt_cfg)
                metrics["loss"] = loss
                return new_p, new_opt, metrics

        return Cell(
            name=name, fn=train_step,
            args=(params, opt_state, batch),
            in_shardings=(psh, opt_sh, bsh),
            out_shardings=(psh, opt_sh, None),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        if arch.family == "encdec":
            # encoder prefill over the (stub) audio memory — DESIGN.md
            audio = _sds((shape.batch, arch.t_enc, cfg.d_model),
                         cfg.compute_dtype)
            ash = NamedSharding(mesh, shd.batch_spec(mesh, shape.batch, 3))

            def enc_prefill(p, a):
                return wh.whisper_encode(p, a, cfg)

            return Cell(name=name, fn=enc_prefill, args=(params, audio),
                        in_shardings=(psh, ash), out_shardings=None)
        if fns["prefill"] is None:
            # ssm / hybrid prefill == a forward pass at that length
            batch = {"tokens": _sds((shape.batch, shape.seq), jnp.int32)}
            bsh = {"tokens": NamedSharding(
                mesh, shd.batch_spec(mesh, shape.batch))}

            def fwd(p, b):
                if arch.family == "ssm":
                    return mb.mamba_forward(p, b["tokens"], cfg)[0][:, -1]
                return rg.rg_forward(p, b["tokens"], cfg)[0][:, -1]

            return Cell(name=name, fn=fwd, args=(params, batch),
                        in_shardings=(psh, bsh), out_shardings=None)

        batch = _train_batch_shapes(arch, dataclasses.replace(shape, kind="train"))
        batch.pop("labels")
        bspecs = _batch_specs(batch, mesh, shape.batch)
        bsh = {k: NamedSharding(mesh, s) for k, s in bspecs.items()}
        prefill = fns["prefill"]

        def prefill_step(p, b):
            return prefill(p, b, shape.seq)

        return Cell(name=name, fn=prefill_step, args=(params, batch),
                    in_shardings=(psh, bsh), out_shardings=None)

    # ---- decode ----
    b = shape.batch
    cap = shape.seq
    if arch.family == "encdec":
        cap = arch.dec_len  # native decoder capacity (DESIGN.md substitution)
        states = jax.eval_shape(
            lambda p: wh.whisper_init_serve(
                p, jnp.zeros((b, arch.t_enc, cfg.d_model), cfg.compute_dtype),
                cfg, cap), params)
    else:
        states = jax.eval_shape(lambda: fns["init_states"](b, cap))
    st_specs = shd.state_specs(states, mesh, b, policy=state_policy)
    st_sh = shd.tree_shardings(st_specs, mesh)
    token = _sds((b,), jnp.int32)
    tok_sh = NamedSharding(mesh, shd.batch_spec(mesh, b, rank=1,
                                                shard_seq_if_small=False))
    pos = _sds((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())
    decode = fns["decode"]

    def decode_step(p, st, tok, pp):
        return decode(p, st, tok, pp)

    return Cell(name=name, fn=decode_step,
                args=(params, states, token, pos),
                in_shardings=(psh, st_sh, tok_sh, pos_sh),
                out_shardings=(None, st_sh),
                donate_argnums=(1,))
