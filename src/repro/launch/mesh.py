"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* any jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist —
    used by tests and the CPU examples."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data*model} devices, "
                         f"have {n}")
    return jax.make_mesh((data, model), ("data", "model"))
