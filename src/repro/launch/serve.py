"""Serving driver: static batch or the continuous-batching engine.

Two paths over the same model/step functions:

  * ``--engine static``      — prefill a fixed batch of equal-length prompts,
    decode everyone for ``--gen`` steps (the PR-0 baseline; also the oracle
    the engine's greedy outputs are pinned against).
  * ``--engine continuous``  — `repro.serve.ServingEngine`: the generic
    scheduler over a `DecodeBackend` resolved from the registry
    architecture (`serve.backends.for_arch`) — the paged MiTA backend for
    attention LMs, constant-state recurrent backends for ssm/hybrid — so
    ANY registry architecture with a decode state is servable:

      PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m \\
          --smoke --engine continuous

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 128 --gen 32 [--engine continuous] \
      [--prefill-chunk 256] [--priority 0] [--reserve-pages 2] \
      [--sample-device fused] [--prefill-mode batched] [--prefill-impl auto]

``--prefill-chunk N`` (continuous engine) admits prompts in N-token chunks
interleaved with the decode batch and enables priority preemption;
``--priority`` tags the generated requests' priority class and
``--reserve-pages`` keeps pages back for decode-time appends
(docs/serving.md explains all three).  ``--sample-device fused`` moves
sampling into the fused decode program so the hot loop downloads [S]
int32 tokens instead of [S, V] logits.

The continuous engine always runs SUPERVISED (`serve.Supervisor`):
``--max-retries`` sets the per-fault retry budget, ``--deadline-ms``
attaches a deadline to every generated request, and ``--chaos-seed`` /
``--chaos-rate`` wrap the backend in the seeded fault injector
(`serve.ChaosBackend`) to demonstrate retry / quarantine / degradation
end-to-end (docs/serving.md §Failure domains).
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data import DataConfig, synthetic_batch
from repro.core import mita_decode as mdec
from repro.models import transformer as tfm
from repro.models.modules import ModelConfig


@functools.lru_cache(maxsize=None)
def _static_fns(cfg: ModelConfig, capacity: int):
    """Jitted static-path step functions, cached so repeated
    `static_generate` calls (per-batch in the benchmark) don't retrace."""
    return (jax.jit(lambda p, t: tfm.lm_prefill(p, t, cfg, capacity)),
            jax.jit(lambda p, st, tok, pos: tfm.lm_decode_step(
                p, st, tok, pos, cfg)),
            jax.jit(lambda st: tfm.lm_finalize_states(st, cfg)))


def static_generate(params, cfg: ModelConfig, prompts: jnp.ndarray, gen: int,
                    temperature: float = 0.0, capacity: int | None = None,
                    sample_key: jax.Array | None = None):
    """Fixed-batch prefill + decode.  prompts: [B, N] (equal length).

    Returns (tokens [B, gen], timings dict).  With ``cfg.attn.
    external_finalize`` the landmark finalize runs as its own program at
    window boundaries (tracking the prefill-finalized count so a
    boundary-aligned prompt is not re-finalized from an empty q_sum).
    """
    b, n = prompts.shape
    w = cfg.attn.window
    capacity = capacity or n + gen
    capacity = mdec.window_aligned(capacity, w)
    if sample_key is None:
        sample_key = jax.random.PRNGKey(1000)
    prefill, decode, finalize = _static_fns(cfg, capacity)

    t0 = time.perf_counter()
    logits, states = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    def sample(lg, i):
        if temperature > 0:
            key = jax.random.fold_in(sample_key, i)
            return jax.random.categorical(
                key, lg / temperature, axis=-1).astype(jnp.int32)
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)

    tok = sample(logits, 0)
    out_tokens = [tok]
    m_done = n // w
    step_times = []
    t0 = time.perf_counter()
    for i in range(gen - 1):
        pos = n + i
        if cfg.attn.external_finalize and pos % w == 0 and pos // w > m_done:
            states = finalize(states)
            m_done = pos // w
        ts = time.perf_counter()
        logits, states = decode(params, states, tok, jnp.asarray(pos))
        tok = sample(logits, i + 1)
        tok.block_until_ready()
        step_times.append(time.perf_counter() - ts)
        out_tokens.append(tok)
    t_decode = time.perf_counter() - t0

    gen_np = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    return gen_np, {"prefill_s": t_prefill, "decode_s": t_decode,
                    "step_times": step_times}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous: total requests (default 2x batch)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="continuous: chunked-prefill length in tokens "
                         "(multiple of the window; 0 = monolithic prefill)")
    ap.add_argument("--priority", type=int, default=0,
                    help="continuous: priority class for the generated "
                         "requests (higher wins admission/preemption)")
    ap.add_argument("--reserve-pages", type=int, default=0,
                    help="continuous: pages reserved for decode appends")
    ap.add_argument("--sample-device", choices=("host", "fused"),
                    default="host",
                    help="continuous: sample on the host from downloaded "
                         "[S, V] logits, or inside the fused decode "
                         "program (downloads [S] int32 tokens per step)")
    ap.add_argument("--prefill-mode", choices=("batched", "per-job"),
                    default="batched",
                    help="continuous+chunked: advance ALL prefilling slots "
                         "in one dispatch per step (batched), or one job "
                         "per step in its own dispatch (per-job, the "
                         "legacy baseline)")
    ap.add_argument("--prefill-impl", choices=("auto", "kernel", "xla"),
                    default="auto",
                    help="chunk-prefill backend: fused Pallas kernel when "
                         "it fits the VMEM budget (auto/kernel) or the XLA "
                         "oracle; REPRO_PREFILL_IMPL overrides")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="continuous+chunked: radix cache of committed "
                         "window-aligned prompt prefixes — repeated "
                         "prompts attach cached pages by reference and "
                         "skip straight to the first unshared chunk")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="continuous: lossless speculative decoding — "
                         "draft up to K tokens per slot per round and "
                         "verify them in one fused teacher-forced pass "
                         "(requires --sample-device fused; 0 = off)")
    ap.add_argument("--spec-mode", default="auto",
                    choices=("auto", "landmark", "self", "stress"),
                    help="drafting strategy: auto picks the backend's "
                         "native one (MiTA: landmark-branch self-draft; "
                         "recurrent: exact decode scan); stress forces "
                         "synthetic wrong drafts to exercise rollback")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="continuous: wrap the backend in the seeded "
                         "fault injector (serve.ChaosBackend) and drive "
                         "the engine through the Supervisor — transient "
                         "faults, slot faults, and allocator spikes on "
                         "this seed's schedule")
    ap.add_argument("--chaos-rate", type=float, default=0.2,
                    help="chaos: per-dispatch new-fault probability")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="continuous: per-request deadline; requests "
                         "still unfinished when it expires are cancelled "
                         "with finish reason 'deadline_expired'")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="supervisor: step retries before a fault "
                         "escalates to quarantine / degradation")
    args = ap.parse_args(argv)
    if args.prefix_cache and not args.prefill_chunk:
        ap.error("--prefix-cache requires --prefill-chunk > 0")
    if args.spec_k and args.sample_device != "fused":
        ap.error("--spec-k requires --sample-device fused (verification "
                 "samples inside the fused program)")
    if args.chaos_seed is not None and args.engine != "continuous":
        ap.error("--chaos-seed requires --engine continuous (the fault "
                 "injector wraps the DecodeBackend)")

    arch = get_arch(args.arch, smoke=args.smoke)
    if arch.family not in ("dense", "moe", "vlm", "ssm", "hybrid"):
        raise SystemExit("serve.py drives decoder LMs (attention, ssm, "
                         "hybrid); use examples/ for whisper serving")
    cfg = arch.model
    if args.prefill_impl != "auto":
        import dataclasses
        cfg = dataclasses.replace(cfg, attn=dataclasses.replace(
            cfg.attn, prefill_impl=args.prefill_impl))
        arch = dataclasses.replace(arch, model=cfg)
    w = cfg.attn.window

    # registry-routed construction: family -> init fn -> DecodeBackend,
    # so every servable architecture rides the same driver
    from repro.configs.registry import arch_params
    from repro.serve import EngineConfig, Request, ServingEngine, backends

    params = arch_params(arch, jax.random.PRNGKey(0))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                      global_batch=max(args.batch, args.requests or 1))
    prompts = np.asarray(synthetic_batch(dcfg, 0)["tokens"])
    pages = mdec.window_aligned(args.prompt_len + args.gen, w) // w
    ecfg = EngineConfig(n_slots=args.batch, pages_per_slot=pages,
                        n_pages=2 * args.batch * pages,
                        prefill_chunk=args.prefill_chunk,
                        reserve_pages=args.reserve_pages,
                        sample_device=args.sample_device,
                        prefill_mode=args.prefill_mode,
                        prefix_cache=args.prefix_cache,
                        spec_k=args.spec_k, spec_mode=args.spec_mode)

    if args.engine == "static" and arch.family in ("dense", "moe", "vlm"):
        gen, tm = static_generate(params, cfg,
                                  jnp.asarray(prompts[: args.batch]),
                                  args.gen, temperature=args.temperature)
        tps = args.batch * (args.gen - 1) / max(tm["decode_s"], 1e-9)
        print(f"prefill: {args.batch}x{args.prompt_len} in "
              f"{tm['prefill_s']:.3f}s")
        print(f"decode:  {args.gen - 1} steps, {tm['decode_s']:.3f}s "
              f"({tps:.1f} tok/s, batch={args.batch})")
        sample = gen
    elif args.engine == "static":
        backend = backends.for_arch(arch, params, ecfg)
        t0 = time.perf_counter()
        gen = backend.static_reference(prompts[: args.batch], args.gen,
                                       temperature=args.temperature)
        dt = time.perf_counter() - t0
        print(f"static ({backend.name}): {args.batch}x{args.prompt_len}"
              f"+{args.gen} in {dt:.3f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s)")
        sample = gen
    else:
        from repro.serve import ChaosBackend, ChaosConfig, Supervisor, \
            SupervisorConfig

        n_req = args.requests or 2 * args.batch
        backend = backends.for_arch(arch, params, ecfg)
        if args.chaos_seed is not None:
            # faults are gated at ops whose injection fires before any
            # state mutation, so supervised retries stay bit-exact on
            # every backend (recurrent self-drafters included)
            backend = ChaosBackend(backend, ChaosConfig(
                seed=args.chaos_seed, p_fault=args.chaos_rate,
                transient_len=2, p_slot_fault=0.3,
                alloc_spike_every=8, alloc_spike_pages=2,
                ops=("decode_step", "prefill_chunks", "prefill_chunk",
                     "prefill_group", "draft_steps")))
        eng = ServingEngine(params, cfg, ecfg, backend=backend)
        sup = Supervisor(eng, SupervisorConfig(
            max_retries=args.max_retries))
        reqs = [Request(rid=i, prompt=prompts[i % len(prompts)],
                        max_new_tokens=args.gen,
                        temperature=args.temperature,
                        priority=args.priority,
                        deadline_ms=args.deadline_ms)
                for i in range(n_req)]
        t0 = time.perf_counter()
        done = sup.run(reqs)
        dt = time.perf_counter() - t0
        sup.close()
        total = sum(len(f.tokens) for f in done)
        st = eng.stats()
        print(f"continuous[{st['backend']}]: {n_req} requests "
              f"({args.prompt_len}+{args.gen}) "
              f"in {dt:.3f}s — {total / dt:.1f} tok/s, "
              f"{eng.steps} fused steps, batch={args.batch}, "
              f"chunks={st['chunks']} in "
              f"{st['prefill_dispatches']} dispatches, "
              f"preemptions={st['preemptions']}, "
              f"pages_hw={st['pages_high_water']}, "
              f"kernel_fallbacks={st['prefill_kernel_fallbacks']}, "
              f"prefix_hits={st['prefix_cache_hits']}, "
              f"pages_shared={st['pages_shared']}, "
              f"spec_accepted={st['spec_accepted']}/"
              f"{st['spec_drafted']}, "
              f"rejected={st['rejected']}, "
              f"deadline_expired={st['deadline_expired']}, "
              f"retries={st['retries']}, "
              f"quarantined={st['quarantined']}, "
              f"degradation_level={st['degradation_level']}")
        full = [f.tokens for f in done if f.reason == "complete"] \
            or [f.tokens for f in done]
        sample = np.stack(full[:2]) if full[0].size else np.zeros((1, 16))
    print("sample generations (token ids):")
    for b in range(min(2, sample.shape[0])):
        print(f"  [{b}] {sample[b, :16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
