"""Serving driver: prefill a batch of prompts, then batched decode.

Demonstrates the paper's technique where it matters most — O(m + s·k + w)
per decoded token vs O(context) for full attention.  CPU-scale with smoke
configs; the same step functions lower on the production mesh (the
decode_32k / long_500k dry-run cells).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 128 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data import DataConfig, synthetic_batch
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch, smoke=args.smoke)
    if arch.family not in ("dense", "moe", "vlm"):
        raise SystemExit("serve.py drives decoder LMs; use examples/ for "
                         "whisper/ssm serving")
    cfg = arch.model
    capacity = args.prompt_len + args.gen
    # MiTA decode capacity must be window-aligned
    w = cfg.attn.window
    capacity = ((capacity + w - 1) // w) * w

    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                      global_batch=args.batch)
    prompts = jnp.asarray(synthetic_batch(dcfg, 0)["tokens"])

    prefill = jax.jit(lambda p, t: tfm.lm_prefill(p, t, cfg, capacity))
    decode = jax.jit(lambda p, st, tok, pos: tfm.lm_decode_step(
        p, st, tok, pos, cfg))

    t0 = time.time()
    logits, states = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, states = decode(params, states, tok, pos)
        if args.temperature > 0:
            key = jax.random.PRNGKey(1000 + i)
            tok = jax.random.categorical(
                key, logits / args.temperature, axis=-1).astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.3f}s")
    print(f"decode:  {args.gen-1} steps, {t_decode:.3f}s "
          f"({tps:.1f} tok/s, batch={args.batch})")
    print("sample generations (token ids):")
    for b in range(min(2, args.batch)):
        print(f"  [{b}] {gen[b, :16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
