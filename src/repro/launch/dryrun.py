import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: a sharding
mismatch, compile-time OOM, or unsupported collective fails the cell.
Writes one JSON record per cell (memory analysis, cost analysis, collective
schedule, roofline terms) to results/dryrun/<arch>_<shape>_<mesh>.json —
resumable, so a long sweep can be interrupted and restarted.

Usage:
  python -m repro.launch.dryrun --all
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --multi-pod
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.analysis import roofline as rl
from repro.configs.registry import ARCHS, SHAPES, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _depth_variant(arch, n_layers: int):
    """Same arch at reduced depth with layer scans fully unrolled."""
    return dataclasses.replace(
        arch, model=dataclasses.replace(
            arch.model, n_layers=n_layers, scan_unroll=True))


def _measure(arch, shape, mesh, state_policy: str = "seq",
             microbatch: int = 1):
    cell = build_cell(arch, shape, mesh, state_policy=state_policy,
                      microbatch=microbatch)
    # NOTE: must lower inside the mesh context — bare-PartitionSpec
    # with_sharding_constraints (MoE EP layout) need the ambient mesh.
    with mesh:
        lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                          out_shardings=cell.out_shardings,
                          donate_argnums=cell.donate_argnums).lower(*cell.args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = rl.collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)), coll)


def calibrated_roofline(arch, shape, mesh, mesh_name: str,
                        model_flops: float,
                        state_policy: str = "seq",
                        microbatch: int = 1) -> rl.Roofline:
    """Scan-trip-count-corrected roofline terms.

    XLA cost_analysis counts a while-loop (scan) body ONCE (verified in
    EXPERIMENTS.md §Dry-run calibration), so deep models are under-counted.
    We compile the cell at two shallow depths with scans UNROLLED (counted
    exactly), fit flops/bytes/collective-bytes linearly in depth, and
    extrapolate to the full layer count.
    """
    unit = 3 if arch.family == "hybrid" else 1
    n1, n2 = 2 * unit, 4 * unit
    f1, b1, c1 = _measure(_depth_variant(arch, n1), shape, mesh,
                          state_policy, microbatch)
    f2, b2, c2 = _measure(_depth_variant(arch, n2), shape, mesh,
                          state_policy, microbatch)
    l_eff = (arch.model.n_layers // unit) * unit

    def extrap(v1, v2):
        slope = max(0.0, (v2 - v1) / (n2 - n1))
        return v1 + slope * (l_eff - n1)

    kinds = set(c1) | set(c2)
    coll = {k: extrap(c1.get(k, 0.0), c2.get(k, 0.0)) for k in kinds}
    return rl.Roofline(
        name=f"{arch.arch_id}:{shape.name}", mesh=mesh_name,
        n_devices=mesh.size,
        flops_per_chip=extrap(f1, f2),
        bytes_per_chip=extrap(b1, b2),
        coll_bytes_per_chip=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops,
    )


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str, force: bool = False,
             backend_override: str | None = None,
             tag: str = "", state_policy: str = "seq",
             attn_overrides: dict | None = None,
             microbatch: int = 1) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    fname = f"{arch_id}_{shape_name}_{mesh_name}{tag}.json"
    path = os.path.join(out_dir, fname)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    arch = get_arch(arch_id, backend=backend_override)
    if attn_overrides:
        arch = dataclasses.replace(
            arch, model=dataclasses.replace(
                arch.model, attn=dataclasses.replace(
                    arch.model.attn, **attn_overrides)))
    shape = SHAPES[shape_name]
    ok, why = arch.shape_supported(shape)
    rec: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                 "backend": backend_override or arch.model.attn.backend,
                 "state_policy": state_policy,
                 "attn_overrides": attn_overrides or {},
                 "microbatch": microbatch}
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(path, rec)
        return rec
    if why:
        rec["note"] = why

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        cell = build_cell(arch, shape, mesh, state_policy=state_policy,
                          microbatch=microbatch)
        with mesh:
            lowered = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate_argnums,
            ).lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        raw = rl.from_compiled(
            cell.name, mesh_name, mesh.size, compiled,
            model_flops=rl.model_flops_for(arch, shape))
        roof = calibrated_roofline(arch, shape, mesh, mesh_name,
                                   rl.model_flops_for(arch, shape),
                                   state_policy=state_policy,
                                   microbatch=microbatch)
        rec["roofline_raw_body_once"] = raw.to_dict()
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory=dict(
                argument_bytes=mem.argument_size_in_bytes,
                output_bytes=mem.output_size_in_bytes,
                temp_bytes=mem.temp_size_in_bytes,
                alias_bytes=mem.alias_size_in_bytes,
                peak_per_device=mem.argument_size_in_bytes
                + mem.output_size_in_bytes + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes,
            ),
            roofline=roof.to_dict(),
        )
    except Exception as e:  # noqa: BLE001 — record the failure
        rec.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    _write(path, rec)
    return rec


def _write(path: str, rec: dict):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--backend", default=None,
                    help="attention backend override (e.g. full for the "
                         "paper-baseline comparison)")
    ap.add_argument("--tag", default="", help="suffix for result files")
    ap.add_argument("--state-policy", default="seq", choices=["seq", "dh"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--attn", default="",
                    help="attention overrides, e.g. impl=capacity,"
                         "route_per_group=true,block_q=512")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    overrides = {}
    for kv in filter(None, args.attn.split(",")):
        key, val = kv.split("=")
        if val.lower() in ("true", "false"):
            overrides[key] = val.lower() == "true"
        elif val.replace(".", "").isdigit():
            overrides[key] = float(val) if "." in val else int(val)
        else:
            overrides[key] = val

    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]

    n_fail = 0
    for arch_id in archs:
        for shape_name in shapes:
            for mp in meshes:
                t0 = time.time()
                rec = run_cell(arch_id, shape_name, mp, args.out,
                               force=args.force,
                               backend_override=args.backend, tag=args.tag,
                               state_policy=args.state_policy,
                               attn_overrides=overrides,
                               microbatch=args.microbatch)
                status = rec.get("status")
                msg = f"[{time.strftime('%H:%M:%S')}] " \
                      f"{arch_id:20s} {shape_name:12s} " \
                      f"{'2x16x16' if mp else '16x16':8s} {status:8s} " \
                      f"({time.time()-t0:6.1f}s)"
                if status == "ok":
                    r = rec["roofline"]
                    msg += (f" bottleneck={r['bottleneck']:10s} "
                            f"t={max(r['t_compute'], r['t_memory'], r['t_collective']):.3e}s "
                            f"mem/dev={rec['memory']['peak_per_device']/2**30:.2f}GiB")
                elif status == "failed":
                    n_fail += 1
                    msg += " " + rec.get("error", "")[:120]
                print(msg, flush=True)
    print(f"done; failures: {n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
