"""Training driver: data pipeline -> sharded train_step -> checkpoints.

CPU-scale by default (reduced configs, host mesh); the same driver lowers
onto the production mesh on real hardware.  Fault-tolerance wiring:
`--simulate-failure N` raises at step N to exercise restart-from-checkpoint.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import SHAPES, ShapeSpec, get_arch
from repro.data import DataConfig, synthetic_batch
from repro.distributed import sharding as shd
from repro.distributed.fault_tolerance import StepTimer
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import abstract_params, build_cell, family_fns
from repro.optim import OptConfig, adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--simulate-failure", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch, smoke=args.smoke)
    cfg = arch.model
    shape = ShapeSpec("cli", "train", args.seq, args.batch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(args.data_parallel, args.model_parallel))
    opt_cfg = OptConfig(lr=args.lr, total_steps=max(args.steps, 10),
                        warmup_steps=max(2, args.steps // 20))

    cell = build_cell(arch, shape, mesh, opt_cfg=opt_cfg)
    fns = family_fns(arch)

    with mesh:
        params = jax.jit(fns["init"],
                         out_shardings=cell.in_shardings[0])(
            jax.random.PRNGKey(0))
        opt_state = jax.jit(adamw_init,
                            out_shardings=cell.in_shardings[1])(params)
        step_fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                          out_shardings=cell.out_shardings,
                          donate_argnums=cell.donate_argnums)

        dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
        start = 0
        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        if ckpt and args.resume and ckpt.latest_step() is not None:
            start, (params, opt_state) = ckpt.restore((params, opt_state))
            print(f"resumed from step {start}")

        timer = StepTimer()
        for step in range(start, args.steps):
            host = synthetic_batch(dcfg, step)
            batch = {"tokens": host["tokens"], "labels": host["labels"]}
            if arch.family == "vlm":
                batch["image_embeds"] = np.zeros(
                    (args.batch, arch.n_img_tokens, cfg.d_model), np.float32)
            if arch.family == "encdec":
                batch = {
                    "audio_embeds": np.random.default_rng(step).standard_normal(
                        (args.batch, arch.t_enc, cfg.d_model)).astype(np.float32),
                    "tokens": host["tokens"][:, : arch.dec_len],
                    "labels": host["labels"][:, : arch.dec_len],
                }
            if step == args.simulate_failure:
                raise RuntimeError("simulated node failure")
            with timer:
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"dt {timer.last:.3f}s"
                      + (" [straggling]" if timer.is_straggling else ""),
                      flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state))
        if ckpt:
            ckpt.save(args.steps, (params, opt_state))
            ckpt.wait()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
