"""Fault tolerance & elasticity for long-running multi-pod jobs.

Mechanisms (exercised by tests/test_fault_tolerance.py on the CPU simulator;
the same code paths run unchanged under real multi-host jax.distributed):

  1. **Checkpoint/restart** — `run_with_restarts` wraps the train loop;
     any step exception (preemption, ICI link flap, host OOM) triggers a
     restore-from-latest and replay.  Data is stateless-resumable
     (`repro.data`), so replayed steps are bit-identical.
  2. **Elastic rescale** — `elastic_retarget` re-places a checkpointed
     pytree onto a *different* mesh (e.g. 512 -> 256 chips after losing a
     pod).  Works because checkpoints are stored unsharded and partition
     specs are derived from the params, not baked into the checkpoint.
  3. **Straggler mitigation** — `StepTimer` keeps an EWMA of step wall time;
     a step slower than ``threshold×`` the EWMA marks the host a straggler.
     The documented policy at scale: report to the coordinator, which
     (a) excludes the host at the next checkpoint boundary and
     (b) triggers elastic rescale.  On-CPU we can only unit-test the
     detector itself.
"""

from __future__ import annotations

import logging
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.sharding import param_specs, tree_shardings

log = logging.getLogger("repro.ft")


class StepTimer:
    """EWMA step timer with straggler detection.

    Shared between the training harness (per-host step times at scale)
    and the serving supervisor (`serve/supervisor.py` wraps every
    engine step in one to spot injected or organic slow steps);
    ``n_stragglers`` accumulates how many observed steps tripped the
    threshold so both consumers report one number."""

    def __init__(self, alpha: float = 0.1, threshold: float = 3.0):
        self.alpha = alpha
        self.threshold = threshold
        self.ewma: Optional[float] = None
        self._prev_ewma: Optional[float] = None   # EWMA before the last obs
        self.last: Optional[float] = None
        self._t0: Optional[float] = None
        self.n_stragglers = 0           # observations past the threshold

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.observe(time.perf_counter() - self._t0)
        return False

    def observe(self, dt: float):
        self.last = dt
        self._prev_ewma = self.ewma
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        if self.is_straggling:
            self.n_stragglers += 1

    @property
    def is_straggling(self) -> bool:
        """Compare the last step against the EWMA of *prior* steps — an
        outlier must not be allowed to raise its own baseline."""
        return (self._prev_ewma is not None and self.last is not None
                and self.last > self.threshold * self._prev_ewma)


def run_with_restarts(step_fn: Callable[[int, Any], Any],
                      init_state: Any,
                      ckpt: CheckpointManager,
                      n_steps: int,
                      ckpt_every: int = 50,
                      max_restarts: int = 3) -> Any:
    """Drive ``step_fn(step, state) -> state`` with restart-on-failure.

    On exception: restore the latest checkpoint and replay from there.
    Determinism of the data pipeline makes the replay exact.
    """
    state = init_state
    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        start, state = ckpt.restore(state)
        log.info("resumed from step %d", start)

    restarts = 0
    step = start
    while step < n_steps:
        try:
            state = step_fn(step, state)
            step += 1
            if step % ckpt_every == 0:
                ckpt.save(step, state)
        except Exception as e:  # noqa: BLE001 — any step failure
            restarts += 1
            if restarts > max_restarts:
                raise
            log.warning("step %d failed (%s); restart %d/%d",
                        step, e, restarts, max_restarts)
            latest = ckpt.latest_step()
            if latest is None:
                state, step = init_state, 0
            else:
                ckpt.wait()
                step, state = ckpt.restore(state)
    ckpt.wait()
    return state


def elastic_retarget(tree: Any, new_mesh) -> Any:
    """Re-place a pytree onto a new mesh using the standard param rules —
    the restore path after the job's topology changed."""
    specs = param_specs(tree, new_mesh)
    shardings = tree_shardings(specs, new_mesh)
    return jax.tree.map(jax.device_put, tree, shardings)
