from repro.distributed.sharding import (batch_spec, param_specs,
                                        state_specs, tree_shardings)
