"""Logical-axis sharding rules: param/batch/state pytrees -> PartitionSpecs.

Parallelism map (DESIGN.md):
  * DP  — batch over ("pod", "data").
  * TP  — attention heads / FFN hidden / vocab over "model"
          (Megatron pairing: column-parallel in-proj, row-parallel out-proj,
          so each block needs only one all-reduce per pass).
  * EP  — MoE expert dim over "model".
  * SP  — sequence over "data" (+"model" for decode caches) when the batch
          axis is too small to shard (long-context decode, batch 1).

Rules are matched on the flattened parameter path (regex on the joined
path).  Stacked per-layer params (leading scan dim) get `None` prepended
automatically.  Unmatched params are replicated — a safe default.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# NOTE: mesh-invariant init depends on jax_threefry_partitionable=True,
# set in repro/__init__.py (package import, so entry-point order can't
# produce divergent RNG streams).

# (path regex, spec WITHOUT the stacked layer dim)
PARAM_RULES: list[tuple[str, P]] = [
    # attention projections (also whisper xattn; rglru/mamba in/out)
    (r"(attn|xattn)/w[qkv]$", P(None, "model")),
    (r"(attn|xattn)/wo$", P("model", None)),
    # dense FFN: column-parallel in, row-parallel out
    (r"(ffn|ffn1|mlp|shared)/w[ig]$", P(None, "model")),
    (r"(ffn|ffn1|mlp|shared)/wo$", P("model", None)),
    (r"(mlp)/bi$", P("model")),
    # MoE experts: EP over "model"
    (r"moe/w[ig]$", P("model", None, None)),
    (r"moe/wo$", P("model", None, None)),
    (r"moe/router$", P(None, None)),
    # embeddings: vocab-sharded
    (r"emb/tok$", P("model", None)),
    (r"emb/head$", P(None, "model")),
    # recurrent blocks: recurrent width over "model"
    (r"(rec\d|.*)/(w_in|w_gate|w_a|w_x)$", P(None, "model")),
    (r"(rec\d|.*)/w_out$", P("model", None)),
    (r"conv$", P(None, "model")),
    (r"(b_a|b_x|lam)$", P("model")),
    (r"ln_y$", P("model")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


_STACKED_ROOTS = ("blocks", "supers", "enc", "dec")


def spec_for_param(path_str: str, ndim: int,
                   shape: tuple[int, ...],
                   model_size: int = 1) -> P:
    stacked = any(f"{r}/" in path_str or path_str.startswith(f"{r}/")
                  for r in _STACKED_ROOTS)
    base_ndim = ndim - 1 if stacked else ndim
    for pattern, spec in PARAM_RULES:
        if re.search(pattern, path_str):
            if len(spec) > base_ndim:
                continue
            padded = tuple(spec) + (None,) * (base_ndim - len(spec))
            # verify divisibility of the sharded dims; replicate otherwise
            dims = shape[1:] if stacked else shape
            ok = all(ax is None or dims[i] % model_size == 0
                     for i, ax in enumerate(padded))
            if not ok:
                padded = tuple(None for _ in padded)
            return P(*(((None,) + padded) if stacked else padded))
    return P(*([None] * ndim))


def param_specs(params: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree for a model parameter pytree."""
    msize = int(np.prod([mesh.shape[a] for a in ("model",)
                         if a in mesh.shape]))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [spec_for_param(_path_str(p), np.ndim(x), np.shape(x), msize)
             for p, x in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh: Mesh, batch: int, rank: int = 2,
               shard_seq_if_small: bool = True) -> P:
    """Spec for [B, S, ...] host batches.  If B can't be sharded (e.g.
    long-context batch 1) shard the sequence dim instead (SP)."""
    dp = batch_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    if batch % dp_size == 0:
        return P(dp, *([None] * (rank - 1)))
    if shard_seq_if_small and rank >= 2:
        return P(None, dp, *([None] * (rank - 2)))
    return P(*([None] * rank))


def state_specs(state: Any, mesh: Mesh, batch: int,
                policy: str = "seq") -> Any:
    """Specs for stacked decode-state pytrees [L, B, ...].

    policy="seq" (baseline): KV caches ([L, B, Hkv, C, d]) shard B over DP
    axes and the cache length C over "model" (kv-head counts are often <
    TP width, so TP shards the *time* dim).  The dry-run showed this makes
    every cache update/slice a cross-shard reshard — GSPMD "involuntary full
    rematerialization" — so decode cells are collective-bound.

    policy="dh" (§Perf optimized): shard the trailing head/feature dim over
    "model" instead.  Cache writes (dynamic_update_slice over C) and local-
    window slices become shard-local; attention contractions over the
    sharded d produce small partial-sum all-reduces ([B,H,G,·] logits)
    instead of cache-sized reshards.
    """
    dp = batch_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    msize = mesh.shape.get("model", 1)
    b_ax = dp if batch % dp_size == 0 else None

    def spec(path, x):
        nd = np.ndim(x)
        shape = np.shape(x)
        name = _path_str(path)
        if nd <= 2:      # step counters, scalars
            return P(*([None] * nd))
        axes: list = [None] * nd
        axes[1] = b_ax
        if policy == "dh":
            if shape[-1] % msize == 0 and shape[-1] >= msize:
                axes[-1] = "model"
            return P(*axes)
        seq_dim = 3 if nd >= 4 else nd - 1  # [L,B,H,C,(d)] -> C at idx 3
        if nd >= 4 and shape[seq_dim] % msize == 0:
            if b_ax is None and shape[seq_dim] % (msize * dp_size) == 0:
                axes[seq_dim] = dp + ("model",)
            else:
                axes[seq_dim] = "model"
        if name.endswith("t"):
            return P(*([None] * nd))
        return P(*axes)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, x) for p, x in flat])


def tree_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
