"""tinyllama-1.1b — llama2-arch small [arXiv:2401.02385; hf]."""

from repro.configs.registry import ArchConfig, production_dtypes
from repro.models.modules import AttnConfig, ModelConfig

ARCH = ArchConfig(
    arch_id="tinyllama-1.1b",
    family="dense",
    model=production_dtypes(ModelConfig(
        name="tinyllama-1.1b",
        n_layers=22, d_model=2048, n_heads=32, n_kv=4,
        d_ff=5632, vocab=32000, rope_theta=1e4,
        attn=AttnConfig(backend="mita", window=128, k=128, s=1),
    )),
)
