"""mamba2-370m — SSD (state-space duality) [arXiv:2405.21060; unverified].
Attention-free: MiTA inapplicable (DESIGN.md §Arch-applicability); the SSD
state is the taxonomy's compressed fast-weight module.  d_inner = 2·d_model,
64-dim heads, ssm_state = 128."""

from repro.configs.registry import ArchConfig, production_dtypes
from repro.models.modules import AttnConfig, ModelConfig

ARCH = ArchConfig(
    arch_id="mamba2-370m",
    family="ssm",
    model=production_dtypes(ModelConfig(
        name="mamba2-370m",
        n_layers=48, d_model=1024, n_heads=32, n_kv=32,
        d_ff=0, vocab=50280,
        attn=AttnConfig(backend="full"),  # unused (attention-free)
    )),
)
