"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066; hf].  (Deviation: the reference model's first layer is a
dense FFN; here all layers are MoE — recorded in DESIGN.md.)"""

from repro.configs.registry import ArchConfig, production_dtypes
from repro.models.modules import AttnConfig, ModelConfig

ARCH = ArchConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    model=production_dtypes(ModelConfig(
        name="deepseek-moe-16b",
        n_layers=28, d_model=2048, n_heads=16, n_kv=16,
        d_ff=1408, vocab=102400, rope_theta=1e4,
        n_experts=64, moe_top_k=6, n_shared_experts=2,
        attn=AttnConfig(backend="mita", window=128, k=128, s=1),
    )),
)
