"""Architecture registry + input-shape grid (the assigned 10 × 4 cells).

Each assigned architecture lives in its own module (``repro.configs.<id>``,
dashes -> underscores) exporting ``ARCH: ArchConfig`` with the exact public
config, plus a reduced ``smoke_variant`` for CPU tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax.numpy as jnp

from repro.models.modules import AttnConfig, ModelConfig

ARCHS = [
    "internvl2-76b", "deepseek-moe-16b", "dbrx-132b", "tinyllama-1.1b",
    "qwen3-0.6b", "qwen3-32b", "stablelm-1.6b", "recurrentgemma-9b",
    "mamba2-370m", "whisper-tiny",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                     # dense | moe | vlm | hybrid | ssm | encdec
    model: ModelConfig
    n_img_tokens: int = 0           # vlm stub frontend
    t_enc: int = 0                  # encdec stub frontend
    dec_len: int = 0                # encdec decoder length (whisper: 448)
    notes: str = ""

    def shape_supported(self, shape: ShapeSpec) -> tuple[bool, str]:
        """DESIGN.md §Arch-applicability shape policy."""
        if self.family == "encdec":
            if shape.name == "long_500k":
                return False, ("whisper decoder max context is 448 by "
                               "construction; 500k decode is not defined "
                               "for this family (DESIGN.md)")
            if shape.kind == "decode":
                return True, ("substituted: decoder-native decode (cap 448) "
                              "with a 32k-scale encoder memory is not "
                              "defined either; we lower native decode")
        return True, ""


def get_arch(arch_id: str, *, smoke: bool = False,
             backend: Optional[str] = None) -> ArchConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    arch: ArchConfig = mod.ARCH
    if smoke:
        arch = smoke_variant(arch)
    if backend is not None:
        arch = dataclasses.replace(
            arch, model=dataclasses.replace(
                arch.model,
                attn=dataclasses.replace(arch.model.attn, backend=backend)))
    return arch


def arch_params(arch: ArchConfig, rng) -> "object":
    """Registry-routed parameter construction for the servable families —
    the single place `launch.serve` (and anything else that wants to serve
    an arbitrary registry architecture) resolves family → init function,
    so smoke variants like ``mamba2-370m`` or ``recurrentgemma-9b`` serve
    without bespoke wiring.  Lazy imports keep config modules light."""
    if arch.family in ("dense", "moe", "vlm"):
        from repro.models import transformer as tfm
        return tfm.lm_init(rng, arch.model)
    if arch.family == "ssm":
        from repro.models.mamba2 import mamba_init
        return mamba_init(rng, arch.model)
    if arch.family == "hybrid":
        from repro.models.rglru import rg_init
        return rg_init(rng, arch.model)
    raise ValueError(
        f"family {arch.family!r} has no servable parameter constructor "
        "(whisper's enc-dec decode is driven from examples/, not serve)")


def production_dtypes(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, param_dtype=jnp.float32,
                               compute_dtype=jnp.bfloat16, remat=True)


def smoke_variant(arch: ArchConfig) -> ArchConfig:
    """Reduced same-family config: small widths/depth/vocab, f32, no remat."""
    m = arch.model
    sm = dataclasses.replace(
        m,
        n_layers=min(m.n_layers, 6 if arch.family == "hybrid" else 2),
        d_model=128,
        n_heads=4,
        n_kv=max(1, min(m.n_kv, 2 if m.n_kv < m.n_heads else 4)),
        head_dim=32,
        d_ff=64 if m.n_experts else 256,
        vocab=251,
        n_experts=min(m.n_experts, 8),
        moe_top_k=min(m.moe_top_k, 2),
        n_shared_experts=min(m.n_shared_experts, 1),
        attn=dataclasses.replace(m.attn, window=16, k=16, block_q=16,
                                 enc_window=16 if m.attn.enc_window else 0),
        param_dtype=jnp.float32,
        compute_dtype=jnp.float32,
        remat=False,
    )
    return dataclasses.replace(
        arch, model=sm,
        n_img_tokens=min(arch.n_img_tokens, 16),
        t_enc=min(arch.t_enc, 64),
        dec_len=min(arch.dec_len, 32))
