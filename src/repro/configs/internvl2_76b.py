"""internvl2-76b — InternViT + InternLM2 [arXiv:2404.16821; unverified].

VLM: the transformer BACKBONE only (InternLM2-70B-class decoder); the ViT
frontend is a STUB — ``input_specs`` supplies precomputed patch embeddings
injected over the first ``n_img_tokens`` positions (DESIGN.md).
"""

from repro.configs.registry import ArchConfig, production_dtypes
from repro.models.modules import AttnConfig, ModelConfig

ARCH = ArchConfig(
    arch_id="internvl2-76b",
    family="vlm",
    model=production_dtypes(ModelConfig(
        name="internvl2-76b",
        n_layers=80, d_model=8192, n_heads=64, n_kv=8,
        d_ff=28672, vocab=128256, rope_theta=1e6,
        attn=AttnConfig(backend="mita", window=128, k=128, s=1),
    )),
    n_img_tokens=256,
)
