"""recurrentgemma-9b — RG-LRU + local attn, 1:2 [arXiv:2402.19427;
unverified].  MQA (kv=1).

Implemented as 13 scanned super-blocks of (RG-LRU, RG-LRU, attention) = 39
layers vs the reference 38 (the 1:2 pattern doesn't tile 38 exactly;
recorded in DESIGN.md).  MiTA replaces the local-attention layers; RG-LRU
layers are attention-free (paper-taxonomy: recurrent compression expert).
"""

from repro.configs.registry import ArchConfig, production_dtypes
from repro.models.modules import AttnConfig, ModelConfig

ARCH = ArchConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    model=production_dtypes(ModelConfig(
        name="recurrentgemma-9b",
        n_layers=39, d_model=4096, n_heads=16, n_kv=1,
        d_ff=12288, vocab=256000, rope_theta=1e4,
        attn=AttnConfig(backend="mita", window=128, k=128, s=1,
                        local_window=2048),
    )),
)
