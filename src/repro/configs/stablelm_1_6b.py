"""stablelm-1.6b — [hf:stabilityai/stablelm-2-1_6b; unverified].  MHA
(kv == heads).  (Deviation: RMSNorm instead of LayerNorm — DESIGN.md.)"""

from repro.configs.registry import ArchConfig, production_dtypes
from repro.models.modules import AttnConfig, ModelConfig

ARCH = ArchConfig(
    arch_id="stablelm-1.6b",
    family="dense",
    model=production_dtypes(ModelConfig(
        name="stablelm-1.6b",
        n_layers=24, d_model=2048, n_heads=32, n_kv=32,
        d_ff=5632, vocab=100352, rope_theta=1e4,
        attn=AttnConfig(backend="mita", window=128, k=128, s=1),
    )),
)
