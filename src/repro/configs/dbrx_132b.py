"""dbrx-132b — 16 experts top-4, fine-grained [hf:databricks/dbrx-base;
unverified]."""

from repro.configs.registry import ArchConfig, production_dtypes
from repro.models.modules import AttnConfig, ModelConfig

ARCH = ArchConfig(
    arch_id="dbrx-132b",
    family="moe",
    model=production_dtypes(ModelConfig(
        name="dbrx-132b",
        n_layers=40, d_model=6144, n_heads=48, n_kv=8,
        d_ff=10752, vocab=100352, rope_theta=5e5,
        n_experts=16, moe_top_k=4, n_shared_experts=0,
        attn=AttnConfig(backend="mita", window=128, k=128, s=1),
    )),
)
