"""qwen3-0.6b — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].
head_dim=128 (Qwen3 uses a fixed 128 head dim, decoupled from d_model)."""

from repro.configs.registry import ArchConfig, production_dtypes
from repro.models.modules import AttnConfig, ModelConfig

ARCH = ArchConfig(
    arch_id="qwen3-0.6b",
    family="dense",
    model=production_dtypes(ModelConfig(
        name="qwen3-0.6b",
        n_layers=28, d_model=1024, n_heads=16, n_kv=8, head_dim=128,
        d_ff=3072, vocab=151936, rope_theta=1e6, qk_norm=True,
        tie_embeddings=True,
        attn=AttnConfig(backend="mita", window=128, k=128, s=1),
    )),
)
