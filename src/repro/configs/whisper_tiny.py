"""whisper-tiny — enc-dec, conv frontend STUB [arXiv:2212.04356; unverified].

``input_specs`` supplies precomputed frame embeddings [B, 1500, 384].
MiTA runs bidirectionally in the encoder (the paper's native mode: m=25
landmarks over 1500 frames, cf. the paper's vision m=k=25 default) and
causally in the decoder; cross-attention stays full (DESIGN.md).
"""

from repro.configs.registry import ArchConfig, production_dtypes
from repro.models.modules import AttnConfig, ModelConfig

ARCH = ArchConfig(
    arch_id="whisper-tiny",
    family="encdec",
    model=production_dtypes(ModelConfig(
        name="whisper-tiny",
        n_layers=4, d_model=384, n_heads=6, n_kv=6,
        d_ff=1536, vocab=51865, rope_theta=1e4,
        attn=AttnConfig(backend="mita", window=64, k=64, s=1,
                        enc_window=60),
    )),
    t_enc=1500,
    dec_len=448,
)
