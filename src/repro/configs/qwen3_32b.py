"""qwen3-32b — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].  head_dim=128."""

from repro.configs.registry import ArchConfig, production_dtypes
from repro.models.modules import AttnConfig, ModelConfig

ARCH = ArchConfig(
    arch_id="qwen3-32b",
    family="dense",
    model=production_dtypes(ModelConfig(
        name="qwen3-32b",
        n_layers=64, d_model=5120, n_heads=64, n_kv=8, head_dim=128,
        d_ff=25600, vocab=151936, rope_theta=1e6, qk_norm=True,
        attn=AttnConfig(backend="mita", window=128, k=128, s=1),
    )),
)
