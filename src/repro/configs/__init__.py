from repro.configs.registry import (ARCHS, SHAPES, ArchConfig, ShapeSpec,
                                    get_arch, smoke_variant)
