"""Fused batched chunk-prefill MiTA kernel (TPU Pallas; interpret on CPU).

One window-aligned prefill chunk for EVERY active slot, per (slot, KV-head)
program — the arithmetic-dense prefill counterpart of the paged-decode
kernel (`mita_paged_attn.py`).  Each program:

  * **append** — DMAs the chunk's valid K/V rows straight into the slot's
    pages (``page_table[s, pos // w] * w + pos % w``; scratch row for
    padding and inactive rows), pools aliased in/out so the write is in
    place;
  * **context gather** — DMAs the slot's whole page set HBM→VMEM in token
    order (context index == token position) and patches the just-appended
    rows from registers, so every downstream read is append-order exact;
  * **landmark build** — resumes the open-window query sums (both the
    decode cache's w-sized windows and the training head's n//m-sized
    prompt windows, `core.mita_decode.mita_batched_chunk_prefill`'s A/B
    systems), scores each completed window against the gathered context
    with one in-kernel top-k, and commits landmark queries/values + global
    expert rows exactly where the XLA oracle does;
  * **chunk attention** — shared + routed + local branches for every chunk
    position, per-position A/B selection (training vs decode landmark
    availability), merged with ONE online softmax over the concatenated
    branch logits — the expert gathers resolve through the VMEM context via
    exact one-hot matmuls (0·x == 0 and 1·x == x bit-exactly for finite x),
    so no per-row DMA is needed on this path.

The XLA path in `core.mita_decode.mita_batched_chunk_prefill` is the
fallback and the bit-exact oracle: `tests/test_kernel_oracle.py` pins
pages, landmarks, expert rows, and the resumed q_sum state bit-identical
(f32 pools) across ragged resume points, non-aligned heads, preemption
recompute, and inactive slots.

Per-program VMEM working set (budget-checked by
`kernels.ops.chunk_prefill_vmem_bytes`): the gathered context ``2·ctx·d``,
chunk q/k/v/out ``(2G+2)·nc·d``, landmark tiles ``8·M·d``, expert tiles
``2·M·K·d``, and the f32 score rows.  The local-branch score matrix is
TILED over query window-groups (static ``q_block`` from
`kernels.ops.select_prefill_q_block`): each tile of ``q_block`` windows
scores only a ``(q_block + 2)``-window key slab, so the local term is
``G·(q_block·w)·kb`` instead of ``G·nc·ctx`` and production chunk shapes
fit the budget instead of tripping `prefill_kernel_fallbacks`.  Because
``w_a <= 2w - 1``, every position's whole local window lies inside its
tile's slab — complete per-position partials, no online-softmax rescale,
bit-identical at every tile size.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _first_argmax(x):
    """Row-wise (max, first-index-of-max) of [R, C] — the lax.top_k /
    jnp.argmax tie rule, expressed as two vector reduces."""
    c = x.shape[-1]
    cid = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    mx = jnp.max(x, axis=-1)
    ix = jnp.min(jnp.where(x == mx[..., None], cid, c), axis=-1)
    return mx, ix.astype(jnp.int32)


def _topk(x, k: int):
    """Iterative top-k over the last axis of [R, C]; bit-identical values
    and indices to `jax.lax.top_k` (descending, ties by ascending index).
    Selected lanes are retired with -inf, strictly below the NEG_INF used
    for masking, so duplicates of NEG_INF still come out in index order."""
    cid = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    vals, idxs = [], []
    for _ in range(k):
        mx, ix = _first_argmax(x)
        vals.append(mx)
        idxs.append(ix)
        x = jnp.where(cid == ix[..., None], -jnp.inf, x)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def _onehot_gather(idx, table):
    """Exact VMEM gather: rows ``table[idx]`` via a one-hot matmul.
    idx: [R] int32 (out-of-range -> zero row); table: [C, d]."""
    c = table.shape[0]
    oh = (jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], c), 1)
          == idx[:, None]).astype(jnp.float32)
    return jax.lax.dot_general(oh, table.astype(jnp.float32),
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _dot(a, b):
    """[R, d] x [C, d] -> [R, C] f32 contraction over the trailing dim."""
    return jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _softmax(x):
    """Replicates jax.nn.softmax(x, axis=-1) op-for-op (bit-parity with
    the XLA oracle's landmark-value softmax)."""
    mx = jnp.max(x, axis=-1, keepdims=True)
    un = jnp.exp(x - mx)
    return un / jnp.sum(un, axis=-1, keepdims=True)


def _partial(s, p_zero):
    """`combine.Partial` statistics of pre-masked scores [R, C]:
    (m [R], l [R], p [R, C]); ``p_zero`` masks the zeroed lanes exactly as
    the oracle does (scores == NEG_INF or an explicit mask)."""
    m = jnp.max(s, axis=-1)
    safe = jnp.where(m == NEG_INF, 0.0, m)
    p = jnp.exp(s - safe[:, None])
    p = jnp.where(p_zero, 0.0, p)
    return m, jnp.sum(p, axis=-1), p


def _chunk_kernel(pt_ref, t0_ref, nv_ref, ntr_ref, act_ref,      # SMEM
                  q_ref, k_ref, v_ref, lmq_ref, lmv_ref, ei_ref, ev_ref,
                  qs_ref, plmq_ref, pqs_ref, kpool_ref, vpool_ref,
                  o_ref, lmq_o, lmv_o, ei_o, ev_o, qs_o, plmq_o, pqs_o,
                  kp_o, vp_o,
                  kctx, vctx, sem,
                  *, window: int, k_width: int, n_route: int,
                  external: bool, q_block: int):
    s = pl.program_id(0)
    h = pl.program_id(1)
    w = window
    nc = k_ref.shape[2]
    m_slot = lmq_ref.shape[2]
    g = q_ref.shape[2]
    d = q_ref.shape[4]
    ctx = m_slot * w
    n_rows = kp_o.shape[0]

    t0 = t0_ref[s]
    nv = nv_ref[s]
    ntr = ntr_ref[s]
    act = act_ref[s] == 1
    new_end = t0 + nv
    m_train = ntr // w
    m_a = jnp.maximum(m_train, 1)
    w_a = jnp.maximum(ntr // m_a, 1)

    # ---- 1. append the chunk's rows to the slot's pages (in place) ----
    def append_row(n, _):
        posn = t0 + n
        page = pt_ref[s, jnp.clip(posn // w, 0, m_slot - 1)]
        row = jnp.where(act & (n < nv), page * w + posn % w, n_rows - 1)
        ck = pltpu.make_async_copy(k_ref.at[0, 0, n], kp_o.at[row, h], sem)
        ck.start()
        ck.wait()
        cv = pltpu.make_async_copy(v_ref.at[0, 0, n], vp_o.at[row, h], sem)
        cv.start()
        cv.wait()
        return 0

    jax.lax.fori_loop(0, nc, append_row, 0)

    # ---- 2. gather the slot's context (token order), patch own rows ----
    def gather_page(mi, _):
        page = pt_ref[s, mi]
        base = pl.multiple_of(page * w, w)
        ck = pltpu.make_async_copy(kp_o.at[pl.ds(base, w), h],
                                   kctx.at[pl.ds(mi * w, w)], sem)
        ck.start()
        ck.wait()
        cv = pltpu.make_async_copy(vp_o.at[pl.ds(base, w), h],
                                   vctx.at[pl.ds(mi * w, w)], sem)
        cv.start()
        cv.wait()
        return 0

    jax.lax.fori_loop(0, m_slot, gather_page, 0)

    def patch_row(n, _):
        @pl.when(act & (n < nv))
        def _():
            kctx[pl.ds(t0 + n, 1)] = k_ref[0, 0, n][None].astype(kctx.dtype)
            vctx[pl.ds(t0 + n, 1)] = v_ref[0, 0, n][None].astype(vctx.dtype)
        return 0

    jax.lax.fori_loop(0, nc, patch_row, 0)

    k_ctx = kctx[...].astype(jnp.float32)               # [ctx, d]
    v_ctx = vctx[...].astype(jnp.float32)
    q = q_ref[0, 0].astype(jnp.float32)                 # [G, nc, d]
    ql = jnp.mean(q, axis=0)                            # [nc, d] group pool

    nid = jax.lax.broadcasted_iota(jnp.int32, (m_slot, nc), 1)
    lid = jax.lax.broadcasted_iota(jnp.int32, (m_slot, nc), 0)
    pos_n = t0 + nid[0:1]                               # [1, nc] positions
    valid_n = act & (nid[0:1] < nv)                     # [1, nc]
    li = lid[:, 0:1]                                    # [M, 1] landmark ids
    cid = jax.lax.broadcasted_iota(jnp.int32, (m_slot, ctx), 1)

    # ---- 3. B system: the decode cache (w-sized windows) ----
    win_b = (t0 + nid) // w
    tok_b = (valid_n & (win_b == lid)).astype(jnp.float32)
    sums_b = jax.lax.dot_general(tok_b, ql, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    m0 = t0 // w
    resume_b = (li == m0) & (t0 % w != 0)
    sums_b = sums_b + jnp.where(resume_b, qs_ref[0, 0][None], 0.0)
    q_lm_b = (sums_b / w).astype(lmq_ref.dtype)         # [M, d]
    wend = (li + 1) * w                                 # [M, 1]
    qdone_b = act & (wend > t0) & (wend <= new_end)
    lm_q_s = jnp.where(qdone_b, q_lm_b, lmq_ref[0, 0])

    ends_b = jnp.where(li < m_train, (li + 1) * w_a, wend)
    s_b = _dot(lm_q_s.astype(jnp.float32), k_ctx) / math.sqrt(d)
    s_b = jnp.where(cid < ends_b, s_b, NEG_INF)
    top_vals, top_loc = _topk(s_b, k_width)             # [M, K]
    new_valid = (top_vals > NEG_INF / 2).astype(jnp.int32)
    pt_vec = jnp.stack([pt_ref[s, j] for j in range(m_slot)])      # [M]
    ctx_rows = (pt_vec[:, None] * w
                + jax.lax.broadcasted_iota(jnp.int32, (m_slot, w), 1)
                ).reshape(1, ctx)                       # [1, ctx]
    mk_cid = jax.lax.broadcasted_iota(jnp.int32, (m_slot * k_width, ctx), 1)
    new_rows = jnp.sum(
        jnp.where(mk_cid == top_loc.reshape(-1)[:, None],
                  jnp.broadcast_to(ctx_rows, (m_slot * k_width, ctx)), 0),
        axis=-1).reshape(m_slot, k_width)
    p_b = _softmax(s_b)
    v_lm_b = jax.lax.dot_general(p_b, v_ctx, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32
                                 ).astype(lmv_ref.dtype)
    scommit = act & (ends_b > t0) & (ends_b <= new_end)
    lm_v_s = jnp.where(scommit, v_lm_b, lmv_ref[0, 0])
    ei_s = jnp.where(scommit, new_rows, ei_ref[0, 0])
    ev_s = jnp.where(scommit, new_valid, ev_ref[0, 0])

    m_new = new_end // w
    q_sum_s = jnp.sum(jnp.where(li == m_new, sums_b, 0.0), axis=0)
    q_sum_s = jnp.where(act, q_sum_s, qs_ref[0, 0])

    # ---- 4. A system: the training head's n//m-sized prompt windows ----
    is_tr_n = pos_n < ntr                               # [1, nc]
    win_a = (t0 + nid) // w_a
    tok_a = (valid_n & is_tr_n & (win_a == lid)).astype(jnp.float32)
    sums_a = jax.lax.dot_general(tok_a, ql, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    m0_a = t0 // w_a
    resume_a = (li == m0_a) & (t0 % w_a != 0) & (t0 < ntr)
    sums_a = sums_a + jnp.where(resume_a, pqs_ref[0, 0][None], 0.0)
    q_lm_a = (sums_a / w_a.astype(jnp.float32)).astype(plmq_ref.dtype)
    ends_a = (li + 1) * w_a                             # [M, 1]
    qdone_a = (act & (ends_a > t0) & (ends_a <= new_end) & (li < m_a))
    pre_lm_q_s = jnp.where(qdone_a, q_lm_a, plmq_ref[0, 0])

    # open-window sum: the resume contribution already sits inside
    # sums_a's open row, so selecting that row reproduces tail + resume
    open_a = new_end // w_a
    pre_q_sum_s = jnp.sum(jnp.where(li == open_a, sums_a, 0.0), axis=0)
    pre_q_sum_s = jnp.where(act, pre_q_sum_s, pqs_ref[0, 0])

    s_a = _dot(pre_lm_q_s.astype(jnp.float32), k_ctx) / math.sqrt(d)
    s_a = jnp.where((cid < ends_a) & (li < m_a), s_a, NEG_INF)
    tv_a, tl_a = _topk(s_a, k_width)                    # [M, K]
    val_a = (tv_a > NEG_INF / 2).astype(jnp.float32)
    k_e_a = _onehot_gather(tl_a.reshape(-1), k_ctx)     # [M*K, d]
    v_e_a = _onehot_gather(tl_a.reshape(-1), v_ctx)
    p_a = _softmax(s_a)
    v_lm_a = jax.lax.dot_general(p_a, v_ctx, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # B expert rows: stored GLOBAL pool rows -> context positions via the
    # slot's page table (no match -> ctx, i.e. a zero one-hot row; such
    # rows are expert_valid-masked downstream either way)
    ei_flat = ei_s.reshape(-1)                          # [M*K]
    page_of = ei_flat // w
    eq = pt_vec[None, :] == page_of[:, None]            # [M*K, M]
    mid = jax.lax.broadcasted_iota(jnp.int32, eq.shape, 1)
    ordn = jnp.min(jnp.where(eq, mid, m_slot), axis=-1)
    b_cidx = jnp.where(ordn < m_slot, ordn * w + ei_flat % w, ctx)
    k_e_b = _onehot_gather(b_cidx, k_ctx)               # [M*K, d]
    v_e_b = _onehot_gather(b_cidx, v_ctx)
    val_b = ev_s.reshape(-1).astype(jnp.float32)

    # ---- 5. chunk attention: shared + routed + local, A/B per position --
    q2 = q.reshape(g * nc, d)
    rows_pos = jnp.broadcast_to(pos_n, (g, nc)).reshape(g * nc, 1)
    rows_tr = jnp.broadcast_to(is_tr_n, (g, nc)).reshape(g * nc, 1)
    lm_id = jax.lax.broadcasted_iota(jnp.int32, (g * nc, m_slot), 1)

    def branch(lm_q_sys, v_lm_sys, k_e, v_e, val_e, avail):
        """Shared + routed partials of one landmark system.
        avail: [g*nc, M] bool; k_e/v_e: [M*K, d]; val_e: [M*K] f32."""
        r = _dot(q2, lm_q_sys.astype(jnp.float32)) / math.sqrt(d)
        r = jnp.where(avail, r, NEG_INF)
        m_sh, l_sh, p_sh = _partial(r, r == NEG_INF)
        o_sh = jax.lax.dot_general(p_sh, v_lm_sys,
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        lg_parts, mask_parts, v_parts = [], [], []
        r_route = r
        for _ in range(n_route):
            vj, ej = _first_argmax(r_route)             # [g*nc]
            ok_j = vj > NEG_INF / 2
            r_route = jnp.where(lm_id == ej[:, None], -jnp.inf, r_route)
            oh = (lm_id == ej[:, None]).astype(jnp.float32)
            k_sel = jax.lax.dot_general(
                oh, k_e.reshape(m_slot, k_width * d),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32
            ).reshape(g * nc, k_width, d)
            v_sel = jax.lax.dot_general(
                oh, v_e.reshape(m_slot, k_width * d),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32
            ).reshape(g * nc, k_width, d)
            vmask = jax.lax.dot_general(
                oh, val_e.reshape(m_slot, k_width),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32) > 0.5
            lg = jax.lax.dot_general(q2, k_sel,
                                     (((1,), (2,)), ((0,), (0,)))
                                     ) / math.sqrt(d)   # [g*nc, K]
            lg_parts.append(lg)
            mask_parts.append(vmask & ok_j[:, None])
            v_parts.append(v_sel)
        lg = jnp.concatenate(lg_parts, axis=-1)         # [g*nc, s*K]
        mask = jnp.concatenate(mask_parts, axis=-1)
        vals = jnp.concatenate(v_parts, axis=1)         # [g*nc, s*K, d]
        lg = jnp.where(mask, lg, NEG_INF)
        m_ro, l_ro, p_ro = _partial(lg, ~mask)
        o_ro = jax.lax.dot_general(p_ro, vals,
                                   (((1,), (1,)), ((0,), (0,))),
                                   preferred_element_type=jnp.float32)
        return (m_sh, l_sh, o_sh), (m_ro, l_ro, o_ro)

    avail_a = ((jnp.transpose(ends_a) <= rows_pos + 1)
               & (lm_id < m_a) & rows_tr)
    avail_b = ((jnp.transpose(wend) <= rows_pos + (0 if external else 1))
               & ~rows_tr)
    sh_a, ro_a = branch(pre_lm_q_s, v_lm_a, k_e_a, v_e_a,
                        val_a.reshape(-1), avail_a)
    sh_b, ro_b = branch(lm_q_s, lm_v_s.astype(jnp.float32), k_e_b, v_e_b,
                        val_b, avail_b)

    # local branch (ctx index == position).  Untiled (q_block == 0): one
    # [g*nc, ctx] masked score matrix.  Tiled (q_block > 0, requires
    # nc % w == 0): queries go in window-groups of q_block windows, each
    # scoring a (q_block + 2)-window key slab that starts two windows
    # before the tile — w_a <= 2w - 1, so every position's WHOLE local
    # window sits inside its tile's slab and no cross-tile merge (and no
    # rescaling) is needed: each lane is either identical to the untiled
    # matrix or masked to an exact zero in both, keeping the tiled path
    # bit-identical to the full-context one.
    if q_block == 0:
        s_loc = _dot(q2, k_ctx) / math.sqrt(d)          # [g*nc, ctx]
        crow = jax.lax.broadcasted_iota(jnp.int32, (g * nc, ctx), 1)
        win_row = jnp.where(rows_tr, (rows_pos // w_a) * w_a,
                            (rows_pos // w) * w)
        lmask = (crow >= win_row) & (crow <= rows_pos)
        s_loc = jnp.where(lmask, s_loc, NEG_INF)
        m_lo, l_lo, p_lo = _partial(s_loc, s_loc == NEG_INF)
        o_lo = jax.lax.dot_general(p_lo, v_ctx, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
    else:
        tw = q_block * w                                # tile width (tokens)
        kb = min((q_block + 2) * w, ctx)                # key-slab width
        n_tiles = nc // tw
        m_parts, l_parts, o_parts = [], [], []
        for ti in range(n_tiles):
            p0 = ti * tw
            qt = q[:, p0:p0 + tw, :].reshape(g * tw, d)
            tpos = (t0 + p0 + jax.lax.broadcasted_iota(
                jnp.int32, (g, tw), 1)).reshape(g * tw, 1)
            ttr = tpos < ntr
            twin = jnp.where(ttr, (tpos // w_a) * w_a, (tpos // w) * w)
            # t0 and p0 are both window-aligned, so the slab start is too
            base = pl.multiple_of(jnp.clip(t0 + p0 - 2 * w, 0, ctx - kb), w)
            kt = kctx[pl.ds(base, kb)].astype(jnp.float32)
            vt = vctx[pl.ds(base, kb)].astype(jnp.float32)
            st = _dot(qt, kt) / math.sqrt(d)            # [g*tw, kb]
            cpos = base + jax.lax.broadcasted_iota(
                jnp.int32, (g * tw, kb), 1)
            st = jnp.where((cpos >= twin) & (cpos <= tpos), st, NEG_INF)
            m_t, l_t, p_t = _partial(st, st == NEG_INF)
            o_t = jax.lax.dot_general(p_t, vt, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
            m_parts.append(m_t.reshape(g, tw))
            l_parts.append(l_t.reshape(g, tw))
            o_parts.append(o_t.reshape(g, tw, d))
        m_lo = jnp.concatenate(m_parts, axis=1).reshape(g * nc)
        l_lo = jnp.concatenate(l_parts, axis=1).reshape(g * nc)
        o_lo = jnp.concatenate(o_parts, axis=1).reshape(g * nc, d)

    # per-position A/B selection, then the oracle's exact `combine`
    sel = rows_tr[:, 0]
    m1 = jnp.where(sel, sh_a[0], sh_b[0])
    l1 = jnp.where(sel, sh_a[1], sh_b[1])
    o1 = jnp.where(sel[:, None], sh_a[2], sh_b[2])
    m2 = jnp.where(sel, ro_a[0], ro_b[0])
    l2 = jnp.where(sel, ro_a[1], ro_b[1])
    o2 = jnp.where(sel[:, None], ro_a[2], ro_b[2])
    m_star = jnp.maximum(jnp.maximum(m1, m2), m_lo)
    safe = jnp.where(m_star == NEG_INF, 0.0, m_star)
    l_tot = jnp.zeros_like(l1)
    o_tot = jnp.zeros_like(o1)
    for m_p, l_p, o_p in ((m1, l1, o1), (m2, l2, o2), (m_lo, l_lo, o_lo)):
        sc = jnp.exp(jnp.where(m_p == NEG_INF, NEG_INF, m_p - safe))
        l_tot = l_tot + l_p * sc
        o_tot = o_tot + o_p * sc[:, None]
    denom = jnp.where(l_tot == 0.0, 1.0, l_tot)
    out = jnp.where((l_tot == 0.0)[:, None], 0.0, o_tot / denom[:, None])
    out = jnp.where(act, out, 0.0)

    # ---- 6. write back ----
    o_ref[0, 0] = out.reshape(g, nc, d).astype(o_ref.dtype)
    lmq_o[0, 0] = lm_q_s
    lmv_o[0, 0] = lm_v_s
    ei_o[0, 0] = ei_s
    ev_o[0, 0] = ev_s
    qs_o[0, 0] = q_sum_s
    plmq_o[0, 0] = pre_lm_q_s
    pqs_o[0, 0] = pre_q_sum_s


@functools.partial(
    jax.jit,
    static_argnames=("window", "k_width", "n_route", "external_finalize",
                     "q_block", "interpret"))
def mita_chunk_prefill_fused(q, k, v, lm_q, lm_v, expert_idx, expert_valid,
                             q_sum, pre_lm_q, pre_q_sum, k_pool, v_pool,
                             page_table, t0, n_valid, n_train, active,
                             window: int, k_width: int, n_route: int = 1,
                             external_finalize: bool = True,
                             q_block: int = 0,
                             interpret: bool = False):
    """Fused batched chunk prefill (+ in-place KV append).

    q: [S, Hkv, G, nc, d]; k/v: [S, Hkv, nc, d]; lm_q/lm_v/pre_lm_q:
    [S, Hkv, M, d]; expert_idx: [S, Hkv, M, K] GLOBAL pool rows;
    expert_valid: [S, Hkv, M, K] bool; q_sum/pre_q_sum: [S, Hkv, d] f32;
    k_pool/v_pool: [R + 1, Hkv, d] (row R is the scratch row); page_table:
    [S, M] i32; t0/n_valid/n_train: [S] i32; active: [S] bool.

    ``q_block`` tiles the local branch (windows per query tile, from
    `kernels.ops.select_prefill_q_block`; 0 = untiled full-context scores;
    > 0 requires ``nc % window == 0`` and ``q_block | (nc // window)``) —
    every tile size is bit-identical to the untiled path.

    Returns (out, lm_q, lm_v, expert_idx, expert_valid [i32], q_sum,
    pre_lm_q, pre_q_sum, k_pool, v_pool) — the pools aliased in/out, every
    other state tensor merged (inactive rows pass through bit-exactly).
    See `core.mita_decode.mita_batched_chunk_prefill` for the semantics
    this kernel must (and is pinned to) reproduce.
    """
    n_slots, hkv, g, nc, d = q.shape
    m_slot, kw = expert_idx.shape[-2:]
    assert kw == k_width
    if q_block:
        assert nc % window == 0 and (nc // window) % q_block == 0, \
            (nc, window, q_block)
    pdt = k_pool.dtype

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(n_slots, hkv),
        in_specs=[
            pl.BlockSpec((1, 1, g, nc, d), lambda s, h, *_: (s, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, nc, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, nc, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, kw), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, kw), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda s, h, *_: (s, h, 0)),
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda s, h, *_: (s, h, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),      # k_pool (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),      # v_pool (HBM)
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, nc, d), lambda s, h, *_: (s, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, kw), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, kw), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda s, h, *_: (s, h, 0)),
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda s, h, *_: (s, h, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        scratch_shapes=[
            pltpu.VMEM((m_slot * window, d), pdt),
            pltpu.VMEM((m_slot * window, d), pdt),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    kern = functools.partial(_chunk_kernel, window=window, k_width=k_width,
                             n_route=n_route, external=external_finalize,
                             q_block=q_block)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_slots, hkv, g, nc, d), pdt),
            jax.ShapeDtypeStruct(lm_q.shape, lm_q.dtype),
            jax.ShapeDtypeStruct(lm_v.shape, lm_v.dtype),
            jax.ShapeDtypeStruct(expert_idx.shape, jnp.int32),
            jax.ShapeDtypeStruct(expert_valid.shape, jnp.int32),
            jax.ShapeDtypeStruct(q_sum.shape, jnp.float32),
            jax.ShapeDtypeStruct(pre_lm_q.shape, pre_lm_q.dtype),
            jax.ShapeDtypeStruct(pre_q_sum.shape, jnp.float32),
            jax.ShapeDtypeStruct(k_pool.shape, pdt),
            jax.ShapeDtypeStruct(v_pool.shape, pdt),
        ],
        # operand indices count the 5 scalar-prefetch args
        input_output_aliases={15: 8, 16: 9},
        interpret=interpret,
    )(page_table.astype(jnp.int32), t0.astype(jnp.int32),
      n_valid.astype(jnp.int32), n_train.astype(jnp.int32),
      active.astype(jnp.int32),
      q, k.astype(pdt), v.astype(pdt), lm_q, lm_v,
      expert_idx.astype(jnp.int32), expert_valid.astype(jnp.int32),
      q_sum, pre_lm_q, pre_q_sum, k_pool, v_pool)
