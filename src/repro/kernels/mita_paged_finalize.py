"""Fused paged landmark-finalize MiTA kernel (TPU Pallas; interpret on CPU).

Every ``window`` decoded tokens a slot's open window completes: its pooled
query becomes a landmark row and the landmark scores a fresh top-k expert
gather over the slot's whole context.  This was the last decode-path op
still on the XLA gathers (`core.mita_decode._paged_finalize`).  Per
(slot, KV-head) program:

  * **context gather** — DMAs the slot's page set HBM→VMEM in token order
    (pages named by the SMEM page table; unowned table entries DMA junk
    that the visibility mask cancels exactly — every lane at or past
    ``t_new`` scores NEG_INF, so its softmax weight underflows to an exact
    0.0 and 0·junk == 0 bit-exactly);
  * **landmark pool** — divides the accumulated window query sum by ``w``
    (the same op the oracle runs on the same f32 accumulator);
  * **expert rebuild** — one in-kernel top-k over the masked landmark
    scores, context positions mapped to GLOBAL pool rows through the page
    table with an exact masked-iota sum, landmark value via the in-kernel
    softmax replica;
  * **commit** — merges the new landmark/expert rows at window ordinal
    ``t_new // w - 1`` for ``due`` slots only and zeroes their q_sum;
    non-due (and inactive) slots pass through bit-exactly.

The XLA path in `core.mita_decode._paged_finalize` stays as the fallback
and the bit-exact oracle (f32 pools): `tests/test_kernel_oracle.py` pins
lm_q/lm_v/expert rows/validity/q_sum bit-identical over shuffled page
tables, ragged per-slot t, and inactive slots.

Per-program VMEM working set (budget-checked by
`kernels.ops.paged_finalize_vmem_bytes`): the gathered context ``2·ctx·d``,
landmark in+out tiles ``4·M·d``, q_sum in+out ``4·d`` (f32), and the f32
score/softmax rows ``2·ctx``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mita_chunk_prefill import NEG_INF, _dot, _softmax, _topk


def _finalize_kernel(pt_ref, t_ref, due_ref,                     # SMEM
                     qs_ref, lmq_ref, lmv_ref, ei_ref, ev_ref,
                     kpool_ref, vpool_ref,
                     lmq_o, lmv_o, ei_o, ev_o, qs_o,
                     kctx, vctx, sem,
                     *, window: int, k_width: int):
    s = pl.program_id(0)
    h = pl.program_id(1)
    w = window
    m_slot = lmq_ref.shape[2]
    d = lmq_ref.shape[3]
    ctx = m_slot * w

    t_new = t_ref[s]
    due = due_ref[s] == 1

    # ---- 1. gather the slot's context (token order) ----
    def gather_page(mi, _):
        page = pt_ref[s, mi]
        base = pl.multiple_of(page * w, w)
        ck = pltpu.make_async_copy(kpool_ref.at[pl.ds(base, w), h],
                                   kctx.at[pl.ds(mi * w, w)], sem)
        ck.start()
        ck.wait()
        cv = pltpu.make_async_copy(vpool_ref.at[pl.ds(base, w), h],
                                   vctx.at[pl.ds(mi * w, w)], sem)
        cv.start()
        cv.wait()
        return 0

    jax.lax.fori_loop(0, m_slot, gather_page, 0)

    k_ctx = kctx[...].astype(jnp.float32)               # [ctx, d]
    v_ctx = vctx[...].astype(jnp.float32)

    # ---- 2. pool the completed window's queries into the landmark ----
    q_lm = (qs_ref[0, 0] / w).astype(lmq_ref.dtype)     # [d]

    # ---- 3. rebuild the top-k expert gather over the visible context ----
    scores = _dot(q_lm.astype(jnp.float32)[None], k_ctx) / math.sqrt(d)
    cid = jax.lax.broadcasted_iota(jnp.int32, (1, ctx), 1)
    scores = jnp.where(cid < t_new, scores, NEG_INF)    # [1, ctx]
    top_vals, top_loc = _topk(scores, k_width)          # [1, K]
    valid = (top_vals[0] > NEG_INF / 2).astype(jnp.int32)        # [K]
    pt_vec = jnp.stack([pt_ref[s, j] for j in range(m_slot)])    # [M]
    ctx_rows = (pt_vec[:, None] * w
                + jax.lax.broadcasted_iota(jnp.int32, (m_slot, w), 1)
                ).reshape(1, ctx)                       # [1, ctx]
    mk = jax.lax.broadcasted_iota(jnp.int32, (k_width, ctx), 1)
    rows = jnp.sum(
        jnp.where(mk == top_loc[0][:, None],
                  jnp.broadcast_to(ctx_rows, (k_width, ctx)), 0),
        axis=-1)                                        # [K] global rows
    p = _softmax(scores)                                # [1, ctx]
    v_lm = jax.lax.dot_general(p, v_ctx, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32
                               )[0].astype(lmv_ref.dtype)        # [d]

    # ---- 4. commit at window ordinal t_new//w - 1 for due slots ----
    i = t_new // w - 1
    li = jax.lax.broadcasted_iota(jnp.int32, (m_slot, 1), 0)
    sel = due & (li == i)                               # [M, 1]
    lmq_o[0, 0] = jnp.where(sel, q_lm[None], lmq_ref[0, 0])
    lmv_o[0, 0] = jnp.where(sel, v_lm[None], lmv_ref[0, 0])
    ei_o[0, 0] = jnp.where(sel, rows[None], ei_ref[0, 0])
    ev_o[0, 0] = jnp.where(sel, valid[None], ev_ref[0, 0])
    qs_o[0, 0] = jnp.where(due, 0.0, qs_ref[0, 0])


@functools.partial(
    jax.jit, static_argnames=("window", "k_width", "interpret"))
def mita_paged_finalize_fused(q_sum, lm_q, lm_v, expert_idx, expert_valid,
                              k_pool, v_pool, page_table, t_new, due,
                              window: int, k_width: int,
                              interpret: bool = False):
    """Fused paged landmark finalize.

    q_sum: [S, Hkv, d] f32; lm_q/lm_v: [S, Hkv, M, d]; expert_idx:
    [S, Hkv, M, K] GLOBAL pool rows; expert_valid: [S, Hkv, M, K] bool;
    k_pool/v_pool: [R + 1, Hkv, d] (read-only here — finalize never
    writes the pools); page_table: [S, M] i32; t_new: [S] i32 (per-slot
    position AFTER the step); due: [S] bool.

    Returns (lm_q, lm_v, expert_idx, expert_valid [i32], q_sum) with
    non-due rows passed through bit-exactly.  See
    `core.mita_decode._paged_finalize` for the semantics this kernel must
    (and is pinned to) reproduce.
    """
    n_slots, hkv, m_slot, d = lm_q.shape
    kw = expert_idx.shape[-1]
    assert kw == k_width
    pdt = k_pool.dtype

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_slots, hkv),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda s, h, *_: (s, h, 0)),
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, kw), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, kw), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),      # k_pool (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),      # v_pool (HBM)
        ],
        out_specs=[
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, kw), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, kw), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda s, h, *_: (s, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((m_slot * window, d), pdt),
            pltpu.VMEM((m_slot * window, d), pdt),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    kern = functools.partial(_finalize_kernel, window=window,
                             k_width=k_width)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(lm_q.shape, lm_q.dtype),
            jax.ShapeDtypeStruct(lm_v.shape, lm_v.dtype),
            jax.ShapeDtypeStruct(expert_idx.shape, jnp.int32),
            jax.ShapeDtypeStruct(expert_valid.shape, jnp.int32),
            jax.ShapeDtypeStruct(q_sum.shape, jnp.float32),
        ],
        interpret=interpret,
    )(page_table.astype(jnp.int32), t_new.astype(jnp.int32),
      due.astype(jnp.int32),
      q_sum, lm_q, lm_v, expert_idx.astype(jnp.int32),
      expert_valid.astype(jnp.int32), k_pool, v_pool)
