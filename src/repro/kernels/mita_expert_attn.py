"""MiTA routed-expert attention kernel (paper Alg. 1 line 14, TPU-native).

The GPU reference implementation uses varlen FlashAttention with cu_seqlens;
TPU kernels want static shapes, so (DESIGN.md "Hardware adaptation"):

  * sub-queries arrive *sorted by expert id* — a fixed-size query block then
    touches a contiguous expert range [a[0], a[-1]];
  * each expert's top-k KV tile ([K, d]) is resident in VMEM (the gathered
    tiles k_e/v_e total m·K·d, materialized once per layer — the TPU answer
    to the paper's per-query gather bottleneck);
  * the kernel walks the block's expert range with a dynamically-bounded
    `fori_loop`, computing one MXU matmul per (query-block × expert tile)
    with an equality mask — load imbalance costs masked lanes, never a
    recompile.

Outputs are un-normalized online-softmax partials (o, m, l) merged with the
shared-expert and local-window branches by `repro.core.combine` — exactly
the paper's Alg. 1 line 16.

VMEM budget: the full expert bank k_e+v_e is 2·m·K·d·2B; ops.py dispatches
to this kernel only when that fits (≲8 MiB), else falls back to the XLA
sorted-span path.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _expert_kernel(a_ref, q_ref, ke_ref, ve_ref, bias_ref,
                   o_ref, m_ref, l_ref,
                   *, n_experts: int, k_width: int, scale: float,
                   block_q: int):
    """One (bh, q-block) step: walk experts [a[0], a[-1]] of this block."""
    a = a_ref[0]                                   # [block_q] int32, sorted
    q = q_ref[0].astype(jnp.float32) * scale       # [block_q, d]
    d = q.shape[-1]

    lo = jnp.minimum(a[0], n_experts - 1)
    hi = jnp.minimum(a[block_q - 1], n_experts - 1)

    def body(e, carry):
        m_prev, l_prev, acc = carry
        kt = ke_ref[0, pl.dslice(e * k_width, k_width), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s + bias_ref[0, pl.dslice(e * k_width, k_width)][None, :]
        s = jnp.where((a == e)[:, None], s, NEG_INF)   # routing mask

        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(jnp.where(m_prev == NEG_INF, NEG_INF, m_prev - m_cur))
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(s == NEG_INF, 0.0, p)
        vt = ve_ref[0, pl.dslice(e * k_width, k_width), :].astype(jnp.float32)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, vt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_cur, l_prev * alpha + jnp.sum(p, axis=-1), acc

    init = (jnp.full((block_q,), NEG_INF, jnp.float32),
            jnp.zeros((block_q,), jnp.float32),
            jnp.zeros((block_q, d), jnp.float32))
    m_out, l_out, acc = jax.lax.fori_loop(lo, hi + 1, body, init)

    o_ref[0] = acc.astype(o_ref.dtype)
    m_ref[0] = m_out
    l_ref[0] = l_out


@functools.partial(jax.jit,
                   static_argnames=("block_q", "interpret"))
def mita_expert_attention(q_sorted: jax.Array, assign: jax.Array,
                          k_e: jax.Array, v_e: jax.Array, valid: jax.Array,
                          block_q: int = 128, interpret: bool = False):
    """Routed-expert attention partials.

    q_sorted: [B, H, NS, d] sub-queries sorted by expert id
    assign:   [B, H, NS] int32 expert per sub-query (>= m means inactive)
    k_e/v_e:  [B, H, M, K, d]; valid: [B, H, M, K]
    Returns (o, m_stat, l): [B,H,NS,d], [B,H,NS], [B,H,NS].

    NS need not divide ``block_q``: the sorted sub-queries are padded to
    the next block boundary with the inactive assignment id ``m`` (sort
    order is preserved — padding sorts after every real sub-query), which
    the routing mask turns into empty partials; outputs are sliced back.
    """
    b, h, ns, d = q_sorted.shape
    m, kw = k_e.shape[-3], k_e.shape[-2]
    block_q = min(block_q, ns)
    ns_pad = ((ns + block_q - 1) // block_q) * block_q
    if ns_pad != ns:
        pad = ((0, 0), (0, 0), (0, ns_pad - ns))
        q_sorted = jnp.pad(q_sorted, pad + ((0, 0),))
        assign = jnp.pad(assign, pad, constant_values=m)
    nso = ns
    ns = ns_pad

    qf = q_sorted.reshape(b * h, ns, d)
    af = assign.reshape(b * h, ns).astype(jnp.int32)
    kef = k_e.reshape(b * h, m * kw, d)
    vef = v_e.reshape(b * h, m * kw, d)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    bias = bias.reshape(b * h, m * kw)

    grid = (b * h, ns // block_q)
    kern = functools.partial(_expert_kernel, n_experts=m, k_width=kw,
                             scale=1.0 / math.sqrt(d), block_q=block_q)
    o, m_stat, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda bh, qi: (bh, qi)),
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, m * kw, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, m * kw, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, m * kw), lambda bh, qi: (bh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh, qi: (bh, qi)),
            pl.BlockSpec((1, block_q), lambda bh, qi: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, ns, d), q_sorted.dtype),
            jax.ShapeDtypeStruct((b * h, ns), jnp.float32),
            jax.ShapeDtypeStruct((b * h, ns), jnp.float32),
        ],
        interpret=interpret,
    )(af, qf, kef, vef, bias)
    return (o.reshape(b, h, ns, d)[:, :, :nso],
            m_stat.reshape(b, h, ns)[:, :, :nso],
            l.reshape(b, h, ns)[:, :, :nso])
