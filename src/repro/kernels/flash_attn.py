"""Flash attention for TPU (Pallas): online-softmax with explicit BlockSpec
VMEM tiling.

Grid layout: (batch·heads, q_blocks, kv_blocks).  TPU grid iteration is
sequential over the trailing dim, so the kv dimension accumulates into the
same output block (revisited across kv steps) with running (max, sumexp)
statistics in VMEM scratch — the standard TPU flash pattern.  Block shapes
default to (128, d) — MXU-aligned for d ∈ {64, 128, 256}.

Causal masking skips fully-masked kv blocks via `pl.when` (no wasted MXU
work above the diagonal at block granularity).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, causal: bool, scale: float, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(jnp.where(m_prev == NEG_INF, NEG_INF, m_prev - m_cur))
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(s == NEG_INF, 0.0, p)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    if causal:
        # skip blocks entirely above the diagonal
        pl.when((ki * block_k) <= (qi * block_q + block_q - 1))(compute)
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = False, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q,k,v: [B, H, N, d] -> [B, H, N, d]."""
    b, h, n, d = q.shape
    nk = k.shape[-2]
    block_q = min(block_q, n)
    block_k = min(block_k, nk)
    if n % block_q or nk % block_k:
        raise ValueError("sequence length must divide block size")
    qf = q.reshape(b * h, n, d)
    kf = k.reshape(b * h, nk, d)
    vf = v.reshape(b * h, nk, d)

    grid = (b * h, n // block_q, nk // block_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal,
                          scale=1.0 / math.sqrt(d),
                          block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, n, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, n, d)
