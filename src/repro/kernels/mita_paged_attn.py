"""Fused paged-decode MiTA attention kernel (TPU Pallas; interpret on CPU).

One decode step of the serving engine's paged cache, per (slot, KV head)
program, without ever materializing a contiguous per-slot cache:

  * **append** — the new (k, v) row is DMA'd straight into the slot's
    current page (`page_table[s, t//w] * w + t%w`; scratch row for inactive
    slots), with the pool aliased as an output so the write is in place;
  * **local window** — the current page's `w` rows are DMA'd HBM→VMEM in
    token order and the just-appended position is patched from registers
    (the read may race the append on-chip; the patch makes it exact);
  * **shared landmarks** — `lm_q`/`lm_v` arrive as per-slot VMEM blocks;
    routing logits double as the shared-expert branch scores;
  * **routed experts** — the top-`s` experts per query head are selected
    in-kernel from the routing logits, and their stored GLOBAL pool rows
    (`expert_idx`, assigned at finalize time) are gathered row-by-row via
    DMA — the vLLM-style page walk, fused with the attention that consumes
    it.

The three branches merge in-kernel with the same guarded online-softmax as
`repro.core.combine`, so the output equals one softmax over the union of
all branch keys (paper Alg. 1 line 16).  The XLA gather path in
`core.mita_decode.mita_paged_decode_step` is the oracle
(`tests/test_kernel_oracle.py` pins parity over randomized page
permutations, ragged per-slot progress, and inactive slots).

Per-program VMEM working set (budget-checked by `kernels.ops` before
dispatch): q/out `2·G·d`, landmark tiles `2·M·d`, local page `2·w·d`, one
expert KV tile `2·K·d`, plus the `M·K` expert index/bias tables.  The
expert-row gathers are double-buffered by default (row i+1's copies are in
flight while row i's drain — the decode step is DMA-latency bound, not
bandwidth bound); ``REPRO_DMA_PIPELINE=0`` serializes them for debugging
(`tests/test_kernel_oracle.py` pins parity in both modes).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _merge(m_a, l_a, o_a, m_b, l_b, o_b):
    """Online-softmax merge of two partials ([G] stats, [G, d] values)."""
    m_n = jnp.maximum(m_a, m_b)
    safe = jnp.where(m_n == NEG_INF, 0.0, m_n)
    sa = jnp.exp(jnp.where(m_a == NEG_INF, NEG_INF, m_a - safe))
    sb = jnp.exp(jnp.where(m_b == NEG_INF, NEG_INF, m_b - safe))
    return (m_n, l_a * sa + l_b * sb,
            o_a * sa[:, None] + o_b * sb[:, None])


def _partial(s):
    """[G, n] masked scores -> (m [G], l [G], p [G, n]) with empty-row guard."""
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - jnp.where(m == NEG_INF, 0.0, m)[:, None])
    p = jnp.where(s == NEG_INF, 0.0, p)
    return m, jnp.sum(p, axis=-1), p


def _paged_kernel(pt_ref, t_ref, act_ref, mcnt_ref,              # SMEM
                  q_ref, kn_ref, vn_ref, lmq_ref, lmv_ref,
                  ei_ref, eb_ref, kpool_ref, vpool_ref,          # pools: ANY
                  o_ref, kpout_ref, vpout_ref,
                  kloc, vloc, ketile, vetile, sem, psem,
                  *, window: int, n_route: int, fuse_append: bool,
                  pipeline: bool, scale: float):
    s = pl.program_id(0)
    h = pl.program_id(1)
    w = window
    ts = t_ref[s]
    act = act_ref[s] == 1
    mc = mcnt_ref[s]
    n_rows = kpout_ref.shape[0]
    cur = pt_ref[s, ts // w]
    page0 = pl.multiple_of(cur * w, w)
    # inactive slots append to the trailing scratch row (never read back)
    row_new = jnp.where(act, page0 + ts % w, n_rows - 1)

    if fuse_append:
        cp = pltpu.make_async_copy(kn_ref.at[0, 0], kpout_ref.at[row_new, h],
                                   sem)
        cp.start()
        cp.wait()
        cp = pltpu.make_async_copy(vn_ref.at[0, 0], vpout_ref.at[row_new, h],
                                   sem)
        cp.start()
        cp.wait()

    # local page HBM->VMEM in token order; the appended position is patched
    # from registers so the result never depends on append/read ordering
    cp = pltpu.make_async_copy(kpool_ref.at[pl.ds(page0, w), h], kloc, sem)
    cp.start()
    cp.wait()
    cp = pltpu.make_async_copy(vpool_ref.at[pl.ds(page0, w), h], vloc, sem)
    cp.start()
    cp.wait()
    kloc[pl.ds(ts % w, 1)] = kn_ref[0, 0][None]
    vloc[pl.ds(ts % w, 1)] = vn_ref[0, 0][None]

    q = q_ref[0, 0].astype(jnp.float32) * scale              # [G, d]
    g, d = q.shape
    m_slot = lmq_ref.shape[2]
    k_width = ketile.shape[0]

    # shared-landmark branch; r doubles as the routing logits
    lmq = lmq_ref[0, 0].astype(jnp.float32)                  # [M, d]
    r = jax.lax.dot_general(q, lmq, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    lm_ids = jax.lax.broadcasted_iota(jnp.int32, (g, m_slot), 1)
    r = jnp.where(lm_ids < mc, r, NEG_INF)
    m_acc, l_acc, p1 = _partial(r)
    o_acc = jax.lax.dot_general(p1, lmv_ref[0, 0].astype(jnp.float32),
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # local-window branch: the slot's own page, positions <= t
    s_loc = jax.lax.dot_general(q, kloc[...].astype(jnp.float32),
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    loc_ids = jax.lax.broadcasted_iota(jnp.int32, (g, w), 1)
    s_loc = jnp.where(loc_ids <= ts % w, s_loc, NEG_INF)
    m_l, l_l, p2 = _partial(s_loc)
    o_l = jax.lax.dot_general(p2, vloc[...].astype(jnp.float32),
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    m_acc, l_acc, o_acc = _merge(m_acc, l_acc, o_acc, m_l, l_l, o_l)

    # routed experts: top-s of r per query head, expert rows gathered from
    # the pool by their stored GLOBAL row ids — no page-table lookup needed
    r_route = r
    for _ in range(n_route):
        e_j = jnp.argmax(r_route, axis=-1)                   # [G]
        ok_j = jnp.max(r_route, axis=-1) > NEG_INF / 2
        r_route = jnp.where(lm_ids == e_j[:, None], NEG_INF, r_route)

        m_rows, l_rows, o_rows = [], [], []
        for gi in range(g):
            e_gi = e_j[gi]
            rows = ei_ref[0, 0, pl.ds(e_gi, 1)][0]           # [K] global rows
            bias = eb_ref[0, 0, pl.ds(e_gi, 1)][0]           # [K] 0 / NEG_INF

            def row_copies(kk, slot):
                row = rows[kk]
                return (pltpu.make_async_copy(kpool_ref.at[row, h],
                                              ketile.at[kk],
                                              psem.at[slot, 0]),
                        pltpu.make_async_copy(vpool_ref.at[row, h],
                                              vetile.at[kk],
                                              psem.at[slot, 1]))

            if pipeline:
                # double-buffered row walk: row kk+1's copies are in
                # flight while row kk's drain (distinct destination rows,
                # alternating semaphore pairs) — hides the per-row DMA
                # latency the serial walk pays K times
                ck, cv = row_copies(0, 0)
                ck.start()
                cv.start()

                def gather_row(kk, _):
                    @pl.when(kk + 1 < k_width)
                    def _():
                        nk, nv = row_copies(kk + 1, (kk + 1) % 2)
                        nk.start()
                        nv.start()
                    wk, wv = row_copies(kk, kk % 2)
                    wk.wait()
                    wv.wait()
                    return 0
            else:
                def gather_row(kk, _):
                    ck = pltpu.make_async_copy(kpool_ref.at[rows[kk], h],
                                               ketile.at[kk], sem)
                    ck.start()
                    ck.wait()
                    cv = pltpu.make_async_copy(vpool_ref.at[rows[kk], h],
                                               vetile.at[kk], sem)
                    cv.start()
                    cv.wait()
                    return 0

            jax.lax.fori_loop(0, k_width, gather_row, 0)
            s_e = jax.lax.dot_general(
                q[gi:gi + 1], ketile[...].astype(jnp.float32),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)          # [1, K]
            s_e = s_e + bias[None, :]
            s_e = jnp.where(ok_j[gi], s_e, NEG_INF)
            m_e, l_e, p_e = _partial(s_e)
            o_e = jax.lax.dot_general(p_e, vetile[...].astype(jnp.float32),
                                      (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
            m_rows.append(m_e)
            l_rows.append(l_e)
            o_rows.append(o_e)
        m_acc, l_acc, o_acc = _merge(
            m_acc, l_acc, o_acc, jnp.concatenate(m_rows),
            jnp.concatenate(l_rows), jnp.concatenate(o_rows))

    denom = jnp.where(l_acc == 0.0, 1.0, l_acc)
    out = o_acc / denom[:, None]
    out = jnp.where((l_acc != 0.0)[:, None] & act, out, 0.0)
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "n_route", "fuse_append", "pipeline",
                     "interpret"))
def mita_paged_attention(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                         lm_q: jax.Array, lm_v: jax.Array,
                         expert_idx: jax.Array, expert_valid: jax.Array,
                         k_pool: jax.Array, v_pool: jax.Array,
                         page_table: jax.Array, t: jax.Array,
                         active: jax.Array, m_cnt: jax.Array,
                         window: int, n_route: int = 1,
                         fuse_append: bool = True, pipeline: bool = True,
                         interpret: bool = False):
    """Fused paged-decode attention (+ optional in-place KV append).

    q: [S, Hkv, G, d]; k_new/v_new: [S, Hkv, d];
    lm_q/lm_v: [S, Hkv, M, d]; expert_idx: [S, Hkv, M, K] GLOBAL pool rows;
    expert_valid: [S, Hkv, M, K] bool; k_pool/v_pool: [R + 1, Hkv, d]
    (row R is the inactive-slot write scratch); page_table: [S, M] int32;
    t: [S] int32 tokens already cached; active: [S] bool;
    m_cnt: [S] int32 landmarks visible to this step (t//w external-finalize,
    (t+1)//w inline — the caller decides).

    Returns (out [S, Hkv, G, d] in pool dtype, k_pool, v_pool).  The pools
    are aliased in/out; with ``fuse_append`` the new row is written at
    ``page_table[s, t//w]*w + t%w`` (scratch row when inactive), otherwise
    they pass through untouched (the caller already appended, e.g. before
    an inline finalize).
    """
    n_slots, hkv, g, d = q.shape
    m_slot, k_width = expert_idx.shape[-2:]
    bias = jnp.where(expert_valid, 0.0, NEG_INF).astype(jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n_slots, hkv),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda s, h, *_: (s, h, 0)),
            pl.BlockSpec((1, 1, d), lambda s, h, *_: (s, h, 0)),
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, k_width),
                         lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec((1, 1, m_slot, k_width),
                         lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),      # k_pool (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),      # v_pool (HBM)
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda s, h, *_: (s, h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        scratch_shapes=[
            pltpu.VMEM((window, d), k_pool.dtype),
            pltpu.VMEM((window, d), v_pool.dtype),
            pltpu.VMEM((k_width, d), k_pool.dtype),
            pltpu.VMEM((k_width, d), v_pool.dtype),
            pltpu.SemaphoreType.DMA(()),
            pltpu.SemaphoreType.DMA((2, 2)),   # expert-row pipeline pairs
        ],
    )
    kern = functools.partial(_paged_kernel, window=window, n_route=n_route,
                             fuse_append=fuse_append, pipeline=pipeline,
                             scale=1.0 / math.sqrt(d))
    out, kp, vp = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_slots, hkv, g, d), k_pool.dtype),
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
        ],
        # operand indices count the 4 scalar-prefetch args
        input_output_aliases={11: 1, 12: 2},
        interpret=interpret,
    )(page_table.astype(jnp.int32), t.astype(jnp.int32),
      active.astype(jnp.int32), m_cnt.astype(jnp.int32),
      q, k_new.astype(k_pool.dtype), v_new.astype(v_pool.dtype),
      lm_q, lm_v, expert_idx.astype(jnp.int32), bias, k_pool, v_pool)
    return out, kp, vp
