"""Dispatch wrappers: Pallas kernels on TPU, interpret/XLA fallbacks on CPU.

`routed_expert_partial` is the integration point used by
`repro.core.mita_sparse` when ``impl="pallas"``: it takes the sorted
sub-queries + expert bank and returns online-softmax partials compatible
with `repro.core.combine.Partial`.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attn as _fa
from repro.kernels import mita_expert_attn as _mea

VMEM_BUDGET_BYTES = 8 * 2**20   # expert bank budget for the resident kernel


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, causal: bool = False,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """[B,H,N,d] flash attention; interpret mode on CPU."""
    if interpret is None:
        interpret = not on_tpu()
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interpret)


def expert_bank_fits(m: int, k: int, d: int, bytes_per_el: int = 2) -> bool:
    return 2 * m * k * d * bytes_per_el <= VMEM_BUDGET_BYTES


# -------------------------------------------------- paged-cache indirection --
#
# The serving engine (repro.serve) keeps one KV pool per layer shared by all
# requests; a request owns a set of fixed-size, window-aligned pages named by
# a page table.  Every decode-time gather then goes through row indirection
# instead of slicing a per-request [B, Hkv, C, d] cache.  These wrappers are
# the dispatch point: XLA gathers everywhere today; a TPU Pallas paged-gather
# kernel (vLLM-style) slots in here without touching `core.mita_decode`.

def gather_pool_rows(pool: jax.Array, rows: jax.Array) -> jax.Array:
    """Gather per-(slot, kv-head) rows from a shared KV pool.

    pool: [R, Hkv, d] — flattened page pool (row = page_id * page_size + off).
    rows: [S, Hkv, n] int32 global row ids (may repeat; must be in-bounds).
    Returns [S, Hkv, n, d].
    """
    pool_t = jnp.swapaxes(pool, 0, 1)                  # [Hkv, R, d]
    return jnp.take_along_axis(pool_t[None], rows[..., None], axis=2)


def gather_pages(pool: jax.Array, page_ids: jax.Array,
                 page_size: int) -> jax.Array:
    """Gather whole pages in page-table order (sequential token order).

    pool: [R, Hkv, d]; page_ids: [S, P] int32.
    Returns [S, P * page_size, Hkv, d].
    """
    rows = page_ids[..., None] * page_size + jnp.arange(page_size)
    return pool[rows.reshape(rows.shape[:-2] + (-1,))]


def scatter_pool_rows(pool: jax.Array, rows: jax.Array,
                      new: jax.Array) -> jax.Array:
    """Write one new row per slot into the pool.

    pool: [R, Hkv, d]; rows: [S] int32 (scratch-row duplicates allowed for
    inactive slots); new: [S, Hkv, d].  Returns the updated pool.
    """
    return pool.at[rows].set(new.astype(pool.dtype))


def routed_expert_partial(q_sorted, assign, k_e, v_e, valid,
                          block_q: int = 128,
                          interpret: Optional[bool] = None):
    """Kernel-backed routed-expert partials with arbitrary lead dims.

    q_sorted: [..., NS, d]; assign: [..., NS];
    k_e/v_e: [kv_lead..., M, K, d] (lead may contain broadcast-1 dims);
    valid: [kv_lead..., M, K].
    Returns (o, m_stat, l) with q_sorted's lead dims.
    """
    if interpret is None:
        interpret = not on_tpu()
    lead = q_sorted.shape[:-2]
    ns, d = q_sorted.shape[-2:]
    m, kw = k_e.shape[-3], k_e.shape[-2]

    def bcast(x, trailing):
        tgt = lead + x.shape[-trailing:]
        return jnp.broadcast_to(x, tgt).reshape((1, -1) + x.shape[-trailing:])

    q4 = q_sorted.reshape((1, -1, ns, d))
    a4 = assign.reshape((1, -1, ns))
    ke4 = bcast(k_e, 3)
    ve4 = bcast(v_e, 3)
    va4 = bcast(valid, 2)
    o, ms, l = _mea.mita_expert_attention(
        q4, a4, ke4, ve4, va4,
        block_q=min(block_q, ns), interpret=interpret)
    return (o.reshape(lead + (ns, d)), ms.reshape(lead + (ns,)),
            l.reshape(lead + (ns,)))
