"""Dispatch wrappers: Pallas kernels on TPU, interpret/XLA fallbacks on CPU.

`routed_expert_partial` is the integration point used by
`repro.core.mita_sparse` when ``impl="pallas"``: it takes the sorted
sub-queries + expert bank and returns online-softmax partials compatible
with `repro.core.combine.Partial`.

`paged_decode_attend` is the integration point used by
`repro.core.mita_decode.mita_paged_decode_step`: the fused paged-decode
kernel (`kernels.mita_paged_attn`) walks page tables in VMEM and gathers
routed-expert rows by global row id; the XLA gather path in
`core.mita_decode` stays as the oracle and the fallback whenever
`use_paged_kernel` says no.

Tunables (satellite of the module constants they replace):
  * ``REPRO_VMEM_BUDGET_BYTES`` — per-kernel VMEM working-set budget used
    by every fits/dispatch decision (default 8 MiB).  `DecodeConfig
    .vmem_budget` overrides it per decode config.
  * ``REPRO_BLOCK_Q`` / ``REPRO_BLOCK_K`` — default kernel block sizes for
    the flash / expert kernels when the caller passes none.
"""

from __future__ import annotations

import contextlib
import math
import os
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attn as _fa
from repro.kernels import mita_chunk_prefill as _mcp
from repro.kernels import mita_expert_attn as _mea
from repro.kernels import mita_paged_attn as _mpa
from repro.kernels import mita_paged_finalize as _mpf

DEFAULT_VMEM_BUDGET_BYTES = 8 * 2**20   # expert-bank / paged working set
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def vmem_budget_bytes() -> int:
    """Effective VMEM working-set budget: env override or the default."""
    return int(os.environ.get("REPRO_VMEM_BUDGET_BYTES",
                              DEFAULT_VMEM_BUDGET_BYTES))


def default_block_q() -> int:
    return int(os.environ.get("REPRO_BLOCK_Q", DEFAULT_BLOCK_Q))


def default_block_k() -> int:
    return int(os.environ.get("REPRO_BLOCK_K", DEFAULT_BLOCK_K))


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, causal: bool = False,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """[B,H,N,d] flash attention; interpret mode on CPU."""
    if interpret is None:
        interpret = not on_tpu()
    return _fa.flash_attention(q, k, v, causal=causal,
                               block_q=block_q or default_block_q(),
                               block_k=block_k or default_block_k(),
                               interpret=interpret)


def expert_bank_fits(m: int, k: int, d: int, bytes_per_el: int = 2,
                     budget: int = 0) -> bool:
    return 2 * m * k * d * bytes_per_el <= (budget or vmem_budget_bytes())


# -------------------------------------------------- paged-cache indirection --
#
# The serving engine (repro.serve) keeps one KV pool per layer shared by all
# requests; a request owns a set of fixed-size, window-aligned pages named by
# a page table.  Every decode-time gather then goes through row indirection
# instead of slicing a per-request [B, Hkv, C, d] cache.  These wrappers are
# the dispatch point: the fused Pallas kernel (`paged_decode_attend`) covers
# the decode hot path; the XLA gathers remain for the finalize / chunk-
# prefill paths and as the decode fallback/oracle.

def gather_pool_rows(pool: jax.Array, rows: jax.Array) -> jax.Array:
    """Gather per-(slot, kv-head) rows from a shared KV pool.

    pool: [R, Hkv, d] — flattened page pool (row = page_id * page_size + off).
    rows: [S, Hkv, n] int32 global row ids (may repeat; must be in-bounds).
    Returns [S, Hkv, n, d].
    """
    pool_t = jnp.swapaxes(pool, 0, 1)                  # [Hkv, R, d]
    return jnp.take_along_axis(pool_t[None], rows[..., None], axis=2)


def gather_pages(pool: jax.Array, page_ids: jax.Array,
                 page_size: int,
                 owned: Optional[jax.Array] = None) -> jax.Array:
    """Gather whole pages in page-table order (sequential token order).

    pool: [R, Hkv, d]; page_ids: [S, P] int32.
    Returns [S, P * page_size, Hkv, d].

    ``owned`` (optional [S] int32): pages each slot actually owns
    (``ceil(t / page_size)``).  Table entries at ordinal >= owned are
    redirected to the pool's trailing scratch row instead of gathering
    whatever page the unused table entry happens to name — unused entries
    are in-bounds but unowned (scheduler invariant 4), so without the
    redirect a short request copies other requests' pages only to mask
    them downstream.
    """
    rows = page_ids[..., None] * page_size + jnp.arange(page_size)
    if owned is not None:
        scratch = pool.shape[0] - 1
        is_owned = (jnp.arange(page_ids.shape[-1])[None, :, None]
                    < owned[:, None, None])
        rows = jnp.where(is_owned, rows, scratch)
    return pool[rows.reshape(rows.shape[:-2] + (-1,))]


def scatter_pool_rows(pool: jax.Array, rows: jax.Array,
                      new: jax.Array) -> jax.Array:
    """Write one new row per slot into the pool.

    pool: [R, Hkv, d]; rows: [S] int32 (scratch-row duplicates allowed for
    inactive slots); new: [S, Hkv, d].  Returns the updated pool.
    """
    return pool.at[rows].set(new.astype(pool.dtype))


# ------------------------------------------------- fused paged-decode attn --

def paged_attention_vmem_bytes(window: int, m: int, k_width: int, g: int,
                               d: int, itemsize: int = 4) -> int:
    """Per-program VMEM working set of the fused paged-decode kernel:
    q + out, the landmark tiles, the local page, one expert KV tile, and
    the expert index/bias tables (`kernels.mita_paged_attn` docstring)."""
    tiles = (2 * g * d          # q + out
             + 2 * m * d        # lm_q + lm_v
             + 2 * window * d   # local page (k, v)
             + 2 * k_width * d)  # expert KV tile scratch
    tables = m * k_width * (4 + 4)   # expert_idx (i32) + bias (f32)
    return tiles * itemsize + tables


# Paged-decode analogue of `_PREFILL_KERNEL_FALLBACKS` below: a dispatch
# decision that WANTED the fused paged-decode kernel but fell back to XLA
# because the working set exceeded the VMEM budget.  Counted at trace time
# (one decision per compiled shape).  Surfaced as
# ``stats()["paged_kernel_fallbacks"]`` by the MiTA serving backend.
_PAGED_KERNEL_FALLBACKS = 0
_PAGED_FALLBACK_WARNED = False


def paged_kernel_fallbacks() -> int:
    """Process-wide count of paged-decode kernel→XLA VMEM fallbacks."""
    return _PAGED_KERNEL_FALLBACKS


def use_paged_kernel(impl: str, *, window: int, m: int, k_width: int,
                     g: int, d: int, itemsize: int = 4,
                     budget: int = 0) -> bool:
    """Decode-step dispatch: fused Pallas kernel vs the XLA gather oracle.

    ``impl``: "auto" (kernel on TPU when the working set fits the VMEM
    budget), "kernel" (force, still bounded by the budget so an oversized
    config degrades to the fallback instead of failing to lower), or "xla".
    ``budget`` = 0 uses `vmem_budget_bytes()` (env-overridable).

    A "no" that is due to the VMEM budget (rather than impl="xla" or
    running off-TPU in auto mode) increments `paged_kernel_fallbacks` and
    warns once per process, mirroring the chunk-prefill dispatch.
    """
    global _PAGED_KERNEL_FALLBACKS, _PAGED_FALLBACK_WARNED
    if impl == "xla":
        return False
    if impl not in ("auto", "kernel"):
        raise ValueError(f"unknown paged impl {impl!r}")
    need = paged_attention_vmem_bytes(window, m, k_width, g, d, itemsize)
    have = budget or vmem_budget_bytes()
    fits = need <= have
    if not fits and (impl == "kernel" or on_tpu()):
        _PAGED_KERNEL_FALLBACKS += 1
        if not _PAGED_FALLBACK_WARNED:
            _PAGED_FALLBACK_WARNED = True
            warnings.warn(
                f"paged-decode kernel working set {need} B exceeds the "
                f"VMEM budget {have} B (m={m}, window={window}, d={d}); "
                "dispatching to the XLA path — raise "
                "REPRO_VMEM_BUDGET_BYTES / DecodeConfig.vmem_budget to "
                "keep the fused kernel "
                "(further fallbacks are counted, not warned)",
                RuntimeWarning, stacklevel=2)
    if impl == "kernel":
        return fits
    return on_tpu() and fits


def paged_decode_attend(q, k_new, v_new, lm_q, lm_v, expert_idx,
                        expert_valid, k_pool, v_pool, page_table, t, active,
                        m_cnt, *, window: int, n_route: int,
                        fuse_append: bool,
                        interpret: Optional[bool] = None):
    """Kernel-backed fused decode step: append + three-branch attend.

    See `kernels.mita_paged_attn.mita_paged_attention` for the contract.
    Returns (out [S, Hkv, G, d], k_pool, v_pool) with the pools aliased
    in/out (new row written in place when ``fuse_append``).
    """
    if interpret is None:
        interpret = not on_tpu()
    return _mpa.mita_paged_attention(
        q, k_new, v_new, lm_q, lm_v, expert_idx, expert_valid,
        k_pool, v_pool, page_table, t, active, m_cnt,
        window=window, n_route=n_route, fuse_append=fuse_append,
        pipeline=dma_pipeline(), interpret=interpret)


def dma_pipeline() -> bool:
    """REPRO_DMA_PIPELINE: double-buffer the paged-decode kernel's per-row
    routed-expert DMAs (prefetch row i+1 while row i's copy drains).
    Default on; set to 0 to serialize the copies (debug / parity bisect)."""
    return os.environ.get("REPRO_DMA_PIPELINE", "1") != "0"


# ------------------------------------------------ fused chunk-prefill attn --

def chunk_prefill_vmem_bytes(nc: int, window: int, m: int, k_width: int,
                             g: int, d: int, itemsize: int = 4,
                             q_block: int = 0) -> int:
    """Per-program VMEM working set of the fused chunk-prefill kernel: the
    gathered slot context, the chunk q/k/v + out blocks, both landmark
    systems, the expert K/V tiles, and the f32 score rows
    (`kernels.mita_chunk_prefill` docstring).

    ``q_block`` > 0 sizes the tiled local branch: queries are processed in
    window-groups of ``q_block`` windows, each scoring only a
    ``(q_block + 2)``-window key slab instead of the full context, so the
    local score matrix is ``g·(q_block·w)·kb`` instead of ``g·nc·ctx``.
    ``q_block`` = 0 sizes the untiled full-context local branch.
    """
    ctx = m * window
    if q_block > 0:
        tw = q_block * window
        kb = min((q_block + 2) * window, ctx)
        local = g * tw * kb          # one local score tile at a time
    else:
        local = g * nc * ctx         # full-context local score matrix
    tiles = (2 * ctx * d            # gathered context (k, v)
             + (2 * g + 2) * nc * d  # chunk q/k/v + out
             + 8 * m * d            # lm_q/lm_v/pre_lm_q in+out tiles
             + 2 * m * k_width * d  # expert K/V tiles
             + 4 * d)               # q_sum / pre_q_sum in+out
    scores = 2 * m * ctx + local     # landmark (A+B) rows + local branch
    onehot = 2 * m * k_width * ctx   # [M*K, ctx] one-hot gather + iota
    tables = m * k_width * (4 + 4)   # expert_idx + validity
    return tiles * itemsize + (scores + onehot) * 4 + tables


def select_prefill_q_block(nc: int, window: int, m: int, k_width: int,
                           g: int, d: int, itemsize: int = 4,
                           budget: int = 0) -> Optional[int]:
    """Pick the local-branch tile size for the chunk-prefill kernel.

    Returns the largest ``q_block`` (in windows, a divisor of
    ``nc // window``) whose working set fits the VMEM budget, 0 for the
    untiled full-context path (only reachable when the chunk is not
    window-aligned), or None when no tiling fits (caller falls back to
    XLA).  Larger tiles amortize the key-slab reload; q_block = 1 is the
    floor the budget can force.
    """
    have = budget or vmem_budget_bytes()
    if nc % window == 0 and nc >= window:
        nw = nc // window
        for qb in range(nw, 0, -1):
            if nw % qb:
                continue
            if chunk_prefill_vmem_bytes(nc, window, m, k_width, g, d,
                                        itemsize, q_block=qb) <= have:
                return qb
        return None
    # non-window-aligned chunk: only the untiled local branch is defined
    if chunk_prefill_vmem_bytes(nc, window, m, k_width, g, d,
                                itemsize) <= have:
        return 0
    return None


# A dispatch decision that WANTED the fused chunk-prefill kernel but fell
# back to XLA because the working set exceeded the VMEM budget.  Counted at
# trace time (one decision per compiled shape, not per dispatch) — at
# production G·nc·ctx shapes the fallback used to be silent, so an engine
# could run an order of magnitude slower with no signal.  The serving
# engine surfaces the count as ``stats()["prefill_kernel_fallbacks"]``.
_PREFILL_KERNEL_FALLBACKS = 0
_PREFILL_FALLBACK_WARNED = False


def prefill_kernel_fallbacks() -> int:
    """Process-wide count of chunk-prefill kernel→XLA VMEM fallbacks."""
    return _PREFILL_KERNEL_FALLBACKS


def use_prefill_kernel(impl: str, *, nc: int, window: int, m: int,
                       k_width: int, g: int, d: int, itemsize: int = 4,
                       budget: int = 0) -> bool:
    """Chunk-prefill dispatch: fused Pallas kernel vs the XLA gather oracle.

    Same tri-state as `use_paged_kernel` (``DecodeConfig.prefill_impl``),
    with a process-wide override via ``REPRO_PREFILL_IMPL`` — the serving
    engine never retraces on an impl flip because the choice is made at
    trace time.

    A "no" that is due to the VMEM budget (rather than impl="xla" or
    running off-TPU in auto mode) increments `prefill_kernel_fallbacks`
    and warns once per process — production shapes that silently degrade
    to the XLA path are an observability bug, not a preference.
    """
    global _PREFILL_KERNEL_FALLBACKS, _PREFILL_FALLBACK_WARNED
    impl = os.environ.get("REPRO_PREFILL_IMPL", impl)
    if impl == "xla":
        return False
    if impl not in ("auto", "kernel"):
        raise ValueError(f"unknown prefill impl {impl!r}")
    q_block = select_prefill_q_block(nc, window, m, k_width, g, d,
                                     itemsize, budget)
    fits = q_block is not None
    if not fits and (impl == "kernel" or on_tpu()):
        _PREFILL_KERNEL_FALLBACKS += 1
        if not _PREFILL_FALLBACK_WARNED:
            _PREFILL_FALLBACK_WARNED = True
            need = chunk_prefill_vmem_bytes(
                nc, window, m, k_width, g, d, itemsize,
                q_block=1 if (nc % window == 0 and nc >= window) else 0)
            have = budget or vmem_budget_bytes()
            warnings.warn(
                f"chunk-prefill kernel working set {need} B at the "
                f"smallest local tile exceeds the VMEM budget {have} B "
                f"(nc={nc}, window={window}, m={m}, k_width={k_width}, "
                f"g={g}, d={d}, itemsize={itemsize}); dispatching to the "
                "XLA path — raise REPRO_VMEM_BUDGET_BYTES / "
                "DecodeConfig.vmem_budget or shrink the chunk to keep "
                "the fused kernel "
                "(further fallbacks are counted, not warned)",
                RuntimeWarning, stacklevel=2)
    if impl == "kernel":
        return fits
    return on_tpu() and fits


def batched_chunk_prefill(q, k, v, lm_q, lm_v, expert_idx, expert_valid,
                          q_sum, pre_lm_q, pre_q_sum, k_pool, v_pool,
                          page_table, t0, n_valid, n_train, active, *,
                          window: int, k_width: int, n_route: int,
                          external_finalize: bool, q_block: int = 0,
                          interpret: Optional[bool] = None):
    """Kernel-backed batched chunk prefill: append + landmark build +
    three-branch chunk attention for every active row in one kernel.

    Operates on COMPACT per-row slot state ([P, ...] — the caller gathers
    rows by slot id and scatters the returned updates back); the pools are
    aliased in/out.  ``q_block`` (windows per local-branch tile, from
    `select_prefill_q_block`; 0 = untiled) is static — a budget change
    retraces.  See `kernels.mita_chunk_prefill.mita_chunk_prefill_fused`
    for the full contract.
    """
    if interpret is None:
        interpret = not on_tpu()
    return _mcp.mita_chunk_prefill_fused(
        q, k, v, lm_q, lm_v, expert_idx, expert_valid, q_sum, pre_lm_q,
        pre_q_sum, k_pool, v_pool, page_table, t0, n_valid, n_train,
        active, window=window, k_width=k_width, n_route=n_route,
        external_finalize=external_finalize, q_block=q_block,
        interpret=interpret)


# ------------------------------------------------ fused paged finalize ----

def paged_finalize_vmem_bytes(window: int, m: int, k_width: int, d: int,
                              itemsize: int = 4) -> int:
    """Per-program VMEM working set of the fused paged-finalize kernel:
    the gathered slot context, the landmark in+out tiles, the q_sum
    accumulator, and the f32 landmark score row
    (`kernels.mita_paged_finalize` docstring)."""
    ctx = m * window
    tiles = (2 * ctx * d        # gathered context (k, v)
             + 4 * m * d        # lm_q / lm_v in+out tiles
             + 4 * d)           # q_sum in+out (f32)
    scores = 2 * ctx            # landmark score + softmax rows (f32)
    onehot = k_width * ctx      # top-k location -> global-row gather iota
    tables = 2 * m * k_width * (4 + 4)   # expert idx/valid in+out
    return tiles * itemsize + (scores + onehot) * 4 + tables


# Finalize analogue of the two fallback counters above: a dispatch decision
# that WANTED the fused finalize kernel but fell back to the XLA gathers
# because the working set exceeded the VMEM budget.  Counted at trace time.
# Surfaced as ``stats()["finalize_kernel_fallbacks"]`` by the MiTA backend.
_FINALIZE_KERNEL_FALLBACKS = 0
_FINALIZE_FALLBACK_WARNED = False


def finalize_kernel_fallbacks() -> int:
    """Process-wide count of paged-finalize kernel→XLA VMEM fallbacks."""
    return _FINALIZE_KERNEL_FALLBACKS


def use_finalize_kernel(impl: str, *, window: int, m: int, k_width: int,
                        d: int, itemsize: int = 4, budget: int = 0) -> bool:
    """Paged-finalize dispatch: fused Pallas kernel vs the XLA gather
    oracle in `core.mita_decode._paged_finalize`.

    Same tri-state as `use_paged_kernel` (``DecodeConfig.finalize_impl``),
    with a process-wide override via ``REPRO_FINALIZE_IMPL``.  A "no" due
    to the VMEM budget (rather than impl="xla" or running off-TPU in auto
    mode) increments `finalize_kernel_fallbacks` and warns once.
    """
    global _FINALIZE_KERNEL_FALLBACKS, _FINALIZE_FALLBACK_WARNED
    impl = os.environ.get("REPRO_FINALIZE_IMPL", impl)
    if impl == "xla":
        return False
    if impl not in ("auto", "kernel"):
        raise ValueError(f"unknown finalize impl {impl!r}")
    need = paged_finalize_vmem_bytes(window, m, k_width, d, itemsize)
    have = budget or vmem_budget_bytes()
    fits = need <= have
    if not fits and (impl == "kernel" or on_tpu()):
        _FINALIZE_KERNEL_FALLBACKS += 1
        if not _FINALIZE_FALLBACK_WARNED:
            _FINALIZE_FALLBACK_WARNED = True
            warnings.warn(
                f"paged-finalize kernel working set {need} B exceeds the "
                f"VMEM budget {have} B (window={window}, m={m}, "
                f"k_width={k_width}, d={d}, itemsize={itemsize}); "
                "dispatching to the XLA path — raise "
                "REPRO_VMEM_BUDGET_BYTES / DecodeConfig.vmem_budget to "
                "keep the fused kernel "
                "(further fallbacks are counted, not warned)",
                RuntimeWarning, stacklevel=2)
    if impl == "kernel":
        return fits
    return on_tpu() and fits


def paged_finalize(q_sum, lm_q, lm_v, expert_idx, expert_valid, k_pool,
                   v_pool, page_table, t_new, due, *, window: int,
                   k_width: int, interpret: Optional[bool] = None):
    """Kernel-backed paged landmark finalize: pool the completed window's
    queries into a landmark row and rebuild the top-k expert gather, per
    (slot, KV-head) program, reading pages via DMA.

    Returns (lm_q, lm_v, expert_idx, expert_valid i32, q_sum) — the
    caller merges them into the paged state.  See
    `kernels.mita_paged_finalize.mita_paged_finalize_fused`.
    """
    if interpret is None:
        interpret = not on_tpu()
    return _mpf.mita_paged_finalize_fused(
        q_sum, lm_q, lm_v, expert_idx, expert_valid, k_pool, v_pool,
        page_table, t_new, due, window=window, k_width=k_width,
        interpret=interpret)


# ------------------------------------------------ fallback counter scope --

def fallback_counters() -> dict:
    """Snapshot of every kernel→XLA fallback counter (process-wide)."""
    return {"prefill": _PREFILL_KERNEL_FALLBACKS,
            "paged": _PAGED_KERNEL_FALLBACKS,
            "finalize": _FINALIZE_KERNEL_FALLBACKS}


def reset_fallback_counters() -> None:
    """Zero all fallback counters (and re-arm the warn-once flags) so a
    bench run or test reports only its own dispatch decisions."""
    global _PREFILL_KERNEL_FALLBACKS, _PREFILL_FALLBACK_WARNED
    global _PAGED_KERNEL_FALLBACKS, _PAGED_FALLBACK_WARNED
    global _FINALIZE_KERNEL_FALLBACKS, _FINALIZE_FALLBACK_WARNED
    _PREFILL_KERNEL_FALLBACKS = 0
    _PREFILL_FALLBACK_WARNED = False
    _PAGED_KERNEL_FALLBACKS = 0
    _PAGED_FALLBACK_WARNED = False
    _FINALIZE_KERNEL_FALLBACKS = 0
    _FINALIZE_FALLBACK_WARNED = False


@contextlib.contextmanager
def scoped_fallback_counters():
    """Scope the fallback counters to a block: yields a dict that is
    filled with this block's deltas on exit.  Counters keep accumulating
    globally (backends that hold base snapshots stay correct); only the
    yielded view is scoped.

        with ops.scoped_fallback_counters() as fb:
            run_bench()
        assert fb["prefill"] == 0
    """
    base = fallback_counters()
    delta: dict = {}
    try:
        yield delta
    finally:
        now = fallback_counters()
        for key, val in now.items():
            delta[key] = val - base[key]


def routed_expert_partial(q_sorted, assign, k_e, v_e, valid,
                          block_q: Optional[int] = None,
                          interpret: Optional[bool] = None):
    """Kernel-backed routed-expert partials with arbitrary lead dims.

    q_sorted: [..., NS, d]; assign: [..., NS];
    k_e/v_e: [kv_lead..., M, K, d] (lead may contain broadcast-1 dims);
    valid: [kv_lead..., M, K].
    Returns (o, m_stat, l) with q_sorted's lead dims.  NS need not divide
    the block size — `mita_expert_attention` pads internally.
    """
    if interpret is None:
        interpret = not on_tpu()
    if block_q is None:
        block_q = default_block_q()
    lead = q_sorted.shape[:-2]
    ns, d = q_sorted.shape[-2:]
    m, kw = k_e.shape[-3], k_e.shape[-2]

    def bcast(x, trailing):
        tgt = lead + x.shape[-trailing:]
        return jnp.broadcast_to(x, tgt).reshape((1, -1) + x.shape[-trailing:])

    q4 = q_sorted.reshape((1, -1, ns, d))
    a4 = assign.reshape((1, -1, ns))
    ke4 = bcast(k_e, 3)
    ve4 = bcast(v_e, 3)
    va4 = bcast(valid, 2)
    o, ms, l = _mea.mita_expert_attention(
        q4, a4, ke4, ve4, va4,
        block_q=min(block_q, ns), interpret=interpret)
    return (o.reshape(lead + (ns, d)), ms.reshape(lead + (ns,)),
            l.reshape(lead + (ns,)))
