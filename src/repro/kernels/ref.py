"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package is validated against these references across
shape/dtype sweeps in tests/test_kernels.py (interpret mode on CPU).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = float(jnp.finfo(jnp.float32).min)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = False) -> jax.Array:
    """q,k,v: [B, H, N, d] -> [B, H, N, d] (f32 softmax accumulation)."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / math.sqrt(d)
    if causal:
        n = q.shape[-2]
        mask = jnp.tril(jnp.ones((n, n), bool))
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def mita_expert_attention_ref(q_sorted: jax.Array, assign: jax.Array,
                              k_e: jax.Array, v_e: jax.Array,
                              valid: jax.Array):
    """Routed-expert attention partial (paper Alg. 1 line 14).

    q_sorted: [B, H, NS, d]  sub-queries sorted by expert id
    assign:   [B, H, NS]     expert id per sub-query (== m -> inactive)
    k_e, v_e: [B, H, M, K, d] gathered expert key/value tiles
    valid:    [B, H, M, K]   gather validity
    Returns (o [B,H,NS,d], m_stat [B,H,NS], l [B,H,NS]) un-normalized
    online-softmax partials (combined downstream with shared/local branches).
    """
    b, h, ns, d = q_sorted.shape
    m, kk = k_e.shape[-3], k_e.shape[-2]
    scores = jnp.einsum("bhnd,bhmkd->bhnmk", q_sorted, k_e) / math.sqrt(d)
    ok = (assign[..., None] == jnp.arange(m)[None, None, None, :])
    mask = ok[..., None] & valid[..., None, :, :]
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    scores = scores.reshape(b, h, ns, m * kk)
    mx = jnp.max(scores, axis=-1)
    safe = jnp.where(mx == NEG_INF, 0.0, mx)
    p = jnp.exp(scores - safe[..., None])
    p = jnp.where(scores == NEG_INF, 0.0, p)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhnk,bhkd->bhnd", p.astype(v_e.dtype),
                   v_e.reshape(b, h, m * kk, d))
    return o, mx, l
