#!/usr/bin/env python3
"""Check internal markdown links (CI docs lane).

Scans every tracked *.md file for inline links/images and verifies that
relative targets exist on disk (anchors and external URLs are skipped).
Exits non-zero listing every broken link.

Run:  python scripts/check_doc_links.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache"}


def md_files():
    for p in sorted(ROOT.rglob("*.md")):
        if not SKIP_DIRS & set(p.relative_to(ROOT).parts):
            yield p


def main() -> int:
    broken = []
    n_links = 0
    for md in md_files():
        for target in LINK.findall(md.read_text(encoding="utf-8")):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            n_links += 1
            path = target.split("#", 1)[0]
            if not (md.parent / path).resolve().exists():
                broken.append(f"{md.relative_to(ROOT)} -> {target}")
    if broken:
        print(f"{len(broken)} broken internal link(s):")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"ok: {n_links} internal links across "
          f"{sum(1 for _ in md_files())} markdown files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
