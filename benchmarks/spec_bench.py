"""Speculative-decoding benchmark: spec_k > 0 vs the plain decode loop.

One row per (backend, spec) cell over the same queued mixed-length trace:

  * ``mita``   — landmark self-drafting: the drafter runs the model over
    the COMPRESSED branch only (landmark + expert summaries, no local
    window reads), the fused verify pass re-derives every draft from the
    full three-branch program in one teacher-forced dispatch;
  * ``mamba2`` / ``rglru`` — "self" mode: the draft scan IS the exact
    decode recurrence, so acceptance is total and a round of k drafts +
    1 verify commits k+1 tokens in 2 dispatches instead of k+1 (the
    dispatch-collapse win this bench measures).

Gates:
  * bit-parity (ALWAYS, hard): every request's stream with spec_k > 0 is
    identical to the spec_k = 0 engine — speculation is lossless or it
    fails the build;
  * accept-rate > 0: the drafter must actually land accepted tokens;
  * tok/s >= 0.95x the non-spec engine on the recurrent self-draft rows
    (their speedup is dispatch arithmetic, so it holds even on CPU CI
    runners); the MiTA row's tok/s ratio is reported but advisory off-TPU
    (the landmark drafter trades FLOPs for memory traffic, a bet the
    paged kernel only cashes on real accelerators).

Emits BENCH_spec.json (always, before any gate-failure exit): per-cell
tok/s, accept-rate, dispatch counts, rollback counts, and the gate block.

Run:  PYTHONPATH=src python -m benchmarks.spec_bench [--smoke]
      PYTHONPATH=src python -m benchmarks.run spec
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from benchmarks.common import emit
from benchmarks.serve_bench import _arch_cell
from repro.core.mita_decode import window_aligned
from repro.serve import EngineConfig, Request, ServingEngine

BACKENDS = ("mita", "mamba2", "rglru")
SPEC_K = 3


def _trace(vocab: int, w: int, n_req: int, lo: int, hi: int,
           seed: int = 11) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=int(
                        rng.choice([w, 2 * w]))).astype(np.int32),
                    max_new_tokens=int(rng.integers(lo, hi)))
            for i in range(n_req)]


def run_spec(n_req: int = 12, smoke: bool = False,
             out: str = "BENCH_spec.json") -> dict:
    gens = dict(mita=(8, 25), mamba2=(8, 21), rglru=(8, 21))
    results: dict = {"config": dict(n_req=n_req, spec_k=SPEC_K, smoke=smoke)}
    gate_fail: list[str] = []
    for name in BACKENDS:
        cfg, params, mk = _arch_cell(name)
        w = cfg.attn.window
        lo, hi = gens[name]
        reqs = _trace(cfg.vocab, w, n_req, lo, hi)
        total = sum(r.max_new_tokens for r in reqs)
        pages = window_aligned(2 * w + hi, w) // w
        base = EngineConfig(n_slots=4, pages_per_slot=pages,
                            n_pages=4 * pages + 4, prefill_chunk=w,
                            sample_device="fused")
        spec = dataclasses.replace(base, spec_k=SPEC_K)

        row: dict = {}
        tokens: dict[str, dict[int, np.ndarray]] = {}
        for cell, ecfg in (("plain", base), ("spec", spec)):
            # compile outside the timed region: the probe runs the
            # IDENTICAL trace, so every program shape (prefill widths
            # included) the timed runs dispatch is already compiled —
            # then best-of-3 fresh-engine repeats against CI-runner noise
            dt = float("inf")
            for _ in range(4):
                eng2 = ServingEngine(params, cfg, ecfg, backend=mk(ecfg))
                t0 = time.perf_counter()
                done = eng2.run([Request(rid=r.rid, prompt=r.prompt.copy(),
                                         max_new_tokens=r.max_new_tokens)
                                 for r in reqs])
                dt = min(dt, time.perf_counter() - t0)
            st = eng2.stats()
            tokens[cell] = {f.rid: f.tokens for f in done}
            row[cell] = dict(
                tok_s=total / dt, steps=st["steps"],
                decode_dispatches=st["decode_dispatches"],
                spec_drafted=st["spec_drafted"],
                spec_accepted=st["spec_accepted"],
                spec_rollbacks=st["spec_rollbacks"],
                accept_rate=(st["spec_accepted"]
                             / max(st["spec_drafted"], 1)),
                rejected=st["rejected"],
                deadline_expired=st["deadline_expired"],
                retries=st["retries"],
                quarantined=st["quarantined"],
                degradation_level=st["degradation_level"])
            emit(f"spec_{name}_{cell}", dt * 1e6 / total,
                 f"{row[cell]['tok_s']:.1f} tok/s | steps={st['steps']} "
                 f"dispatches={st['decode_dispatches']} | accepted "
                 f"{st['spec_accepted']}/{st['spec_drafted']} "
                 f"rollbacks={st['spec_rollbacks']}")

        match = (set(tokens["plain"]) == set(tokens["spec"]) and all(
            np.array_equal(tokens["plain"][r], tokens["spec"][r])
            for r in tokens["plain"]))
        tps_ratio = row["spec"]["tok_s"] / row["plain"]["tok_s"]
        # the recurrent self-drafters' win is dispatch arithmetic — gate
        # it; the MiTA landmark drafter's wall-clock is advisory off-TPU
        tps_gated = name != "mita"
        row["gates"] = dict(
            parity=bool(match),
            accept_rate=row["spec"]["accept_rate"],
            accept_nonzero=row["spec"]["accept_rate"] > 0,
            tps_ratio=tps_ratio, tps_gated=tps_gated,
            tps_gate=bool(tps_ratio >= 0.95) if tps_gated else True)
        if not match:
            gate_fail.append(f"{name}:parity")
        if not row["gates"]["accept_nonzero"]:
            gate_fail.append(f"{name}:accept_rate")
        if not row["gates"]["tps_gate"]:
            gate_fail.append(f"{name}:tps")
        results[name] = row
        emit(f"spec_{name}_gates", 0.0,
             f"parity={match} accept_rate="
             f"{row['spec']['accept_rate']:.2f} "
             f"tps_ratio={tps_ratio:.3f} "
             f"({'gate>=0.95' if tps_gated else 'advisory'})")

    results["gates_failed"] = gate_fail
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    if gate_fail:
        raise SystemExit(f"spec bench gate(s) failed: {gate_fail}")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: fewer requests (gates unchanged — "
                         "parity and the recurrent tok/s ratio hold at "
                         "any scale)")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--out", default="BENCH_spec.json")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run_spec(n_req=args.requests or (6 if args.smoke else 12),
             smoke=args.smoke, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
