"""Chaos benchmark: supervised serving under seeded fault injection.

One cell per backend (the `serve_arch` matrix: paged MiTA, Mamba2 SSD,
RG-LRU hybrid), four phases each:

  1. **reference** — a fault-free engine runs the trace; its greedy
     tokens are the parity oracle for every later phase.
  2. **chaos** — the same trace through `Supervisor` + `ChaosBackend`
     with seeded transient faults, slot-bound faults (quarantine +
     bit-exact resurrection), and allocator spikes (real page pressure).
     Gates: injected faults on >= 20% of step attempts, greedy bit-parity
     for every completed request, and a drained pool (zero page leaks).
  3. **ladder** — one scripted persistent fault that only clears at the
     last degradation rung, so the supervised engine walks
     spec_off -> prefix_cache_off -> xla_forced and still gates parity.
  4. **kill + restore** — the supervised run is snapshotted mid-trace
     (atomic journal), the engine is dropped, and a fresh supervised
     engine restores and drains.  Gate: the union of pre-kill and
     post-restore tokens is bit-identical to the reference.

Rows land in ``BENCH_chaos.json`` with the robustness counters
(`rejected` / `deadline_expired` / `retries` / `quarantined` /
`degradation_level` / `stragglers`) plus the injector's own counts; any
failed gate raises SystemExit (the CI lane hard-fails).

Run:  PYTHONPATH=src python -m benchmarks.chaos_bench --smoke
      PYTHONPATH=src python -m benchmarks.run chaos
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from benchmarks.serve_bench import BACKENDS, _arch_cell
from repro.core.mita_decode import window_aligned
from repro.serve import (ChaosBackend, ChaosConfig, EngineConfig, Request,
                         ServingEngine, Supervisor, SupervisorConfig)

#: robustness counters every bench row carries (mirrors STATS_SCHEMA adds)
ROBUSTNESS_KEYS = ("rejected", "deadline_expired", "retries",
                   "quarantined", "degradation_level")


def _trace(cfg, n_req: int, hi: int, seed: int = 3) -> list[Request]:
    w = cfg.attn.window
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=int(
                        rng.choice([w, 2 * w]))).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, hi)))
            for i in range(n_req)]


def _copies(reqs: list[Request]) -> list[Request]:
    return [Request(rid=r.rid, prompt=r.prompt.copy(),
                    max_new_tokens=r.max_new_tokens) for r in reqs]


def _completed(finished) -> dict[int, np.ndarray]:
    return {f.rid: f.tokens for f in finished if f.reason == "complete"}


def _parity(tokens: dict, ref: dict) -> bool:
    return set(tokens) == set(ref) and all(
        np.array_equal(tokens[r], ref[r]) for r in ref)


def _leaks(eng: ServingEngine) -> int:
    return eng.alloc.in_use + len(eng.alloc.refs)


def run_chaos(which: str = "all", n_req: int = 8,
              out: str = "BENCH_chaos.json", kill_after: int = 6) -> dict:
    results: dict = {}
    gates_failed: list[str] = []
    for name in (BACKENDS if which in ("all", None) else (which,)):
        cfg, params, mk = _arch_cell(name)
        w = cfg.attn.window
        hi = 13
        reqs = _trace(cfg, n_req, hi)
        total = sum(r.max_new_tokens for r in reqs)
        pages = window_aligned(2 * w + hi, w) // w
        ecfg = EngineConfig(n_slots=4, pages_per_slot=pages,
                            n_pages=4 * pages + 4, prefill_chunk=w)

        # -- phase 1: fault-free reference ------------------------------
        ref_eng = ServingEngine(params, cfg, ecfg, backend=mk(ecfg))
        ref = _completed(ref_eng.run(_copies(reqs)))
        assert _leaks(ref_eng) == 0

        # -- phase 2: seeded chaos (transient + slot + spikes) ----------
        chaos = ChaosConfig(seed=11, p_fault=0.35, transient_len=2,
                            p_slot_fault=0.3, alloc_spike_every=6,
                            alloc_spike_pages=2, alloc_spike_len=3,
                            ops=("decode_step", "prefill_chunks"))
        cb = ChaosBackend(mk(ecfg), chaos)
        eng = ServingEngine(params, cfg, ecfg, backend=cb)
        sup = Supervisor(eng, SupervisorConfig(max_retries=2))
        t0 = time.perf_counter()
        done = sup.run(_copies(reqs))
        dt = time.perf_counter() - t0
        st = sup.stats()
        attempts = st["steps"] + cb.n_injected
        fault_fraction = cb.n_injected / max(attempts, 1)
        chaos_parity = _parity(_completed(done), ref)
        chaos_leaks = _leaks(eng)
        sup.close()

        # -- phase 3: scripted persistent fault walks the full ladder ---
        lcfg = ChaosConfig(seed=0, persistent_clears_at=3)
        lcb = ChaosBackend(mk(ecfg), lcfg)
        leng = ServingEngine(params, cfg, ecfg, backend=lcb)
        lsup = Supervisor(leng, SupervisorConfig(max_retries=1))
        lcb.inject("decode_step", kind="persistent")
        ldone = lsup.run(_copies(reqs))
        ladder_parity = _parity(_completed(ldone), ref)
        ladder_level = leng.degradation_level
        ladder_leaks = _leaks(leng)
        lsup.close()        # restores REPRO_PREFILL_IMPL

        # -- phase 4: kill mid-trace, restore on a fresh engine ---------
        rcb = ChaosBackend(mk(ecfg), chaos)
        reng = ServingEngine(params, cfg, ecfg, backend=rcb)
        rsup = Supervisor(reng, SupervisorConfig(max_retries=2))
        for r in _copies(reqs):
            rsup.submit(r)
        for _ in range(kill_after):
            if not rsup.step():
                break
        fd, snap_path = tempfile.mkstemp(suffix=".chaos.json")
        os.close(fd)
        try:
            rsup.save_snapshot(snap_path)
            rsup.close()    # the old engine is now dead
            snap = Supervisor.load_snapshot(snap_path)
        finally:
            os.unlink(snap_path)
        rcb2 = ChaosBackend(mk(ecfg), ChaosConfig(seed=23, p_fault=0.2,
                                                  transient_len=1,
                                                  ops=("decode_step",)))
        reng2 = ServingEngine(params, cfg, ecfg, backend=rcb2)
        rsup2 = Supervisor(reng2, SupervisorConfig(max_retries=2))
        rsup2.restore(snap)
        while rsup2.step():
            pass
        restore_parity = _parity(_completed(reng2.finished), ref)
        restore_leaks = _leaks(reng2)
        rsup2.close()

        gates = dict(
            parity=bool(chaos_parity),
            zero_leak=bool(chaos_leaks == 0 and ladder_leaks == 0
                           and restore_leaks == 0),
            fault_fraction=bool(fault_fraction >= 0.2),
            ladder_walked=bool(ladder_level == 3 and ladder_parity),
            restore_parity=bool(restore_parity))
        row = dict(
            tok_s=total / dt, fault_fraction=fault_fraction,
            injected=cb.n_injected, faults_started=cb.n_faults_started,
            spikes=cb.n_spikes, stragglers=st["stragglers"],
            ladder_rungs=list(lsup.degradations), gates=gates)
        for k in ROBUSTNESS_KEYS:
            row[k] = st[k]
        results[name] = row
        gates_failed += [f"{name}:{g}" for g, ok in gates.items() if not ok]
        emit(f"chaos_{name}", dt * 1e6 / total,
             f"{row['tok_s']:.1f} tok/s | injected={cb.n_injected} "
             f"({fault_fraction:.0%} of attempts) retries={st['retries']} "
             f"quarantined={st['quarantined']} spikes={cb.n_spikes} | "
             f"parity={chaos_parity} ladder={ladder_level} "
             f"restore={restore_parity} leaks={chaos_leaks}")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    if gates_failed:
        raise SystemExit(f"chaos gates failed: {gates_failed}")
    return results


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: fewer requests")
    ap.add_argument("--backend", default="all",
                    choices=("all",) + BACKENDS)
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run_chaos(args.backend, n_req=6 if args.smoke else 8, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
