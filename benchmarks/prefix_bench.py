"""Prefix-cache benchmark: shared-system-prompt serving, warm vs cold.

One cell: a Poisson trace whose requests all share a long system prompt
(12 windows) followed by a short unique tail (1 window).  Two engines run
the identical trace:

  * cold — the chunked continuous-batching engine, no prefix cache: every
    request prefills all 13 windows itself;
  * warm — `prefix_cache=True`: the first completed prefill commits the
    prompt's window pages to the radix cache, every later arrival attaches
    the shared pages by reference and prefills ONLY its tail chunk, so
    TTFT for a hit is one chunk dispatch instead of thirteen.

Gates (full mode; --smoke gates parity + nonzero hits only, timing is
advisory on shared CI runners):
  * greedy bit-parity: every request's tokens identical warm vs cold;
  * hit TTFT p99 <= 0.25x the cold engine's TTFT p99 over the same rids;
  * aggregate tokens/sec >= 0.95x cold.

Emits BENCH_prefix.json (always, before any gate failure exits) with both
engines' latency rows plus the scheduler's cache/sharing counters.

Run:  PYTHONPATH=src python -m benchmarks.prefix_bench [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from benchmarks.common import emit, tiny_lm_cfg
from repro.core.mita_decode import window_aligned
from repro.models import transformer as tfm
from repro.serve import EngineConfig, Request, ServingEngine

SYS_W = 12          # shared system prompt, in windows
TAIL_W = 1          # unique per-request tail, in windows
GEN_RANGE = (4, 13)


def _trace(vocab: int, w: int, n_req: int, seed: int = 0,
           mean_gap_s: float = 0.05) -> list[Request]:
    """Poisson arrivals; prompt = shared 12-window system prefix + a
    1-window unique tail (window-aligned, so every prompt is cacheable)."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, vocab, size=SYS_W * w).astype(np.int32)
    arrivals = np.cumsum(rng.exponential(mean_gap_s, size=n_req))
    return [Request(
        rid=i,
        prompt=np.concatenate([
            sys_prompt,
            rng.integers(0, vocab, size=TAIL_W * w).astype(np.int32)]),
        max_new_tokens=int(rng.integers(*GEN_RANGE)),
        arrival=float(arrivals[i]))
        for i in range(n_req)]


def _ttft(done, start):
    return {f.rid: f.first_token - (start + f.arrival) for f in done}


def _probe(eng, vocab: int, w: int, seed: int = 99) -> None:
    """Compile outside the timed region: two identical aligned prompts so
    a prefix-cache engine also compiles the attach + short-resume path (the
    second probe is a guaranteed hit).  The probe prompt shares nothing
    with the benchmark trace, so it only costs the trie a few pages."""
    rng = np.random.default_rng(seed)
    p = rng.integers(0, vocab, size=(SYS_W + TAIL_W) * w).astype(np.int32)
    eng.run([Request(rid=-1 - i, prompt=p.copy(),
                     max_new_tokens=GEN_RANGE[1] - 1) for i in range(2)])


def run_prefix(n_req: int = 16, n_slots: int = 4, smoke: bool = False,
               out: str = "BENCH_prefix.json") -> dict:
    cfg = tiny_lm_cfg("mita_ref", m=8, k=16, layers=2, d=64, seq=128)
    w = cfg.attn.window
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    reqs = _trace(cfg.vocab, w, n_req)
    total_tokens = sum(r.max_new_tokens for r in reqs)
    pages = window_aligned((SYS_W + TAIL_W) * w + GEN_RANGE[1], w) // w
    # headroom past the slots' worst case so trie pages (the whole probe
    # prompt + the trace's system prompt + one tail node per request)
    # never force evictions inside the measured region
    base = EngineConfig(n_slots=n_slots, pages_per_slot=pages,
                        n_pages=n_slots * pages + 3 * pages + n_req,
                        prefill_chunk=w)
    warm_cfg = dataclasses.replace(base, prefix_cache=True)

    results: dict = {"config": dict(
        n_req=n_req, n_slots=n_slots, window=w, sys_windows=SYS_W,
        tail_windows=TAIL_W, prefill_chunk=w, smoke=smoke)}
    tokens: dict[str, dict[int, np.ndarray]] = {}
    ttfts: dict[str, dict[int, float]] = {}
    for name, ecfg in (("cold", base), ("warm", warm_cfg)):
        eng = ServingEngine(params, cfg, ecfg)
        _probe(eng, cfg.vocab, w)
        trace = [Request(rid=r.rid, prompt=r.prompt.copy(),
                         max_new_tokens=r.max_new_tokens,
                         arrival=r.arrival) for r in reqs]
        start = time.perf_counter()
        done = eng.run(trace, realtime=True)
        dt = time.perf_counter() - start
        ttft = _ttft(done, start)
        ttfts[name] = ttft
        st = eng.stats()
        tokens[name] = {f.rid: f.tokens for f in done}
        hit_rids = sorted(rid for rid, n in eng.prefix_hits.items()
                          if n > 0 and rid >= 0)
        results[name] = dict(
            tok_s=total_tokens / dt,
            ttft_p50=float(np.percentile(list(ttft.values()), 50)),
            ttft_p99=float(np.percentile(list(ttft.values()), 99)),
            hit_rids=hit_rids,
            prefix_cache_hits=st["prefix_cache_hits"],
            prefix_cache_misses=st["prefix_cache_misses"],
            pages_shared=st["pages_shared"],
            prefix_tokens_reused=st["prefix_tokens_reused"],
            prefix_cache_evictions=st["prefix_cache_evictions"],
            preemptions=st["preemptions"],
            prefill_kernel_fallbacks=st["prefill_kernel_fallbacks"],
            spec_drafted=st["spec_drafted"],
            spec_accepted=st["spec_accepted"],
            spec_rollbacks=st["spec_rollbacks"],
            rejected=st["rejected"],
            deadline_expired=st["deadline_expired"],
            retries=st["retries"],
            quarantined=st["quarantined"],
            degradation_level=st["degradation_level"])
        emit(f"prefix_{name}", dt * 1e6 / total_tokens,
             f"{results[name]['tok_s']:.1f} tok/s | ttft "
             f"p50 {results[name]['ttft_p50'] * 1e3:.0f}ms "
             f"p99 {results[name]['ttft_p99'] * 1e3:.0f}ms | "
             f"hits={st['prefix_cache_hits']} "
             f"pages_shared={st['pages_shared']} "
             f"tokens_reused={st['prefix_tokens_reused']}")

    match = all(np.array_equal(tokens["warm"][r.rid], tokens["cold"][r.rid])
                for r in reqs)
    hits = results["warm"]["prefix_cache_hits"]
    hit_rids = results["warm"]["hit_rids"]
    # the per-request win of attaching instead of re-prefilling: warm TTFT
    # p99 over the HIT requests vs the cold engine's TTFT p99 over the
    # very same rids (same arrivals, same queueing pressure)
    if hit_rids:
        hit_p99 = float(np.percentile(
            [ttfts["warm"][r] for r in hit_rids], 99))
        cold_p99 = float(np.percentile(
            [ttfts["cold"][r] for r in hit_rids], 99))
    else:
        hit_p99 = cold_p99 = float("nan")
    ttft_ratio = hit_p99 / cold_p99 if hit_rids else float("inf")
    tps_ratio = results["warm"]["tok_s"] / results["cold"]["tok_s"]
    gates = dict(
        greedy_match=bool(match),
        hits_nonzero=hits > 0,
        hit_ttft_p99=hit_p99, cold_ttft_p99_same_rids=cold_p99,
        ttft_ratio=ttft_ratio, ttft_gate=bool(ttft_ratio <= 0.25),
        tps_ratio=tps_ratio, tps_gate=bool(tps_ratio >= 0.95))
    checked = ["greedy_match", "hits_nonzero"]
    if not smoke:
        checked += ["ttft_gate", "tps_gate"]
    gates["pass"] = all(bool(gates[g]) for g in checked)
    results["gates"] = gates
    emit("prefix_gates", 0.0,
         f"greedy_match={match} hits={hits} "
         f"ttft_ratio={ttft_ratio:.3f} (gate<=0.25, "
         f"{'checked' if not smoke else 'advisory'}) "
         f"tps_ratio={tps_ratio:.3f} pass={gates['pass']}")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    if not gates["pass"]:
        failed = [g for g in checked if not bool(gates[g])]
        raise SystemExit(f"prefix bench gate(s) failed: {failed}")
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: fewer requests, parity+hits gates only")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--out", default="BENCH_prefix.json")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    n_req = args.requests or (6 if args.smoke else 16)
    run_prefix(n_req=n_req, smoke=args.smoke, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
