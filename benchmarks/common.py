"""Shared benchmark utilities: timing, model builders, CSV emission."""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.models.modules import AttnConfig, ModelConfig

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn, *args, iters: int = 5, warmup: int = 1) -> float:
    """Median wall time per call in microseconds (jit-compiled fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def tiny_vit_cfg(backend: str, n: int, m: int = 8, k: int = 8,
                 layers: int = 2, d: int = 64,
                 landmark: str = "pool1d") -> ModelConfig:
    window = max(1, n // m)
    return ModelConfig(
        n_layers=layers, d_model=d, n_heads=4, n_kv=4, d_ff=2 * d,
        vocab=11,
        attn=AttnConfig(backend=backend, window=window, k=k, s=1,
                        causal=False, block_q=32, landmark=landmark))


def tiny_lm_cfg(backend: str, m: int = 8, k: int = 16, layers: int = 2,
                d: int = 64, vocab: int = 211, seq: int = 256) -> ModelConfig:
    return ModelConfig(
        n_layers=layers, d_model=d, n_heads=4, n_kv=2, d_ff=2 * d,
        vocab=vocab,
        attn=AttnConfig(backend=backend, window=max(1, seq // m), k=k, s=1,
                        block_q=64))
