"""One benchmark per paper table/figure (CPU-scale proxies of the paper's
GPU experiments; relative orderings and ratios are the claims under test).

| function                        | paper artifact |
|---------------------------------|----------------|
| tab2_imagenet_proxy             | Tab. 2 — DeiT-recipe attention-swap comparison |
| tab4_segmentation_flops         | Tab. 4 — ADE20K backbone FLOPs reduction |
| tab5_lra_throughput             | Tab. 5 — LRA accuracy/throughput |
| tab6_ablations                  | Tab. 6 — landmark/(m,k)/branch ablations |
| tab7_algorithmic_generalization | Tab. 7 / Fig. 9 — train-A/infer-B transfer |
| fig5_inference_throughput       | Fig. 5 — decode throughput vs context |
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, tiny_lm_cfg, tiny_vit_cfg
from repro.models import vit as vitm
from repro.models.modules import AttnConfig, ModelConfig
from repro.optim import OptConfig, adamw_init, adamw_update


def _train_vit(backend: str, steps: int = 60, n: int = 128, b: int = 32,
               m: int = 16, k: int = 16, seed: int = 0,
               landmark: str = "pool1d"):
    """Train the tiny ViT on the sparse-signal synthetic task (tuned so the
    attention mechanisms separate: compression dilutes the 3 signal patches,
    retrieval finds them).  Returns (eval_acc, us_per_step, params, cfg)."""
    cfg = tiny_vit_cfg(backend, n, m=m, k=k, landmark=landmark)
    n_classes, patch_dim = 10, 48
    params = vitm.vit_init(jax.random.PRNGKey(seed), cfg, patch_dim, n_classes)
    opt_cfg = OptConfig(lr=2e-3, warmup_steps=5, total_steps=steps,
                        weight_decay=0.01)
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, batch):
        loss, g = jax.value_and_grad(vitm.vit_loss)(p, batch, cfg)
        p, o, _ = adamw_update(g, o, p, opt_cfg)
        return p, o, loss

    us = None
    for i in range(steps):
        batch = vitm.synthetic_vision_batch(
            jax.random.PRNGKey(1000 + i), b, n, patch_dim, n_classes,
            n_signal=3, noise=1.2)
        params, opt, loss = step(params, opt, batch)
        if i == steps - 1:
            us = time_fn(lambda: step(params, opt, batch), iters=3)
    evalb = vitm.synthetic_vision_batch(
        jax.random.PRNGKey(9), 256, n, patch_dim, n_classes,
        n_signal=3, noise=1.2)
    acc = float(vitm.vit_accuracy(params, evalb, cfg))
    return acc, us, params, cfg


def tab2_imagenet_proxy():
    """Attention-swap comparison under one training recipe (paper Tab. 2)."""
    results = {}
    for backend in ["full", "mita", "agent", "mita_route", "linear"]:
        acc, us, _, _ = _train_vit(backend)
        results[backend] = acc
        emit(f"tab2_{backend}", us, f"eval_acc={acc:.3f}")
    gap = results["full"] - results["mita"]
    beats = sum(results["mita"] >= results[b]
                for b in ("agent", "mita_route", "linear"))
    emit("tab2_summary", 0.0,
         f"mita_vs_full_gap={gap:.3f};mita_beats_{beats}_of_3_baselines")


def _vit_flops(n: int, d: int, layers: int, heads: int, ff: int,
               attn: str, m: int = 49, k: int = 49) -> float:
    """Analytic per-image FLOPs of a ViT encoder (paper Tab. 4 accounting)."""
    proj = 4 * n * d * d * 2           # qkvo
    if attn == "full":
        att = 2 * n * n * d * 2        # scores + weighted sum
    else:                               # MiTA: landmarks + gather + m+ks
        att = (n * m * d * 2           # landmark scores (shared w/ routing)
               + n * m * d * 2         # routing logits
               + m * n * d * 2         # landmark values
               + n * (m + k) * d * 2 * 2)
    mlp = 2 * n * d * ff * 2
    return layers * (proj + att + mlp)


def tab4_segmentation_flops():
    """ADE20K backbone FLOPs reduction (paper Tab. 4: ↓42/24/14/18%)."""
    # (name, layers, d, heads, ff, resolution)
    vits = [("vit_t", 12, 192, 3, 768, 512), ("vit_s", 12, 384, 6, 1536, 512),
            ("vit_b", 12, 768, 12, 3072, 512), ("vit_l", 24, 1024, 16, 4096, 640)]
    for name, layers, d, heads, ff, res in vits:
        n = (res // 16) ** 2
        f_full = _vit_flops(n, d, layers, heads, ff, "full")
        f_mita = _vit_flops(n, d, layers, heads, ff, "mita", m=49, k=49)
        red = 100 * (1 - f_mita / f_full)
        emit(f"tab4_{name}_{res}", 0.0,
             f"full={f_full/1e9:.1f}G;mita={f_mita/1e9:.1f}G;reduction={red:.0f}%")


def _train_lm(backend: str, seq: int, steps: int = 40, b: int = 8,
              vocab: int = 211):
    from repro.data import DataConfig, synthetic_batch
    from repro.models import transformer as tfm
    cfg = tiny_lm_cfg(backend, seq=seq, m=8, k=16)
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=5, total_steps=steps)
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, batch):
        loss, g = jax.value_and_grad(lambda pp: tfm.lm_loss(pp, batch, cfg))(p)
        p, o, _ = adamw_update(g, o, p, opt_cfg)
        return p, o, loss

    dcfg = DataConfig(vocab=vocab, seq_len=seq, global_batch=b)
    loss = None
    for i in range(steps):
        batch = synthetic_batch(dcfg, i)
        params, opt, loss = step(params, opt, batch)
    us = time_fn(lambda: step(params, opt, batch), iters=3)
    return float(loss), us


def tab5_lra_throughput():
    """Long-sequence train throughput ratios (paper Tab. 5: MiTA trains to
    parity with standard attention while cutting wall-clock by 77%)."""
    for seq in (1024, 2048):
        res = {}
        for backend in ("full", "mita", "mita_route", "agent"):
            loss, us = _train_lm(backend, seq, steps=15)
            res[backend] = (loss, us)
            emit(f"tab5_{backend}_{seq}", us, f"final_loss={loss:.3f}")
        speedup = res["full"][1] / res["mita"][1]
        emit(f"tab5_summary_{seq}", 0.0,
             f"mita_speedup_vs_full={speedup:.2f}x;"
             f"route_only_slower={res['mita_route'][1] > res['mita'][1]}")


def tab6_ablations():
    """(m, k) grid + landmark-extraction + branch ablations (paper Tab. 6)."""
    grid = {}
    for (m, k) in [(8, 8), (8, 16), (16, 8), (16, 16)]:
        acc, us, _, _ = _train_vit("mita", m=m, k=k, steps=45)
        grid[(m, k)] = acc
        emit(f"tab6_m{m}_k{k}", us, f"eval_acc={acc:.3f}")
    bigger_better = grid[(16, 16)] >= grid[(8, 8)] - 0.02
    k_vs_m = grid[(8, 16)] >= grid[(16, 8)] - 0.02
    emit("tab6_summary", 0.0,
         f"mk_monotone={bigger_better};k_beats_m={k_vs_m}")

    # landmark extraction (paper Tab. 6: avg pooling beats random selection)
    for extractor in ("pool1d", "random"):
        acc, us, _, _ = _train_vit("mita", m=16, k=16, steps=45,
                                   landmark=extractor)
        emit(f"tab6_landmark_{extractor}", us, f"eval_acc={acc:.3f}")


def tab7_algorithmic_generalization():
    """Train with attention A, evaluate with attention B (paper Tab.7/Fig.9:
    standard<->MiTA transfer retains most accuracy; agent transfers worse)."""
    import dataclasses
    acc_full, _, params, cfg_full = _train_vit("full", steps=60)
    res = {"full": acc_full}
    n_classes, patch_dim, n = 10, 48, 128
    evalb = vitm.synthetic_vision_batch(
        jax.random.PRNGKey(9), 256, n, patch_dim, n_classes,
        n_signal=3, noise=1.2)
    for infer_backend in ("mita", "agent", "linear"):
        cfg_b = dataclasses.replace(
            cfg_full, attn=dataclasses.replace(cfg_full.attn,
                                               backend=infer_backend))
        acc = float(vitm.vit_accuracy(params, evalb, cfg_b))
        res[infer_backend] = acc
        emit(f"tab7_train-full_infer-{infer_backend}", 0.0,
             f"eval_acc={acc:.3f};retention={acc/max(acc_full,1e-9):.2f}")
    emit("tab7_summary", 0.0,
         f"mita_retention={res['mita']/max(acc_full,1e-9):.2f};"
         f"mita_beats_linear={res['mita'] > res['linear']}")


def fig5_inference_throughput():
    """Decode step time vs context length: MiTA O(m+k+w) vs full O(ctx)."""
    from repro.core import mita_decode as mdec
    d, hkv, g, b = 32, 2, 2, 8
    w, kk = 64, 64
    for ctx in (1024, 4096, 16384):
        dcfg = mdec.DecodeConfig(window=w, k=kk, s=1)
        # t chosen mid-window: times the common-case step (the O(ctx)
        # landmark finalize runs once per w steps and is amortized).
        t0 = ctx - w // 2
        st_m = mdec.init_decode_state(b, hkv, d, ctx, dcfg, jnp.float32)
        st_m = st_m._replace(t=jnp.asarray(t0, jnp.int32))
        st_f = mdec.init_full_state(b, hkv, d, ctx, jnp.float32)
        st_f = st_f._replace(t=jnp.asarray(t0, jnp.int32))
        q = jax.random.normal(jax.random.PRNGKey(0), (b, hkv, g, d))
        kn = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, d))

        mita_step = jax.jit(lambda s: mdec.mita_decode_step(s, q, kn, kn, dcfg)[0])
        full_step = jax.jit(lambda s: mdec.full_decode_step(s, q, kn, kn)[0])
        us_m = time_fn(mita_step, st_m, iters=5)
        us_f = time_fn(full_step, st_f, iters=5)
        emit(f"fig5_ctx{ctx}", us_m,
             f"full_us={us_f:.1f};speedup={us_f/us_m:.2f}x")
