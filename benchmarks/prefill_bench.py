"""Chunk-prefill microbenchmark: single-dispatch batched prefill + the
fused Pallas chunk-prefill kernel vs the PR-2 per-job chunked baseline.

Three measurements, emitted as CSV rows (`benchmarks.common.emit`) and as
``BENCH_prefill.json``:

  * ``prefill_engine_{per_job,batched}`` — the chunked+preemptive engine on
    the long-prompt-interference trace (decode-heavy short stream, long
    prompts landing mid-stream).  Per-job mode advances ONE prefilling job
    per engine step in its own dispatch (the PR-2 baseline); batched mode
    advances EVERY prefilling job in ONE dispatch per step.  Reports
    short-class TTFT p50/p99, aggregate tok/s, and the dispatch accounting
    (prefill dispatches issued vs chunks advanced — the O(prefilling
    slots) -> O(1) conversion).
  * ``prefill_engine_gates`` — batched short-class TTFT p99 must beat the
    per-job baseline at >= 0.98x tok/s, with greedy tokens per request
    identical to the static baseline for BOTH engines (hard failure).
  * ``prefill_step_{xla,kernel}`` — one jitted `mita_batched_chunk_prefill`
    dispatch with ``prefill_impl`` "xla" vs "kernel".  Off-TPU the kernel
    runs in interpret mode, so its absolute time is NOT meaningful there —
    the row exists so the TPU lane has a like-for-like comparison and the
    CPU CI lane exercises the kernel's compile + numerics end to end.
  * ``recurrent_prefill_{seq,chunk}_{mamba2,rglru}`` — one jitted recurrent
    prefill chunk per family, token-sequential reference scan
    (`*_prefill_chunk_seq`) vs the chunk-parallel path.  Bit-equality on
    logits and every state leaf is a hard failure; main() additionally
    gates mamba2 chunk-parallel speedup >= 1.5x (rglru is advisory — its
    per-token attention cache append bounds the win).

The kernel row runs inside `ops.scoped_fallback_counters()` and main()
hard-gates zero kernel→XLA VMEM fallbacks on it and on the engine rows
(after the JSON dump, so a red run still leaves BENCH_prefill.json).

Run:  PYTHONPATH=src python -m benchmarks.run prefill
      PYTHONPATH=src python -m benchmarks.prefill_bench --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, tiny_lm_cfg
from benchmarks.serve_bench import _interference_trace, _ttft
from repro.core import mita_decode as mdec
from repro.core.mita_decode import window_aligned
from repro.kernels import ops
from repro.launch.serve import static_generate
from repro.models import transformer as tfm
from repro.serve import EngineConfig, Request, ServingEngine


def _engine_compare(n_short: int, n_long: int, n_slots: int,
                    repeats: int = 3) -> dict:
    cfg = tiny_lm_cfg("mita_ref", m=8, k=16, layers=2, d=64, seq=256)
    w = cfg.attn.window
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    reqs = _interference_trace(cfg.vocab, w, n_short, n_long)
    pages = window_aligned(12 * w + 8, w) // w
    total_tokens = sum(r.max_new_tokens for r in reqs)
    prompt_lens = sorted({len(r.prompt) for r in reqs})

    base = EngineConfig(n_slots=n_slots, pages_per_slot=pages,
                        n_pages=3 * pages + 6, prefill_chunk=2 * w,
                        reserve_pages=4)
    out: dict = {"n_short": n_short, "n_long": n_long, "n_slots": n_slots,
                 "total_tokens": total_tokens}
    tokens: dict = {}
    for name, ecfg in (
            ("per_job", dataclasses.replace(base, prefill_mode="per-job")),
            ("batched", base)):
        ServingEngine(params, cfg, ecfg).warmup(prompt_lens)
        # best-of-N full-trace runs: CPU smoke boxes are noisy and the
        # realtime Poisson arrivals amplify a single slow step into every
        # later request's TTFT
        best = None
        for _ in range(repeats):
            eng = ServingEngine(params, cfg, ecfg)
            start = time.perf_counter()
            done = eng.run(reqs, realtime=True)
            dt = time.perf_counter() - start
            if best is None or dt < best[1]:
                best = (eng, dt, done, start)
        eng, dt, done, start = best
        ttft = _ttft(done, start)
        short = np.asarray([ttft[r.rid] for r in reqs if r.priority == 1])
        st = eng.stats()
        tokens[name] = {f.rid: f.tokens for f in done}
        out[name] = {
            "tok_s": total_tokens / dt,
            "ttft_short_p50_ms": float(np.percentile(short, 50) * 1e3),
            "ttft_short_p99_ms": float(np.percentile(short, 99) * 1e3),
            "steps": int(eng.steps),
            "chunks": int(st["chunks"]),
            "prefill_dispatches": int(st["prefill_dispatches"]),
            # dispatches per chunk-of-work: 1.0 for per-job, < 1 when the
            # batched dispatch advances several slots at once
            "dispatches_per_chunk": (st["prefill_dispatches"]
                                     / max(st["chunks"], 1)),
            "preemptions": int(st["preemptions"]),
            "prefill_kernel_fallbacks": int(st["prefill_kernel_fallbacks"]),
            "paged_kernel_fallbacks": int(st["paged_kernel_fallbacks"]),
            "finalize_kernel_fallbacks": int(st["finalize_kernel_fallbacks"]),
            "prefix_cache_hits": int(st["prefix_cache_hits"]),
            "pages_shared": int(st["pages_shared"]),
            "spec_drafted": int(st["spec_drafted"]),
            "spec_accepted": int(st["spec_accepted"]),
            "spec_rollbacks": int(st["spec_rollbacks"]),
            "rejected": int(st["rejected"]),
            "deadline_expired": int(st["deadline_expired"]),
            "retries": int(st["retries"]),
            "quarantined": int(st["quarantined"]),
            "degradation_level": int(st["degradation_level"]),
        }
        emit(f"prefill_engine_{name}", dt * 1e6 / total_tokens,
             f"{out[name]['tok_s']:.1f} tok/s | short ttft "
             f"p50 {out[name]['ttft_short_p50_ms']:.0f}ms "
             f"p99 {out[name]['ttft_short_p99_ms']:.0f}ms | "
             f"dispatches {st['prefill_dispatches']} for "
             f"{st['chunks']} chunks")

    # greedy parity vs the static baseline, per request, both engines
    scfg = dataclasses.replace(cfg, attn=dataclasses.replace(
        cfg.attn, external_finalize=True))
    match = True
    for r in reqs:
        ref, _ = static_generate(params, scfg, jnp.asarray(r.prompt)[None],
                                 r.max_new_tokens, capacity=pages * w)
        for name in ("per_job", "batched"):
            if not np.array_equal(tokens[name][r.rid], ref[0]):
                match = False
    p99_better = (out["batched"]["ttft_short_p99_ms"]
                  < out["per_job"]["ttft_short_p99_ms"])
    tps_ratio = out["batched"]["tok_s"] / out["per_job"]["tok_s"]
    out["greedy_match"] = bool(match)
    out["short_p99_better"] = bool(p99_better)
    out["tps_ratio"] = tps_ratio
    emit("prefill_engine_gates", 0.0,
         f"greedy_match={match} short_p99_better={p99_better} "
         f"tps_ratio={tps_ratio:.3f} tps_ok={tps_ratio >= 0.98}")
    return out


def _chunk_step_compare(n_steps: int) -> dict:
    """One batched chunk-prefill dispatch, XLA path vs the Pallas kernel."""
    w, k = 8, 8
    s_n, hkv, g, d, m, nc = 4, 2, 2, 32, 4, 16
    cfg_x = mdec.DecodeConfig(window=w, k=k, s=1, prefill_impl="xla")
    cfg_k = dataclasses.replace(cfg_x, prefill_impl="kernel")
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (s_n, hkv, g, nc, d))
    kc, vc = (jax.random.normal(kk, (s_n, hkv, nc, d))
              for kk in jax.random.split(key, 2))
    pt = jnp.asarray(np.arange(s_n * m).reshape(s_n, m), jnp.int32)
    slots = jnp.arange(s_n, dtype=jnp.int32)
    t0 = jnp.zeros((s_n,), jnp.int32)
    nv = jnp.full((s_n,), nc, jnp.int32)
    ntr = jnp.full((s_n,), nc, jnp.int32)
    act = jnp.ones((s_n,), bool)
    res = {"interpret": not ops.on_tpu()}
    with ops.scoped_fallback_counters() as fb:
        for name, cfg in (("xla", cfg_x), ("kernel", cfg_k)):
            st = mdec.init_paged_state(hkv, d, s_n * m, s_n, m, cfg,
                                       jnp.float32)
            step = jax.jit(mdec.mita_batched_chunk_prefill,
                           static_argnames="cfg")
            o, st2 = step(st, q, kc, vc, pt, slots, t0, nv, ntr, act,
                          cfg=cfg)
            jax.block_until_ready(o)
            t_start = time.perf_counter()
            for _ in range(n_steps):
                o, _ = step(st, q, kc, vc, pt, slots, t0, nv, ntr, act,
                            cfg=cfg)
            jax.block_until_ready(o)
            us = (time.perf_counter() - t_start) / n_steps * 1e6
            res[f"{name}_us"] = us
            note = " (interpret — not meaningful off-TPU)" \
                if name == "kernel" and res["interpret"] else ""
            emit(f"prefill_step_{name}", us,
                 f"S={s_n} Hkv={hkv} G={g} nc={nc} d={d}{note}")
    res["kernel_fallbacks"] = fb["prefill"]
    return res


def _recurrent_chunk_compare(n_steps: int) -> dict:
    """One recurrent prefill chunk per family: the retained token-sequential
    scan (`*_prefill_chunk_seq`, the exact decode-step update) vs the
    chunk-parallel path that hoists every position-local op out of the
    scan.  Bit-equality on logits and EVERY state leaf is a hard failure —
    the speedup row may never quietly trade the preemption-recompute
    contract for wall time."""
    from repro.models import mamba2 as m2
    from repro.models import rglru as rg
    from repro.models.modules import AttnConfig, ModelConfig

    w, s_n, nc = 8, 4, 64
    reps = max(n_steps, 2)
    res: dict = {"n_slots": s_n, "chunk": nc}
    for family in ("mamba2", "rglru"):
        if family == "mamba2":
            cfg = ModelConfig(n_layers=2, d_model=32, n_heads=1, n_kv=1,
                              d_ff=0, vocab=97,
                              attn=AttnConfig(window=w, backend="full"))
            params = m2.mamba_init(jax.random.PRNGKey(0), cfg)
            states = m2.mamba_slot_states(cfg, s_n)
            fns = (("seq", m2.mamba_prefill_chunk_seq),
                   ("chunk", m2.mamba_prefill_chunk))
        else:
            cfg = ModelConfig(n_layers=3, d_model=64, n_heads=4, n_kv=2,
                              d_ff=128, vocab=97,
                              attn=AttnConfig(window=w, k=w,
                                              backend="mita_ref"))
            params = rg.rg_init(jax.random.PRNGKey(0), cfg)
            states = rg.rg_slot_states(cfg, s_n, 2 * nc)
            fns = (("seq", rg.rg_prefill_chunk_seq),
                   ("chunk", rg.rg_prefill_chunk))
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (s_n, nc)), jnp.int32)
        t0 = jnp.zeros((s_n,), jnp.int32)
        nv = jnp.full((s_n,), nc, jnp.int32)
        outs, row = {}, {}
        for name, fn in fns:
            step = jax.jit(fn, static_argnames="cfg")
            lg, st = step(params, states, toks, t0, nv, cfg=cfg)   # compile
            jax.block_until_ready(lg)
            best = np.inf
            for _ in range(3):
                t_start = time.perf_counter()
                for _ in range(reps):
                    lg, st = step(params, states, toks, t0, nv, cfg=cfg)
                jax.block_until_ready(lg)
                best = min(best, time.perf_counter() - t_start)
            us = best / reps * 1e6
            row[f"{name}_us"] = us
            outs[name] = (lg, st)
            emit(f"recurrent_prefill_{name}_{family}", us,
                 f"S={s_n} nc={nc} d={cfg.d_model} L={cfg.n_layers}")
        if not np.array_equal(np.asarray(outs["seq"][0]),
                              np.asarray(outs["chunk"][0])):
            raise SystemExit(f"recurrent prefill logits mismatch ({family})")
        for a, b in zip(jax.tree.leaves(outs["seq"][1]),
                        jax.tree.leaves(outs["chunk"][1])):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                raise SystemExit(
                    f"recurrent prefill state mismatch ({family})")
        row["speedup"] = row["seq_us"] / row["chunk_us"]
        res[family] = row
    return res


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for the CI interpret-mode lane")
    ap.add_argument("--out", default="BENCH_prefill.json")
    args = ap.parse_args(argv)

    if args.smoke:
        n_short, n_long, n_slots, n_steps, reps = 12, 2, 4, 2, 2
    else:
        n_short, n_long, n_slots, n_steps, reps = 48, 3, 8, 10, 3

    print("name,us_per_call,derived")
    result = {
        "engine": _engine_compare(n_short, n_long, n_slots, repeats=reps),
        "chunk_step": _chunk_step_compare(n_steps),
        "recurrent_chunk": _recurrent_chunk_compare(n_steps),
        "backend": jax.default_backend(),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")
    # hard gates AFTER the dump: a red run still leaves the JSON behind,
    # and that is exactly the run worth inspecting (ci.yml uploads it)
    if not result["engine"]["greedy_match"]:
        raise SystemExit("greedy parity violated between chunked engines "
                         "and the static baseline")
    if result["chunk_step"]["kernel_fallbacks"]:
        raise SystemExit(
            f"chunk_step: {result['chunk_step']['kernel_fallbacks']} "
            "kernel->XLA VMEM fallback(s) on a kernel bench row (expected 0)")
    for side in ("per_job", "batched"):
        if result["engine"][side]["prefill_kernel_fallbacks"]:
            raise SystemExit(
                f"engine[{side}]: prefill_kernel_fallbacks != 0")
    m2_speedup = result["recurrent_chunk"]["mamba2"]["speedup"]
    if m2_speedup < 1.5:
        raise SystemExit(
            f"recurrent chunk-parallel prefill speedup {m2_speedup:.2f}x "
            "on mamba2 below the 1.5x gate")
    return result


def prefill_bench() -> None:
    """benchmarks.run entry point (full shapes, default output path)."""
    main([])


if __name__ == "__main__":
    main()
