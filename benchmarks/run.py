"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (stdout), mirrored in ROWS.

Usage:  PYTHONPATH=src python -m benchmarks.run [tab2 tab5 ...]
"""

import sys

from benchmarks import (chaos_bench, decode_bench, prefill_bench,
                        prefix_bench, serve_bench, spec_bench, tables)


ALL = [
    ("tab2", tables.tab2_imagenet_proxy),
    ("tab4", tables.tab4_segmentation_flops),
    ("tab5", tables.tab5_lra_throughput),
    ("tab6", tables.tab6_ablations),
    ("tab7", tables.tab7_algorithmic_generalization),
    ("fig5", tables.fig5_inference_throughput),
    ("serve", serve_bench.serve_poisson),
    ("serve_interference", serve_bench.serve_interference),
    ("serve_arch", serve_bench.serve_arch),
    ("decode", decode_bench.decode_bench),
    ("prefill", prefill_bench.prefill_bench),
    ("prefix", prefix_bench.run_prefix),
    ("spec", spec_bench.run_spec),
    ("chaos", chaos_bench.run_chaos),
]


def main() -> None:
    want = set(sys.argv[1:])
    print("name,us_per_call,derived")
    for name, fn in ALL:
        if want and name not in want:
            continue
        fn()


if __name__ == '__main__':
    main()
