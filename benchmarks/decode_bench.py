"""Decode-step microbenchmark: fused kernel + on-device sampling vs the
PR-2 XLA-gather / host-sampling path.

Three measurements, emitted as CSV rows (`benchmarks.common.emit`) and as
``BENCH_decode.json``:

  * ``decode_engine_{host,fused}`` — the continuous-batching engine on a
    Poisson mixed-length trace, sampling on the host (downloads the whole
    [S, V] logits every step) vs inside the fused program (downloads [S]
    int32 tokens).  Reports tok/s, per-step latency, and the per-step
    host<->device transfer in bytes; the gate row checks greedy tokens are
    bit-identical between the two engines.
  * ``decode_step_{xla,kernel}`` — one jitted `mita_paged_decode_step`
    with ``paged_impl`` "xla" vs "kernel".  Off-TPU the kernel runs in
    interpret mode, so its absolute time is NOT meaningful there — the
    row exists so the TPU lane has a like-for-like comparison and the CPU
    CI lane exercises the kernel's compile + numerics end to end.
  * ``finalize_step_{xla,kernel}`` — one jitted `mita_paged_finalize`
    with ``finalize_impl`` "xla" vs "kernel" (same interpret-mode caveat),
    bit-equality asserted on every finalized field, so the finalize-kernel
    win lands in its own wall-time row instead of being buried in tok/s.

Every kernel row runs inside `ops.scoped_fallback_counters()` and the
main() hard-gates zero kernel→XLA VMEM fallbacks on those rows (after the
JSON dump, so a red run still leaves BENCH_decode.json behind).

Run:  PYTHONPATH=src python -m benchmarks.run decode
      PYTHONPATH=src python -m benchmarks.decode_bench --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, tiny_lm_cfg
from repro.core import mita_decode as mdec
from repro.core.mita_decode import window_aligned
from repro.kernels import ops
from repro.models import transformer as tfm
from repro.serve import EngineConfig, Request, ServingEngine


def _trace(vocab: int, window: int, n_req: int, seed: int = 0):
    """Decode-heavy Poisson trace (same length mix as
    serve_bench.serve_poisson), half greedy and half temperature-sampled —
    the production mix: tempered requests are what makes host sampling a
    per-slot Python (fold_in + categorical) dispatch in the hot loop."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(0.03, size=n_req))
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=int(
                        rng.choice([window, 2 * window]))).astype(np.int32),
                    max_new_tokens=int(rng.integers(window, 4 * window + 1)),
                    temperature=0.8 if i % 2 else 0.0,
                    arrival=float(arrivals[i]))
            for i in range(n_req)]


def _engine_compare(vocab: int, n_req: int, n_slots: int,
                    repeats: int = 3) -> dict:
    cfg = tiny_lm_cfg("mita_ref", m=8, k=16, layers=2, d=64, vocab=vocab,
                      seq=256)
    w = cfg.attn.window
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    reqs = _trace(cfg.vocab, w, n_req)
    pages = window_aligned(2 * w + 4 * w, w) // w
    base = EngineConfig(n_slots=n_slots, pages_per_slot=pages,
                        n_pages=2 * n_slots * pages)
    total_tokens = sum(r.max_new_tokens for r in reqs)
    prompt_lens = sorted({len(r.prompt) for r in reqs})

    out: dict = {"vocab": vocab, "n_slots": n_slots, "n_req": n_req,
                 "total_tokens": total_tokens}
    tokens = {}
    for name, ecfg in (("host", base),
                       ("fused", dataclasses.replace(
                           base, sample_device="fused"))):
        ServingEngine(params, cfg, ecfg).warmup(prompt_lens)
        # best-of-N full-trace runs: CPU smoke boxes are noisy and the
        # two paths differ by well under the load-induced variance there
        dt, steps = np.inf, None
        for _ in range(repeats):
            eng = ServingEngine(params, cfg, ecfg)
            t0 = time.perf_counter()
            done = eng.run(reqs)
            dt_i = time.perf_counter() - t0
            if dt_i < dt:
                dt, steps = dt_i, np.asarray(eng.step_times)
        tokens[name] = {f.rid: f.tokens for f in done}
        # per-step host<->device traffic of the hot loop: tokens up, and
        # logits ([S, V] f32) or sampled tokens ([S] i32) down
        down = n_slots * (vocab * 4 if name == "host" else 4)
        st = eng.stats()
        out[name] = {
            "tok_s": total_tokens / dt,
            "step_ms_p50": float(np.percentile(steps, 50) * 1e3),
            "step_ms_p99": float(np.percentile(steps, 99) * 1e3),
            "steps": int(eng.steps),
            "bytes_down_per_step": down,
            "bytes_up_per_step": n_slots * 4,
            "prefill_kernel_fallbacks": int(st["prefill_kernel_fallbacks"]),
            "paged_kernel_fallbacks": int(st["paged_kernel_fallbacks"]),
            "finalize_kernel_fallbacks": int(st["finalize_kernel_fallbacks"]),
            "prefix_cache_hits": int(st["prefix_cache_hits"]),
            "pages_shared": int(st["pages_shared"]),
            "spec_drafted": int(st["spec_drafted"]),
            "spec_accepted": int(st["spec_accepted"]),
            "spec_rollbacks": int(st["spec_rollbacks"]),
            "rejected": int(st["rejected"]),
            "deadline_expired": int(st["deadline_expired"]),
            "retries": int(st["retries"]),
            "quarantined": int(st["quarantined"]),
            "degradation_level": int(st["degradation_level"]),
        }
        emit(f"decode_engine_{name}", dt * 1e6 / total_tokens,
             f"{out[name]['tok_s']:.1f} tok/s | step p50 "
             f"{out[name]['step_ms_p50']:.2f}ms | "
             f"down {down}B/step (S={n_slots}, V={vocab})")

    # bit-parity for EVERY request: greedy, and tempered too (the fused
    # sampler derives the same (rid, index) threefry keys as the host)
    match = all(np.array_equal(tokens["host"][r.rid], tokens["fused"][r.rid])
                for r in reqs)
    out["speedup"] = out["fused"]["tok_s"] / out["host"]["tok_s"]
    out["greedy_match"] = bool(match)
    out["transfer_reduction"] = (out["host"]["bytes_down_per_step"]
                                 / out["fused"]["bytes_down_per_step"])
    emit("decode_engine_gates", 0.0,
         f"greedy_match={match} speedup={out['speedup']:.2f}x "
         f"transfer_down {out['host']['bytes_down_per_step']}B -> "
         f"{out['fused']['bytes_down_per_step']}B/step "
         f"({out['transfer_reduction']:.0f}x)")
    if not match:
        raise SystemExit("greedy parity violated between host and fused "
                         "sampling engines")
    return out


def _kernel_step_compare(n_steps: int) -> dict:
    """One fused decode step, XLA gather path vs the Pallas kernel."""
    w, k = 8, 8
    b, hkv, g, d, m = 4, 2, 2, 32, 4
    cfg_x = mdec.DecodeConfig(window=w, k=k, s=1, external_finalize=True,
                              paged_impl="xla")
    cfg_k = dataclasses.replace(cfg_x, paged_impl="kernel")
    key = jax.random.PRNGKey(0)
    qi = jax.random.normal(key, (b, hkv, g, d))
    ki, vi = (jax.random.normal(kk, (b, hkv, d))
              for kk in jax.random.split(key, 2))
    pt = jnp.asarray(np.arange(b * m).reshape(b, m), jnp.int32)
    t = jnp.full((b,), w + 1, jnp.int32)
    ac = jnp.ones((b,), bool)
    res = {"interpret": not ops.on_tpu()}
    with ops.scoped_fallback_counters() as fb:
        for name, cfg in (("xla", cfg_x), ("kernel", cfg_k)):
            st = mdec.init_paged_state(hkv, d, b * m, b, m, cfg, jnp.float32)
            step = jax.jit(lambda s, *a: mdec.mita_paged_decode_step(
                s, *a, cfg))
            o, st = step(st, qi, ki, vi, pt, t, ac)       # compile
            jax.block_until_ready(o)
            t0 = time.perf_counter()
            for _ in range(n_steps):
                o, st = step(st, qi, ki, vi, pt, t, ac)
            jax.block_until_ready(o)
            us = (time.perf_counter() - t0) / n_steps * 1e6
            res[f"{name}_us"] = us
            note = " (interpret — not meaningful off-TPU)" \
                if name == "kernel" and res["interpret"] else ""
            emit(f"decode_step_{name}", us,
                 f"S={b} Hkv={hkv} G={g} d={d}{note}")
    res["kernel_fallbacks"] = fb["paged"] + fb["prefill"]
    return res


def _finalize_compare(n_steps: int) -> dict:
    """One external-finalize dispatch, XLA gathers vs the fused Pallas
    finalize kernel (`finalize_impl`), over randomized pools, landmarks,
    and window-query accumulators.  Bit-equality on every finalized field
    is a hard failure — the timing row may never quietly trade exactness
    for speed."""
    w, k = 8, 8
    b, hkv, d, m = 4, 2, 32, 4
    cfg_x = mdec.DecodeConfig(window=w, k=k, s=1, external_finalize=True,
                              finalize_impl="xla")
    cfg_k = dataclasses.replace(cfg_x, finalize_impl="kernel")
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    n_pages = b * m
    pt = jnp.asarray(np.arange(n_pages).reshape(b, m), jnp.int32)
    t = jnp.full((b,), 2 * w, jnp.int32)
    due = jnp.ones((b,), bool)
    res = {"interpret": not ops.on_tpu()}
    states = {}
    with ops.scoped_fallback_counters() as fb:
        for name, cfg in (("xla", cfg_x), ("kernel", cfg_k)):
            st = mdec.init_paged_state(hkv, d, n_pages, b, m, cfg,
                                       jnp.float32)
            st = st._replace(
                k_pool=jax.random.normal(ks[0], st.k_pool.shape),
                v_pool=jax.random.normal(ks[1], st.v_pool.shape),
                q_sum=jax.random.normal(ks[2], st.q_sum.shape),
                lm_q=jax.random.normal(ks[3], st.lm_q.shape))
            fin = jax.jit(mdec.mita_paged_finalize, static_argnames="cfg")
            out = fin(st, pt, t, due, cfg=cfg)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(n_steps):
                out = fin(st, pt, t, due, cfg=cfg)
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / n_steps * 1e6
            res[f"{name}_us"] = us
            states[name] = out
            note = " (interpret — not meaningful off-TPU)" \
                if name == "kernel" and res["interpret"] else ""
            emit(f"finalize_step_{name}", us,
                 f"S={b} Hkv={hkv} M={m} d={d}{note}")
    res["kernel_fallbacks"] = fb["finalize"]
    for f in ("lm_q", "lm_v", "expert_idx", "expert_valid", "q_sum"):
        if not np.array_equal(np.asarray(getattr(states["kernel"], f)),
                              np.asarray(getattr(states["xla"], f))):
            raise SystemExit(f"finalize kernel/xla bit mismatch on {f}")
    return res


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for the CI interpret-mode lane")
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args(argv)

    if args.smoke:
        vocab, n_req, n_slots, n_steps, reps = 1024, 8, 4, 3, 2
    else:
        vocab, n_req, n_slots, n_steps, reps = 32768, 32, 8, 20, 3

    print("name,us_per_call,derived")
    result = {
        "engine": _engine_compare(vocab, n_req, n_slots, repeats=reps),
        "kernel_step": _kernel_step_compare(n_steps),
        "finalize_step": _finalize_compare(n_steps),
        "backend": jax.default_backend(),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")
    # hard gates AFTER the dump: a red run still leaves the JSON behind
    for row in ("kernel_step", "finalize_step"):
        if result[row]["kernel_fallbacks"]:
            raise SystemExit(
                f"{row}: {result[row]['kernel_fallbacks']} kernel->XLA VMEM "
                "fallback(s) on a kernel bench row (expected 0)")
    for side in ("host", "fused"):
        if result["engine"][side]["prefill_kernel_fallbacks"]:
            raise SystemExit(
                f"engine[{side}]: prefill_kernel_fallbacks != 0")
    return result


def decode_bench() -> None:
    """benchmarks.run entry point (full shapes, default output path)."""
    main([])


if __name__ == "__main__":
    main()
