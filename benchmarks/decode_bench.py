"""Decode-step microbenchmark: fused kernel + on-device sampling vs the
PR-2 XLA-gather / host-sampling path.

Three measurements, emitted as CSV rows (`benchmarks.common.emit`) and as
``BENCH_decode.json``:

  * ``decode_engine_{host,fused}`` — the continuous-batching engine on a
    Poisson mixed-length trace, sampling on the host (downloads the whole
    [S, V] logits every step) vs inside the fused program (downloads [S]
    int32 tokens).  Reports tok/s, per-step latency, and the per-step
    host<->device transfer in bytes; the gate row checks greedy tokens are
    bit-identical between the two engines.
  * ``decode_step_{xla,kernel}`` — one jitted `mita_paged_decode_step`
    with ``paged_impl`` "xla" vs "kernel".  Off-TPU the kernel runs in
    interpret mode, so its absolute time is NOT meaningful there — the
    row exists so the TPU lane has a like-for-like comparison and the CPU
    CI lane exercises the kernel's compile + numerics end to end.

Run:  PYTHONPATH=src python -m benchmarks.run decode
      PYTHONPATH=src python -m benchmarks.decode_bench --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, tiny_lm_cfg
from repro.core import mita_decode as mdec
from repro.core.mita_decode import window_aligned
from repro.kernels import ops
from repro.models import transformer as tfm
from repro.serve import EngineConfig, Request, ServingEngine


def _trace(vocab: int, window: int, n_req: int, seed: int = 0):
    """Decode-heavy Poisson trace (same length mix as
    serve_bench.serve_poisson), half greedy and half temperature-sampled —
    the production mix: tempered requests are what makes host sampling a
    per-slot Python (fold_in + categorical) dispatch in the hot loop."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(0.03, size=n_req))
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=int(
                        rng.choice([window, 2 * window]))).astype(np.int32),
                    max_new_tokens=int(rng.integers(window, 4 * window + 1)),
                    temperature=0.8 if i % 2 else 0.0,
                    arrival=float(arrivals[i]))
            for i in range(n_req)]


def _engine_compare(vocab: int, n_req: int, n_slots: int,
                    repeats: int = 3) -> dict:
    cfg = tiny_lm_cfg("mita_ref", m=8, k=16, layers=2, d=64, vocab=vocab,
                      seq=256)
    w = cfg.attn.window
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    reqs = _trace(cfg.vocab, w, n_req)
    pages = window_aligned(2 * w + 4 * w, w) // w
    base = EngineConfig(n_slots=n_slots, pages_per_slot=pages,
                        n_pages=2 * n_slots * pages)
    total_tokens = sum(r.max_new_tokens for r in reqs)
    prompt_lens = sorted({len(r.prompt) for r in reqs})

    out: dict = {"vocab": vocab, "n_slots": n_slots, "n_req": n_req,
                 "total_tokens": total_tokens}
    tokens = {}
    for name, ecfg in (("host", base),
                       ("fused", dataclasses.replace(
                           base, sample_device="fused"))):
        ServingEngine(params, cfg, ecfg).warmup(prompt_lens)
        # best-of-N full-trace runs: CPU smoke boxes are noisy and the
        # two paths differ by well under the load-induced variance there
        dt, steps = np.inf, None
        for _ in range(repeats):
            eng = ServingEngine(params, cfg, ecfg)
            t0 = time.perf_counter()
            done = eng.run(reqs)
            dt_i = time.perf_counter() - t0
            if dt_i < dt:
                dt, steps = dt_i, np.asarray(eng.step_times)
        tokens[name] = {f.rid: f.tokens for f in done}
        # per-step host<->device traffic of the hot loop: tokens up, and
        # logits ([S, V] f32) or sampled tokens ([S] i32) down
        down = n_slots * (vocab * 4 if name == "host" else 4)
        st = eng.stats()
        out[name] = {
            "tok_s": total_tokens / dt,
            "step_ms_p50": float(np.percentile(steps, 50) * 1e3),
            "step_ms_p99": float(np.percentile(steps, 99) * 1e3),
            "steps": int(eng.steps),
            "bytes_down_per_step": down,
            "bytes_up_per_step": n_slots * 4,
            "prefill_kernel_fallbacks": int(st["prefill_kernel_fallbacks"]),
            "prefix_cache_hits": int(st["prefix_cache_hits"]),
            "pages_shared": int(st["pages_shared"]),
            "spec_drafted": int(st["spec_drafted"]),
            "spec_accepted": int(st["spec_accepted"]),
            "spec_rollbacks": int(st["spec_rollbacks"]),
            "rejected": int(st["rejected"]),
            "deadline_expired": int(st["deadline_expired"]),
            "retries": int(st["retries"]),
            "quarantined": int(st["quarantined"]),
            "degradation_level": int(st["degradation_level"]),
        }
        emit(f"decode_engine_{name}", dt * 1e6 / total_tokens,
             f"{out[name]['tok_s']:.1f} tok/s | step p50 "
             f"{out[name]['step_ms_p50']:.2f}ms | "
             f"down {down}B/step (S={n_slots}, V={vocab})")

    # bit-parity for EVERY request: greedy, and tempered too (the fused
    # sampler derives the same (rid, index) threefry keys as the host)
    match = all(np.array_equal(tokens["host"][r.rid], tokens["fused"][r.rid])
                for r in reqs)
    out["speedup"] = out["fused"]["tok_s"] / out["host"]["tok_s"]
    out["greedy_match"] = bool(match)
    out["transfer_reduction"] = (out["host"]["bytes_down_per_step"]
                                 / out["fused"]["bytes_down_per_step"])
    emit("decode_engine_gates", 0.0,
         f"greedy_match={match} speedup={out['speedup']:.2f}x "
         f"transfer_down {out['host']['bytes_down_per_step']}B -> "
         f"{out['fused']['bytes_down_per_step']}B/step "
         f"({out['transfer_reduction']:.0f}x)")
    if not match:
        raise SystemExit("greedy parity violated between host and fused "
                         "sampling engines")
    return out


def _kernel_step_compare(n_steps: int) -> dict:
    """One fused decode step, XLA gather path vs the Pallas kernel."""
    w, k = 8, 8
    b, hkv, g, d, m = 4, 2, 2, 32, 4
    cfg_x = mdec.DecodeConfig(window=w, k=k, s=1, external_finalize=True,
                              paged_impl="xla")
    cfg_k = dataclasses.replace(cfg_x, paged_impl="kernel")
    key = jax.random.PRNGKey(0)
    qi = jax.random.normal(key, (b, hkv, g, d))
    ki, vi = (jax.random.normal(kk, (b, hkv, d))
              for kk in jax.random.split(key, 2))
    pt = jnp.asarray(np.arange(b * m).reshape(b, m), jnp.int32)
    t = jnp.full((b,), w + 1, jnp.int32)
    ac = jnp.ones((b,), bool)
    res = {"interpret": not ops.on_tpu()}
    for name, cfg in (("xla", cfg_x), ("kernel", cfg_k)):
        st = mdec.init_paged_state(hkv, d, b * m, b, m, cfg, jnp.float32)
        step = jax.jit(lambda s, *a: mdec.mita_paged_decode_step(s, *a, cfg))
        o, st = step(st, qi, ki, vi, pt, t, ac)       # compile
        jax.block_until_ready(o)
        t0 = time.perf_counter()
        for _ in range(n_steps):
            o, st = step(st, qi, ki, vi, pt, t, ac)
        jax.block_until_ready(o)
        us = (time.perf_counter() - t0) / n_steps * 1e6
        res[f"{name}_us"] = us
        note = " (interpret — not meaningful off-TPU)" \
            if name == "kernel" and res["interpret"] else ""
        emit(f"decode_step_{name}", us, f"S={b} Hkv={hkv} G={g} d={d}{note}")
    return res


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for the CI interpret-mode lane")
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args(argv)

    if args.smoke:
        vocab, n_req, n_slots, n_steps, reps = 1024, 8, 4, 3, 2
    else:
        vocab, n_req, n_slots, n_steps, reps = 32768, 32, 8, 20, 3

    print("name,us_per_call,derived")
    result = {
        "engine": _engine_compare(vocab, n_req, n_slots, repeats=reps),
        "kernel_step": _kernel_step_compare(n_steps),
        "backend": jax.default_backend(),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")
    return result


def decode_bench() -> None:
    """benchmarks.run entry point (full shapes, default output path)."""
    main([])


if __name__ == "__main__":
    main()
