"""Serving benchmark: continuous-batching paged engine vs the static-batch
baseline on a Poisson arrival trace with mixed prompt/generation lengths.

Emits (via benchmarks.common.emit):
  * aggregate decode throughput (tokens/sec) for both schedulers,
  * p50/p99 inter-token latency and mean TTFT (arrival -> first token),
  * a greedy-parity bit: every request's engine tokens must equal the
    static path's tokens for the same request.

Run:  PYTHONPATH=src python -m benchmarks.run serve
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, tiny_lm_cfg
from repro.core.mita_decode import window_aligned
from repro.launch.serve import static_generate
from repro.models import transformer as tfm
from repro.serve import EngineConfig, Request, ServingEngine


def _trace(vocab: int, window: int, n_req: int, seed: int = 0,
           mean_gap_s: float = 0.03) -> list[Request]:
    """Poisson arrivals, prompt length in {w, 2w}, gen length in [w, 4w] —
    a decode-heavy mix whose generation-length spread is what continuous
    batching exploits (a static batch decodes everyone to the group max)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_gap_s, size=n_req))
    reqs = []
    for i in range(n_req):
        n = int(rng.choice([window, 2 * window]))
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab, size=n).astype(np.int32),
            max_new_tokens=int(rng.integers(window, 4 * window + 1)),
            arrival=float(arrivals[i])))
    return reqs


def _latency_stats(token_times: dict[int, list[float]],
                   arrivals: dict[int, float]):
    itl, ttft = [], []
    for rid, times in token_times.items():
        ttft.append(times[0] - arrivals[rid])
        itl.extend(np.diff(times))
    itl = np.asarray(itl) if itl else np.zeros(1)
    return (float(np.percentile(itl, 50)), float(np.percentile(itl, 99)),
            float(np.mean(ttft)))


def _run_static_trace(params, cfg, reqs, n_slots: int, capacity: int,
                      start: float):
    """FCFS static batching: group arrived same-prompt-length requests into
    fixed batches, decode everyone to the group's max generation length.
    Tokens are stamped at their decode-step times (generous to the
    baseline); the slot waste of mixed lengths shows up as wall time."""
    waiting = sorted(reqs, key=lambda r: r.arrival)
    idx = 0
    queue: list[Request] = []
    tokens: dict[int, np.ndarray] = {}
    times: dict[int, list[float]] = {}
    while idx < len(waiting) or queue:
        now = time.perf_counter() - start
        while idx < len(waiting) and waiting[idx].arrival <= now:
            queue.append(waiting[idx])
            idx += 1
        if not queue:
            time.sleep(max(0.0, waiting[idx].arrival - now))
            continue
        n0 = len(queue[0].prompt)
        group = [r for r in queue if len(r.prompt) == n0][:n_slots]
        for r in group:
            queue.remove(r)
        gmax = max(r.max_new_tokens for r in group)
        prompts = np.stack([r.prompt for r in group])
        if len(group) < n_slots:   # a static server pads the fixed batch
            prompts = np.concatenate(
                [prompts, np.repeat(prompts[-1:], n_slots - len(group), 0)])
        t0 = time.perf_counter()
        out, tm = static_generate(params, cfg, jnp.asarray(prompts), gmax,
                                  capacity=capacity)
        stamps = t0 + tm["prefill_s"] + np.concatenate(
            [[0.0], np.cumsum(tm["step_times"])])
        for si, r in enumerate(group):
            tokens[r.rid] = out[si, : r.max_new_tokens]
            times[r.rid] = list(stamps[: r.max_new_tokens])
    return tokens, times, time.perf_counter() - start


def serve_poisson(n_req: int = 32, n_slots: int = 8) -> None:
    cfg = tiny_lm_cfg("mita", m=8, k=16, layers=2, d=64, seq=256)
    w = cfg.attn.window
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    reqs = _trace(cfg.vocab, w, n_req)
    pages = window_aligned(2 * w + 4 * w, w) // w   # max prompt + max gen
    capacity = pages * w                        # matched shapes -> bit parity
    ecfg = EngineConfig(n_slots=n_slots, pages_per_slot=pages,
                        n_pages=2 * n_slots * pages)
    total_tokens = sum(r.max_new_tokens for r in reqs)

    # warmup both paths (compile outside the timed region)
    import dataclasses
    prompt_lens = sorted({len(r.prompt) for r in reqs})
    ServingEngine(params, cfg, ecfg).warmup(prompt_lens)
    scfg = dataclasses.replace(cfg, attn=dataclasses.replace(
        cfg.attn, external_finalize=True))
    for n in prompt_lens:
        # gen w+2 crosses a window boundary, so the static path's external
        # finalize program compiles here and not inside the timed region
        static_generate(params, scfg,
                        jnp.asarray(np.stack([r.prompt for r in reqs
                                              if len(r.prompt) == n][:1]
                                             * n_slots)),
                        w + 2, capacity=capacity)

    # --- continuous-batching engine, arrivals on the wall clock ---
    eng = ServingEngine(params, cfg, ecfg)
    start = time.perf_counter()
    done = eng.run(reqs, realtime=True)
    dt_engine = time.perf_counter() - start
    eng_tokens = {f.rid: f.tokens for f in done}
    p50, p99, ttft = _latency_stats({f.rid: f.token_times for f in done},
                                    {f.rid: start + f.arrival for f in done})
    tps_e = total_tokens / dt_engine
    emit("serve_poisson_engine", dt_engine * 1e6 / total_tokens,
         f"{tps_e:.1f} tok/s | itl p50 {p50 * 1e3:.1f}ms "
         f"p99 {p99 * 1e3:.1f}ms | ttft {ttft * 1e3:.0f}ms")

    # --- static-batch baseline on the same trace ---
    start = time.perf_counter()
    st_tokens, st_times, dt_static = _run_static_trace(
        params, scfg, reqs, n_slots, capacity, start)
    p50s, p99s, ttfts = _latency_stats(
        st_times, {r.rid: start + r.arrival for r in reqs})
    tps_s = total_tokens / dt_static
    emit("serve_poisson_static", dt_static * 1e6 / total_tokens,
         f"{tps_s:.1f} tok/s | itl p50 {p50s * 1e3:.1f}ms "
         f"p99 {p99s * 1e3:.1f}ms | ttft {ttfts * 1e3:.0f}ms")

    match = all(np.array_equal(eng_tokens[r.rid], st_tokens[r.rid])
                for r in reqs)
    emit("serve_poisson_parity", 0.0,
         f"greedy_match={match} speedup={tps_e / tps_s:.2f}x")
