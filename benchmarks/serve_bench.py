"""Serving benchmarks: continuous-batching paged engine vs baselines.

Three cells:
  * `serve_poisson` — engine vs static batching on a Poisson arrival trace
    with mixed prompt/generation lengths (PR-1 regression cell);
  * `serve_interference` — a decode-heavy short-request stream with long
    prompts arriving mid-stream: the unchunked engine stalls every decoding
    request behind each long monolithic prefill, the chunked+preemptive
    engine admits the long prompt in window-aligned chunks interleaved
    with the decode batch.  Reports TTFT p50/p99 for the short (victim)
    class and overall, aggregate tokens/sec for both engines, and gates:
    chunked short-class TTFT p99 strictly lower, tokens/sec within 5%,
    greedy tokens per request identical to the static baseline.
  * `serve_arch` — the cross-BACKEND matrix: the same generic scheduler
    over the paged MiTA backend, the Mamba2 (SSD) backend, and the RG-LRU
    hybrid backend (`serve.backends`), one mixed-length Poisson trace
    each, gating greedy bit-parity vs each backend's static reference and
    emitting per-backend rows to ``BENCH_serve_arch.json``.

Emits (via benchmarks.common.emit) throughput, latency percentiles, and a
greedy-parity bit per trace.

Run:  PYTHONPATH=src python -m benchmarks.run serve serve_arch
      PYTHONPATH=src python -m benchmarks.serve_bench --smoke --backend all
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, tiny_lm_cfg
from repro.core.mita_decode import window_aligned
from repro.launch.serve import static_generate
from repro.models import transformer as tfm
from repro.serve import EngineConfig, Request, ServingEngine


def _trace(vocab: int, window: int, n_req: int, seed: int = 0,
           mean_gap_s: float = 0.03) -> list[Request]:
    """Poisson arrivals, prompt length in {w, 2w}, gen length in [w, 4w] —
    a decode-heavy mix whose generation-length spread is what continuous
    batching exploits (a static batch decodes everyone to the group max)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_gap_s, size=n_req))
    reqs = []
    for i in range(n_req):
        n = int(rng.choice([window, 2 * window]))
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab, size=n).astype(np.int32),
            max_new_tokens=int(rng.integers(window, 4 * window + 1)),
            arrival=float(arrivals[i])))
    return reqs


def _latency_stats(token_times: dict[int, list[float]],
                   arrivals: dict[int, float]):
    itl, ttft = [], []
    for rid, times in token_times.items():
        ttft.append(times[0] - arrivals[rid])
        itl.extend(np.diff(times))
    itl = np.asarray(itl) if itl else np.zeros(1)
    return (float(np.percentile(itl, 50)), float(np.percentile(itl, 99)),
            float(np.mean(ttft)))


def _run_static_trace(params, cfg, reqs, n_slots: int, capacity: int,
                      start: float):
    """FCFS static batching: group arrived same-prompt-length requests into
    fixed batches, decode everyone to the group's max generation length.
    Tokens are stamped at their decode-step times (generous to the
    baseline); the slot waste of mixed lengths shows up as wall time."""
    waiting = sorted(reqs, key=lambda r: r.arrival)
    idx = 0
    queue: list[Request] = []
    tokens: dict[int, np.ndarray] = {}
    times: dict[int, list[float]] = {}
    while idx < len(waiting) or queue:
        now = time.perf_counter() - start
        while idx < len(waiting) and waiting[idx].arrival <= now:
            queue.append(waiting[idx])
            idx += 1
        if not queue:
            time.sleep(max(0.0, waiting[idx].arrival - now))
            continue
        n0 = len(queue[0].prompt)
        group = [r for r in queue if len(r.prompt) == n0][:n_slots]
        for r in group:
            queue.remove(r)
        gmax = max(r.max_new_tokens for r in group)
        prompts = np.stack([r.prompt for r in group])
        if len(group) < n_slots:   # a static server pads the fixed batch
            prompts = np.concatenate(
                [prompts, np.repeat(prompts[-1:], n_slots - len(group), 0)])
        t0 = time.perf_counter()
        out, tm = static_generate(params, cfg, jnp.asarray(prompts), gmax,
                                  capacity=capacity)
        stamps = t0 + tm["prefill_s"] + np.concatenate(
            [[0.0], np.cumsum(tm["step_times"])])
        for si, r in enumerate(group):
            tokens[r.rid] = out[si, : r.max_new_tokens]
            times[r.rid] = list(stamps[: r.max_new_tokens])
    return tokens, times, time.perf_counter() - start


def serve_poisson(n_req: int = 32, n_slots: int = 8) -> None:
    cfg = tiny_lm_cfg("mita", m=8, k=16, layers=2, d=64, seq=256)
    w = cfg.attn.window
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    reqs = _trace(cfg.vocab, w, n_req)
    pages = window_aligned(2 * w + 4 * w, w) // w   # max prompt + max gen
    capacity = pages * w                        # matched shapes -> bit parity
    ecfg = EngineConfig(n_slots=n_slots, pages_per_slot=pages,
                        n_pages=2 * n_slots * pages)
    total_tokens = sum(r.max_new_tokens for r in reqs)

    # warmup both paths (compile outside the timed region)
    import dataclasses
    prompt_lens = sorted({len(r.prompt) for r in reqs})
    ServingEngine(params, cfg, ecfg).warmup(prompt_lens)
    scfg = dataclasses.replace(cfg, attn=dataclasses.replace(
        cfg.attn, external_finalize=True))
    for n in prompt_lens:
        # gen w+2 crosses a window boundary, so the static path's external
        # finalize program compiles here and not inside the timed region
        static_generate(params, scfg,
                        jnp.asarray(np.stack([r.prompt for r in reqs
                                              if len(r.prompt) == n][:1]
                                             * n_slots)),
                        w + 2, capacity=capacity)

    # --- continuous-batching engine, arrivals on the wall clock ---
    eng = ServingEngine(params, cfg, ecfg)
    start = time.perf_counter()
    done = eng.run(reqs, realtime=True)
    dt_engine = time.perf_counter() - start
    eng_tokens = {f.rid: f.tokens for f in done}
    p50, p99, ttft = _latency_stats({f.rid: f.token_times for f in done},
                                    {f.rid: start + f.arrival for f in done})
    tps_e = total_tokens / dt_engine
    emit("serve_poisson_engine", dt_engine * 1e6 / total_tokens,
         f"{tps_e:.1f} tok/s | itl p50 {p50 * 1e3:.1f}ms "
         f"p99 {p99 * 1e3:.1f}ms | ttft {ttft * 1e3:.0f}ms")

    # --- static-batch baseline on the same trace ---
    start = time.perf_counter()
    st_tokens, st_times, dt_static = _run_static_trace(
        params, scfg, reqs, n_slots, capacity, start)
    p50s, p99s, ttfts = _latency_stats(
        st_times, {r.rid: start + r.arrival for r in reqs})
    tps_s = total_tokens / dt_static
    emit("serve_poisson_static", dt_static * 1e6 / total_tokens,
         f"{tps_s:.1f} tok/s | itl p50 {p50s * 1e3:.1f}ms "
         f"p99 {p99s * 1e3:.1f}ms | ttft {ttfts * 1e3:.0f}ms")

    match = all(np.array_equal(eng_tokens[r.rid], st_tokens[r.rid])
                for r in reqs)
    emit("serve_poisson_parity", 0.0,
         f"greedy_match={match} speedup={tps_e / tps_s:.2f}x")


# -------------------------------------------------- long-prompt interference --

def _interference_trace(vocab: int, w: int, n_short: int, n_long: int,
                        seed: int = 0):
    """Decode-heavy short stream + long prompts arriving mid-stream.

    Shorts: prompt = w, gen in [w, 2w], priority 1, Poisson arrivals.
    Longs:  prompt = 12w (window-aligned so the chunked path serves them),
            gen = 8, priority 0, arriving evenly inside the short stream.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(0.025, size=n_short))
    reqs = []
    for i in range(n_short):
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, vocab, size=w).astype(np.int32),
            max_new_tokens=int(rng.integers(w, 2 * w + 1)),
            arrival=float(arrivals[i]), priority=1))
    span = float(arrivals[-1])
    for j in range(n_long):
        reqs.append(Request(
            rid=n_short + j,
            prompt=rng.integers(0, vocab, size=12 * w).astype(np.int32),
            max_new_tokens=8,
            arrival=span * (j + 1) / (n_long + 1), priority=0))
    return reqs


def _ttft(done, start):
    return {f.rid: f.first_token - (start + f.arrival) for f in done}


def serve_interference(n_short: int = 48, n_long: int = 3,
                       n_slots: int = 8) -> None:
    """Chunked+preemptive engine vs the unchunked engine on the same trace.

    Gates (emitted in the derived column):
      * short-class TTFT p99 strictly lower with chunking,
      * aggregate tokens/sec within 5% of the unchunked engine,
      * greedy tokens per request identical to the static baseline.
    """
    cfg = tiny_lm_cfg("mita_ref", m=8, k=16, layers=2, d=64, seq=256)
    w = cfg.attn.window
    params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
    reqs = _interference_trace(cfg.vocab, w, n_short, n_long)
    pages = window_aligned(12 * w + 8, w) // w      # long prompt + gen
    total_tokens = sum(r.max_new_tokens for r in reqs)
    prompt_lens = sorted({len(r.prompt) for r in reqs})

    base = EngineConfig(n_slots=n_slots, pages_per_slot=pages,
                        n_pages=3 * pages + 6)
    chunked = dataclasses.replace(base, prefill_chunk=2 * w, reserve_pages=4)

    results = {}
    for name, ecfg in (("unchunked", base), ("chunked", chunked)):
        eng = ServingEngine(params, cfg, ecfg)
        eng.warmup(prompt_lens)     # compiles outside the timed region
        start = time.perf_counter()
        done = eng.run(reqs, realtime=True)
        dt = time.perf_counter() - start
        ttft = _ttft(done, start)
        short = np.asarray([ttft[r.rid] for r in reqs if r.priority == 1])
        allt = np.asarray(list(ttft.values()))
        stats = eng.stats()
        results[name] = dict(
            tokens={f.rid: f.tokens for f in done}, tps=total_tokens / dt,
            p50=float(np.percentile(short, 50)),
            p99=float(np.percentile(short, 99)),
            p99_all=float(np.percentile(allt, 99)), stats=stats)
        emit(f"serve_interference_{name}", dt * 1e6 / total_tokens,
             f"{results[name]['tps']:.1f} tok/s | short ttft "
             f"p50 {results[name]['p50'] * 1e3:.0f}ms "
             f"p99 {results[name]['p99'] * 1e3:.0f}ms | all ttft "
             f"p99 {results[name]['p99_all'] * 1e3:.0f}ms | "
             f"chunks={stats['chunks']} preempt={stats['preemptions']} "
             f"pages_hw={stats['pages_high_water']}")

    # greedy parity vs the static baseline, per request
    scfg = dataclasses.replace(cfg, attn=dataclasses.replace(
        cfg.attn, external_finalize=True))
    match = True
    for r in reqs:
        ref, _ = static_generate(params, scfg, jnp.asarray(r.prompt)[None],
                                 r.max_new_tokens, capacity=pages * w)
        for name in results:
            if not np.array_equal(results[name]["tokens"][r.rid], ref[0]):
                match = False
    p99_better = results["chunked"]["p99"] < results["unchunked"]["p99"]
    tps_ratio = results["chunked"]["tps"] / results["unchunked"]["tps"]
    emit("serve_interference_gates", 0.0,
         f"greedy_match={match} short_p99_better={p99_better} "
         f"tps_ratio={tps_ratio:.3f} tps_within_5pct={abs(tps_ratio - 1) <= 0.05}")


# ----------------------------------------------------- cross-backend matrix --

BACKENDS = ("mita", "mamba2", "rglru")


def _arch_cell(name: str):
    """(model cfg, params, backend factory) for one matrix cell — the MiTA
    cell at the tiny-LM scale of `serve_poisson`, the recurrent cells as
    the registry smoke variants (the same configs `launch.serve --arch
    mamba2-370m --smoke` serves)."""
    from repro.serve import backends

    if name == "mita":
        cfg = tiny_lm_cfg("mita_ref", m=8, k=16, layers=2, d=64, seq=128)
        params = tfm.lm_init(jax.random.PRNGKey(0), cfg)
        return cfg, params, lambda ecfg: backends.resolve(params, cfg, ecfg)
    from repro.configs.registry import arch_params, get_arch
    arch = get_arch("mamba2-370m" if name == "mamba2"
                    else "recurrentgemma-9b", smoke=True)
    params = arch_params(arch, jax.random.PRNGKey(0))
    return arch.model, params, \
        lambda ecfg: backends.for_arch(arch, params, ecfg)


def serve_arch(which: str = "all", n_req: int = 10,
               out: str = "BENCH_serve_arch.json") -> dict:
    """Backend matrix on a mixed-length Poisson trace (queued up front —
    max-throughput mode keeps the row deterministic): one row per backend
    with tok/s, scheduler counters, the backend's own dispatch counts, and
    the greedy-parity gate vs its static reference.  Chunked mode with a
    tight pool so admission pressure (and the preemption machinery) is
    exercised on every backend.  Raises if any backend loses bit-parity.
    """
    import json

    rng_gens = dict(mita=(2, 17), mamba2=(2, 13), rglru=(2, 13))
    results = {}
    for name in (BACKENDS if which in ("all", None) else (which,)):
        cfg, params, mk = _arch_cell(name)
        w = cfg.attn.window
        rng = np.random.default_rng(3)
        lo, hi = rng_gens[name]
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, size=int(
                            rng.choice([w, 2 * w]))).astype(np.int32),
                        max_new_tokens=int(rng.integers(lo, hi)))
                for i in range(n_req)]
        total = sum(r.max_new_tokens for r in reqs)
        pages = window_aligned(2 * w + hi, w) // w
        ecfg = EngineConfig(n_slots=4, pages_per_slot=pages,
                            n_pages=4 * pages + 2, prefill_chunk=w)
        eng = ServingEngine(params, cfg, ecfg, backend=mk(ecfg))
        t0 = time.perf_counter()
        done = eng.run(reqs)
        dt = time.perf_counter() - t0
        ref_backend = mk(ecfg)
        match = all(
            np.array_equal(f.tokens, ref_backend.static_reference(
                r.prompt[None], r.max_new_tokens)[0])
            for f, r in zip(done, reqs))
        st = eng.stats()
        results[name] = dict(
            tok_s=total / dt, greedy_match=bool(match),
            steps=st["steps"], chunks=st["chunks"],
            prefill_dispatches=st["prefill_dispatches"],
            decode_dispatches=st["decode_dispatches"],
            preemptions=st["preemptions"],
            prefill_kernel_fallbacks=st["prefill_kernel_fallbacks"],
            prefix_cache_hits=st["prefix_cache_hits"],
            pages_shared=st["pages_shared"],
            spec_drafted=st["spec_drafted"],
            spec_accepted=st["spec_accepted"],
            spec_rollbacks=st["spec_rollbacks"],
            rejected=st["rejected"],
            deadline_expired=st["deadline_expired"],
            retries=st["retries"],
            quarantined=st["quarantined"],
            degradation_level=st["degradation_level"])
        emit(f"serve_arch_{name}", dt * 1e6 / total,
             f"{total / dt:.1f} tok/s | greedy_match={match} | "
             f"chunks={st['chunks']} in {st['prefill_dispatches']} "
             f"dispatches, decode_dispatches={st['decode_dispatches']}, "
             f"preempt={st['preemptions']}, "
             f"kernel_fallbacks={st['prefill_kernel_fallbacks']}")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    bad = [n for n, r in results.items() if not r["greedy_match"]]
    if bad:
        raise SystemExit(f"greedy parity lost for backend(s): {bad}")
    return results


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: fewer requests")
    ap.add_argument("--backend", default="all",
                    choices=("all",) + BACKENDS)
    ap.add_argument("--out", default="BENCH_serve_arch.json")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    serve_arch(args.backend, n_req=6 if args.smoke else 10, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
